"""Shared benchmark utilities: wall-clock timing of jitted callables and the
canonical `name,us_per_call,derived` CSV row format."""
from __future__ import annotations

import json
import os
import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median-of-iters wall time in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float | None, derived: str) -> dict:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


def emit(rows: list[dict]) -> None:
    for r in rows:
        us = "" if r["us_per_call"] is None else f"{r['us_per_call']:.1f}"
        print(f"{r['name']},{us},{r['derived']}")


def save_artifact(name: str, data) -> str:
    os.makedirs("artifacts/bench", exist_ok=True)
    path = f"artifacts/bench/{name}.json"
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return path
