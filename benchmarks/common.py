"""Shared benchmark utilities: wall-clock timing of jitted callables, the
canonical `name,us_per_call,derived` CSV row format, and the provenance
stamp every BENCH_*.json artifact carries."""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import time

import jax


def provenance() -> dict:
    """Stamp for BENCH_*.json artifacts: the commit and date the numbers were
    measured at plus the jax backend that produced them, so the bench
    trajectory is machine-reconstructable from the artifacts alone.

    ``dirty`` records whether the working tree had uncommitted changes at
    measurement time -- a PR's refreshed artifact is necessarily stamped
    with the parent commit plus ``dirty: true`` (the measuring tree IS the
    commit under review); ``dirty: false`` means the stamped commit alone
    reproduces the numbers.
    """
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def _git(*args):
        return subprocess.run(["git", *args], capture_output=True, text=True,
                              cwd=repo, timeout=10).stdout

    try:
        commit = _git("rev-parse", "HEAD").strip() or "unknown"
        dirty = bool(_git("status", "--porcelain").strip())
    except (OSError, subprocess.SubprocessError):
        commit, dirty = "unknown", False
    return {
        "commit": commit,
        "dirty": dirty,
        "date": datetime.datetime.now(datetime.timezone.utc)
                        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "backend": jax.default_backend(),
    }


def validate_provenance(data: dict) -> None:
    """Assert the artifact carries the stamp fields (schema checkers call
    this so an unstamped artifact fails CI, not a later archaeology dig)."""
    for key in ("schema", "commit", "date", "backend"):
        assert isinstance(data.get(key), str) and data[key], (
            f"bench artifact missing provenance field {key!r}")
    assert isinstance(data.get("dirty"), bool), (
        "bench artifact missing provenance field 'dirty'")
    assert "T" in data["date"], "date must be ISO-8601 UTC"


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median-of-iters wall time in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float | None, derived: str) -> dict:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


def emit(rows: list[dict]) -> None:
    for r in rows:
        us = "" if r["us_per_call"] is None else f"{r['us_per_call']:.1f}"
        print(f"{r['name']},{us},{r['derived']}")


def save_artifact(name: str, data) -> str:
    os.makedirs("artifacts/bench", exist_ok=True)
    path = f"artifacts/bench/{name}.json"
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return path
