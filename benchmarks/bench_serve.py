"""Control-plane serving benchmark -> repo-root ``BENCH_serve.json``.

``BENCH_allocation.json`` pinned the raw per-period solve and
``BENCH_fleet.json`` the offline sweep throughput; this artifact measures
the *online* serving path (``launch.allocd`` over ``fl.control_plane``):
sustained decisions/sec and p50/p99 per-decision latency of the asyncio
daemon under a Poisson admission workload, at market capacities
N in {16, 64, 256}, with the warm-started dual carry against a cold solve
every period.  Warm vs cold is the serving-side payoff of the <= 6-trip
safeguarded-Newton path: at steady state the daemon re-clears an almost
unchanged market, exactly the regime warm-starting targets.

The artifact also carries the control plane's correctness anchor as a
``parity`` record: a daemon run under completion-based churn whose served
allocation stream must be **bitwise equal** to ``simulator.run_scan`` fed
the same admission trace (see fl/control_plane.py's differential contract),
and a stale-decision drill (an injected solver delay with a tight deadline)
proving the degraded path serves and counts ``stale_decisions`` instead of
stalling.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_serve [--tiny] [--out PATH]

``--tiny`` shrinks capacities/periods for the CI smoke step (same schema,
same validation path).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

SCHEMA = "bench_serve/v1"
DEFAULT_OUT = "BENCH_serve.json"
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _plan(tiny: bool) -> dict:
    if tiny:
        return {
            "capacities": [4, 8],
            "periods": 10, "warmup": 2,
            "rate_per_slot": 0.1,       # mean admissions/period = rate * N
            "k_max": 8,
            "parity": {"capacity": 8, "periods": 10, "rate": 0.4,
                       "rounds_required": 60, "k_max": 8},
        }
    return {
        "capacities": [16, 64, 256],
        "periods": 40, "warmup": 4,
        "rate_per_slot": 0.1,
        "k_max": 16,
        "parity": {"capacity": 16, "periods": 24, "rate": 0.5,
                   "rounds_required": 100, "k_max": 8},
    }


def _serving_row(capacity: int, warm: bool, plan: dict, seed: int = 0) -> dict:
    """Drive one daemon through a Poisson workload; time each decision."""
    import numpy as np

    from repro.fl.control_plane import ControlPlaneConfig
    from repro.launch import allocd

    cfg = ControlPlaneConfig(
        capacity=capacity, k_max=plan["k_max"], policy="coop",
        warm_start=warm, rounds_required=100_000, seed=seed,
    )
    daemon = allocd.AllocDaemon(cfg)
    workload = allocd.poisson_admissions(
        np.random.default_rng(seed), plan["rate_per_slot"] * capacity,
        plan["periods"], plan["k_max"])

    latencies: list[float] = []

    async def drive() -> None:
        for p in range(plan["periods"]):
            for req in workload.get(p, ()):
                daemon.submit(req)
            t0 = time.perf_counter()
            await daemon.step_period()
            if p >= plan["warmup"]:      # exclude compile periods
                latencies.append(time.perf_counter() - t0)

    asyncio.run(drive())
    lat = np.asarray(latencies)
    m = daemon.plane.metrics
    return {
        "capacity": capacity,
        "warm": warm,
        "periods": plan["periods"],
        "measured_decisions": int(lat.size),
        "decisions_per_sec": float(lat.size / lat.sum()),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "admitted": m["admitted"],
        "rejected": m["rejected"] + len(daemon.rejections),
        "stale_decisions": m["stale_decisions"],
    }


def _parity_record(plan: dict, seed: int = 0) -> dict:
    """Daemon vs run_scan differential on one completion-churn workload."""
    import numpy as np

    from repro.fl.control_plane import ControlPlaneConfig
    from repro.launch import allocd

    p = plan["parity"]
    cfg = ControlPlaneConfig(
        capacity=p["capacity"], k_max=p["k_max"], policy="coop",
        warm_start=True, rounds_required=p["rounds_required"], seed=seed,
    )
    daemon = allocd.AllocDaemon(cfg)
    workload = allocd.poisson_admissions(
        np.random.default_rng(seed), p["rate"], p["periods"], p["k_max"])
    asyncio.run(allocd._run_workload(daemon, workload, p["periods"]))
    assert daemon.plane.replayable, (
        "parity workload overflowed capacity into slot reuse; lower the rate")
    ref = daemon.plane.replay_reference()
    live = {k: np.stack([getattr(d, k) for d in daemon.plane.decisions])
            for k in ("b", "f", "active")}
    n = live["b"].shape[0]
    max_dev = max(
        float(np.max(np.abs(np.asarray(ref["history"][k][:n], np.float64)
                            - np.asarray(live[k], np.float64))))
        for k in ("b", "f"))
    return {
        "capacity": p["capacity"], "periods": n,
        "admitted": daemon.plane.metrics["admitted"],
        "retired": daemon.plane.metrics["retired"],
        "bitwise_equal": bool(
            all(np.array_equal(ref["history"][k][:n], live[k])
                for k in ("b", "f", "active"))),
        "max_dev": max_dev,
    }


def _stale_drill(plan: dict) -> dict:
    """Deadline-miss path: injected solver delay + tight timeout must yield
    counted stale decisions, then a committed fresh one."""
    from repro.fl.control_plane import ControlPlaneConfig
    from repro.launch import allocd

    cfg = ControlPlaneConfig(capacity=4, k_max=plan["k_max"], policy="coop",
                             rounds_required=1000)
    daemon = allocd.AllocDaemon(cfg)        # no deadline while compiling

    async def drive() -> list:
        daemon.submit(allocd.Admit("svc-0", 4))
        await daemon.step_period()          # compile + commit period 0
        daemon.solver_timeout_s = 0.05
        daemon._solver_delay_s = 0.5        # overrun the 50 ms deadline
        await daemon.step_period()          # -> stale
        daemon._solver_delay_s = 0.0
        daemon.solver_timeout_s = None
        await daemon.step_period()          # pending solve commits -> fresh
        await daemon.step_period()          # steady state again
        await daemon.close()
        return daemon.served

    served = asyncio.run(drive())
    return {
        "served": len(served),
        "stale_decisions": daemon.plane.metrics["stale_decisions"],
        "stale_flags": [bool(d.stale) for d in served],
        "fresh_decisions": len(daemon.plane.decisions),
    }


def run(tiny: bool = False) -> dict:
    from benchmarks import common

    plan = _plan(tiny)
    rows = [
        _serving_row(capacity, warm, plan)
        for capacity in plan["capacities"]
        for warm in (True, False)
    ]
    return {
        "schema": SCHEMA,
        "tiny": tiny,
        **common.provenance(),
        "plan": {k: v for k, v in plan.items() if k != "parity"},
        "rows": rows,
        "parity": _parity_record(plan),
        "stale_drill": _stale_drill(plan),
    }


def validate(data: dict) -> None:
    """Schema check used by CI and tests: provenance stamped, both warm
    branches measured at every capacity, the differential replay bitwise
    clean, and the deadline-miss drill counted -- never silent."""
    from benchmarks import common

    assert data["schema"] == SCHEMA
    common.validate_provenance(data)
    seen = {(row["capacity"], row["warm"]) for row in data["rows"]}
    capacities = {c for c, _ in seen}
    assert all((c, w) in seen for c in capacities for w in (True, False)), (
        "every capacity needs a warm AND a cold row")
    for row in data["rows"]:
        assert row["decisions_per_sec"] > 0, row
        assert 0 < row["p50_ms"] <= row["p99_ms"], row
        assert row["stale_decisions"] == 0, (
            "serving rows run without a deadline; stale decisions here mean "
            "the daemon miscounted")
    parity = data["parity"]
    assert parity["bitwise_equal"] is True, parity
    assert parity["max_dev"] == 0.0, parity
    assert parity["admitted"] > 0 and parity["retired"] > 0, (
        "parity run must exercise admissions AND completion-based departures")
    drill = data["stale_drill"]
    assert drill["stale_decisions"] >= 1, drill
    assert drill["stale_flags"].count(True) == drill["stale_decisions"], (
        "every stale decision must be flagged on the served stream")
    assert drill["fresh_decisions"] + drill["stale_decisions"] \
        == drill["served"], drill


def run_rows(tiny: bool = False) -> list[dict]:
    """benchmarks.run adapter: execute, write the artifact, emit CSV rows."""
    from benchmarks import common

    data = run(tiny=tiny)
    validate(data)
    if tiny:
        common.save_artifact("bench_serve_tiny", data)
    else:
        with open(os.path.join(_REPO_ROOT, DEFAULT_OUT), "w") as fp:
            json.dump(data, fp, indent=1, default=float)
            fp.write("\n")
    rows = []
    for row in data["rows"]:
        rows.append(common.row(
            f"serve/{'warm' if row['warm'] else 'cold'}_N{row['capacity']}",
            row["p50_ms"] * 1e3,
            f"dps={row['decisions_per_sec']:.1f} "
            f"p99_ms={row['p99_ms']:.2f}"))
    parity = data["parity"]
    rows.append(common.row(
        "serve/replay_parity", None,
        f"N={parity['capacity']} periods={parity['periods']} "
        f"bitwise={parity['bitwise_equal']} max_dev={parity['max_dev']:.1f}"))
    drill = data["stale_drill"]
    rows.append(common.row(
        "serve/stale_drill", None,
        f"stale={drill['stale_decisions']}/{drill['served']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds instead of minutes)")
    ap.add_argument("--out", default=os.path.join(_REPO_ROOT, DEFAULT_OUT),
                    help=f"output path (default: {DEFAULT_OUT} at repo root)")
    args = ap.parse_args()
    data = run(tiny=args.tiny)
    validate(data)
    with open(args.out, "w") as fp:
        json.dump(data, fp, indent=1, default=float)
        fp.write("\n")
    for row in data["rows"]:
        print(f"N={row['capacity']} {'warm' if row['warm'] else 'cold'}: "
              f"{row['decisions_per_sec']:.1f} decisions/s "
              f"p50={row['p50_ms']:.2f}ms p99={row['p99_ms']:.2f}ms")
    parity = data["parity"]
    print(f"replay parity: bitwise={parity['bitwise_equal']} "
          f"max_dev={parity['max_dev']} "
          f"(admitted={parity['admitted']} retired={parity['retired']})")
    print(f"stale drill: {data['stale_drill']['stale_decisions']} counted")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
