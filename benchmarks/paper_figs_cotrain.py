"""Accuracy-vs-wallclock co-training comparison -> repo-root
``BENCH_cotrain.json``.

The paper's bottom line is that bandwidth allocation changes *learning*
outcomes: its evaluation reads as FL accuracy against wall-clock time per
allocation regime, not just round lengths.  This benchmark reproduces that
comparison with the training-in-the-loop engine (``fl.cotrain``): every
policy co-trains real FedAvg models paced by its own allocation stream
(identical arrivals/channels across policies, Monte-Carlo over seeds via the
sharded ``run_cotrain_fleet``), and the artifact records the mean
accuracy-vs-time curve with across-seed bands, the accuracy-time AUC, time
to a target accuracy, and the realized service durations.

The configuration is chosen so the comparison is *allocation-bound and
unclipped*: client compute (``t_local`` 0.15-0.3 s) bounds the FL frequency
at ~3.3 rounds/s, so the per-period round grant can never exceed the static
training cap (``clipped_rounds == 0`` is asserted for full runs -- a clipped
sweep silently equalizes the policies), while a scarce 2 MHz band keeps the
pace bandwidth-bound so the allocator actually decides the curves.

Ordering contract (``ordering`` block, asserted by ``validate`` on full
runs, mirroring the paper):

* cooperative DISBA dominates the fairness-adjusted auction's accuracy-time
  curve (AUC) at comparable durations (the paper's coop-over-auction claim);
* both market mechanisms finish services faster than the equal-share
  benchmarks (Fig. 12's duration ordering: coop/selfish < es/pp).

Schema v2 adds the **compression frontier** (``frontier`` block): the same
co-trained comparison swept over uplink compression levels (dense / topk /
int8 / topk_int8 / the adaptive controller) x allocation policies on an
uplink-dominated, bandwidth-starved network.  Each cell records the
accuracy-time AUC, time to the target accuracy, and the realized s^UT
multiplier -- the accuracy-vs-allocated-wallclock frontier the closed
compression->allocation loop buys.  Two standing assertions: the dense
("none") cells' duration streams are *bitwise* the duration engine's
(``none_bitwise``, checked even on tiny runs -- compression support must
not perturb the uncompressed path), and on full runs topk at the benched
``k_frac`` dominates dense on time-to-target under tight bandwidth
(compressing 13x buys more wall-clock than the sparser updates cost).

``--tiny`` is the CI smoke: a smoke-scaled ``gemma3-1b`` zoo transformer
(task="zoo"), 2 services, 3 periods -- same schema, same validation path
minus the ordering/clipping asserts (a 3-period smoke proves the plumbing,
not the science).  The tiny frontier covers topk + int8 on 2 services and
still pins ``none_bitwise``.

Usage:
  PYTHONPATH=src python -m benchmarks.paper_figs_cotrain [--tiny] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os

import numpy as np

from repro.core import network
from repro.fl import cotrain, simulator

SCHEMA = "bench_cotrain/v2"
DEFAULT_OUT = "BENCH_cotrain.json"
ACC_TARGET = 0.55
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(tiny: bool):
    """(net, sim-config kwargs, train spec, seeds, policies)."""
    if tiny:
        # CI smoke: tiny zoo transformer, 2 services, 3 periods.
        net = network.NetworkConfig(mean_clients=3.0, var_clients=1.0)
        cfg = dict(n_services_total=2, rounds_required=4, p_arrive=1.0,
                   max_periods=3, k_max=5, mean_clients=3.0, var_clients=1.0)
        train = cotrain.TrainSpec(task="zoo", arch="gemma3-1b", seq_len=8,
                                  batch_size=2, eval_batch=2, rounds_cap=2,
                                  client_lr=0.1)
        return net, cfg, train, [0, 1], ("coop", "selfish", "es")
    net = network.NetworkConfig(total_bandwidth_mhz=2.0, period_s=4.0,
                                mean_clients=12.0, var_clients=12.0,
                                t_local_lo=0.15, t_local_hi=0.3)
    cfg = dict(n_services_total=5, rounds_required=48, p_arrive=3.0,
               max_periods=64, k_max=32, mean_clients=12.0, var_clients=12.0)
    train = cotrain.TrainSpec(vocab=32, seq_len=8, batch_size=4,
                              eval_batch=32, rounds_cap=14, client_lr=0.5)
    return net, cfg, train, list(range(8)), ("coop", "selfish", "es", "pp")


def _frontier_setup(tiny: bool):
    """(net, sim kwargs, base train spec, seeds, policies, levels).

    The frontier network is uplink-dominated (UT powers an order below DT,
    so s^UT/r^UT carries most of alpha) and bandwidth-starved -- the regime
    where compressing the upload actually buys wall-clock.  Levels are
    (name, TrainSpec overrides); every lossy level runs with error feedback
    on, matching how the controller is meant to be deployed."""
    topk = dict(compression="topk", topk_frac=0.05, index_bits=16,
                error_feedback=True)
    if tiny:
        net = network.NetworkConfig(mean_clients=3.0, var_clients=1.0,
                                    p_ul_lo=0.01, p_ul_hi=0.03)
        cfg = dict(n_services_total=2, rounds_required=4, p_arrive=1.0,
                   max_periods=3, k_max=5, mean_clients=3.0, var_clients=1.0)
        train = cotrain.TrainSpec(vocab=16, seq_len=6, batch_size=2,
                                  eval_batch=8, rounds_cap=2)
        levels = (("none", {}), ("topk", topk),
                  ("int8", dict(compression="int8", error_feedback=True)))
        return net, cfg, train, [0, 1], ("coop",), levels
    net = network.NetworkConfig(total_bandwidth_mhz=1.0, period_s=4.0,
                                mean_clients=10.0, var_clients=6.0,
                                t_local_lo=0.05, t_local_hi=0.1,
                                p_ul_lo=0.01, p_ul_hi=0.03)
    cfg = dict(n_services_total=4, rounds_required=40, p_arrive=3.0,
               max_periods=56, k_max=16, mean_clients=10.0, var_clients=6.0)
    train = cotrain.TrainSpec(vocab=32, seq_len=8, batch_size=4,
                              eval_batch=32, rounds_cap=24, client_lr=0.5)
    levels = (
        ("none", {}),
        ("topk", topk),
        ("int8", dict(compression="int8", error_feedback=True)),
        ("topk_int8", dict(compression="topk_int8", topk_frac=0.05,
                           index_bits=16, error_feedback=True)),
        ("adaptive", dict(**topk, comp_policy="adaptive",
                          comp_threshold=0.75)),
    )
    return net, cfg, train, [0, 1, 2, 3], ("coop", "es"), levels


def _run_frontier(tiny: bool) -> dict:
    """Compression level x policy sweep -> the ``frontier`` block."""
    net, cfg_kw, base_train, seeds, policies, levels = _frontier_setup(tiny)
    block = {
        "seeds": seeds,
        "sim": {**cfg_kw},
        "net": {"total_bandwidth_mhz": net.total_bandwidth_mhz,
                "period_s": net.period_s, "p_ul_lo": net.p_ul_lo,
                "p_ul_hi": net.p_ul_hi, "t_local_lo": net.t_local_lo,
                "t_local_hi": net.t_local_hi},
        "levels": {name: dict(kw) for name, kw in levels},
        "cells": {},
        "none_bitwise": True,
    }
    for pol in policies:
        cfg = simulator.SimConfig(policy=pol, **cfg_kw)
        ref_durations = np.asarray(
            simulator.run_batch(cfg, seeds, net)["durations"])
        block["cells"][pol] = {}
        for name, kw in levels:
            train = dataclasses.replace(base_train, **kw)
            out = cotrain.run_cotrain_fleet(cfg, train, seeds, net,
                                            chunk_size=4)
            acc = np.asarray(out["history"]["acc"])        # (S, T, N)
            time_s = np.asarray(out["time_s"])
            per_seed = acc.mean(axis=2)
            tta = _time_to_acc(acc, time_s, ACC_TARGET)
            if name == "none":
                block["none_bitwise"] &= bool(np.array_equal(
                    np.asarray(out["durations"]), ref_durations))
            block["cells"][pol][name] = {
                "auc": float(per_seed.mean()),
                "time_to_acc_mean": float(tta.mean()),
                "acc_mean": per_seed.mean(axis=0).tolist(),
                "time_s": time_s.tolist(),
                "ul_mult_mean": float(
                    np.mean(np.asarray(out["history"]["ul_mult"]))),
                "avg_duration_periods": float(np.mean(out["avg_duration"])),
                "clipped_rounds": int(np.sum(out["clipped_rounds"])),
                "finished": bool(np.all(out["finished"])),
            }
    block["dominance"] = {
        pol: {
            "tta_none": cells["none"]["time_to_acc_mean"],
            "tta_topk": cells["topk"]["time_to_acc_mean"],
            "topk_beats_dense": bool(cells["topk"]["time_to_acc_mean"]
                                     < cells["none"]["time_to_acc_mean"]),
        }
        for pol, cells in block["cells"].items()
    }
    return block


def _time_to_acc(acc: np.ndarray, time_s: np.ndarray, target: float):
    """(S, N) first-crossing times, censored at the horizon end."""
    s, t, n = acc.shape
    out = np.full((s, n), time_s[-1])
    for i in range(s):
        for j in range(n):
            hit = np.where(acc[i, :, j] >= target)[0]
            if len(hit):
                out[i, j] = time_s[hit[0]]
    return out


def run(tiny: bool = False) -> dict:
    from benchmarks import common

    net, cfg_kw, train, seeds, policies = _setup(tiny)
    data = {
        "schema": SCHEMA,
        "tiny": tiny,
        **common.provenance(),
        "acc_target": ACC_TARGET,
        "seeds": seeds,
        "sim": {**cfg_kw},
        "net": {"total_bandwidth_mhz": net.total_bandwidth_mhz,
                "period_s": net.period_s, "t_local_lo": net.t_local_lo,
                "t_local_hi": net.t_local_hi},
        # strict-JSON spec record: a float("inf") deadline_x would emit the
        # non-RFC-8259 token Infinity, so non-finite floats go as strings
        "train": {k: (str(v) if isinstance(v, float) and not math.isfinite(v)
                      else v)
                  for k, v in dataclasses.asdict(train).items()},
        "policies": {},
    }
    for pol in policies:
        cfg = simulator.SimConfig(policy=pol, **cfg_kw)
        out = cotrain.run_cotrain_fleet(cfg, train, seeds, net, chunk_size=4)
        acc = np.asarray(out["history"]["acc"])        # (S, T, N)
        loss = np.asarray(out["history"]["loss"])
        time_s = np.asarray(out["time_s"])
        per_seed = acc.mean(axis=2)                    # (S, T) service means
        tta = _time_to_acc(acc, time_s, ACC_TARGET)
        data["policies"][pol] = {
            "time_s": time_s.tolist(),
            "acc_mean": per_seed.mean(axis=0).tolist(),
            "acc_band_lo": per_seed.min(axis=0).tolist(),
            "acc_band_hi": per_seed.max(axis=0).tolist(),
            "loss_mean": loss.mean(axis=(0, 2)).tolist(),
            "auc": float(per_seed.mean()),
            "time_to_acc_mean": float(tta.mean()),
            "avg_duration_periods": float(np.mean(out["avg_duration"])),
            "durations": np.asarray(out["durations"]).astype(int).tolist(),
            "finished": bool(np.all(out["finished"])),
            "clipped_rounds": int(np.sum(out["clipped_rounds"])),
            "fleet": out["fleet"],
        }
    auc = {p: data["policies"][p]["auc"] for p in policies}
    dur = {p: data["policies"][p]["avg_duration_periods"] for p in policies}
    eq_share = [p for p in ("es", "pp") if p in auc]
    market = [p for p in ("coop", "selfish") if p in auc]
    data["ordering"] = {
        "auc": auc,
        "avg_duration_periods": dur,
        # coop's curve dominates the auction's at comparable durations
        "coop_auction_consistent": bool(
            auc.get("coop", 0.0) >= auc.get("selfish", 0.0) - 1e-3
            and dur.get("coop", 0.0) <= dur.get("selfish", 0.0) + 1.0),
        # the market mechanisms retire services faster than equal shares
        "equal_share_slower": bool(all(
            dur[e] >= dur[m] - 0.25 for e in eq_share for m in market)),
    }
    data["frontier"] = _run_frontier(tiny)
    return data


def validate(data: dict) -> None:
    """Schema check used by CI and tests: provenance stamped, curves
    well-formed, caps accounted for, and (full runs) the paper's
    coop/auction and equal-share orderings hold."""
    from benchmarks import common

    assert data["schema"] == SCHEMA
    common.validate_provenance(data)
    assert isinstance(data["tiny"], bool)
    pols = data["policies"]
    assert len(pols) >= 3, f"need >= 3 policies, got {sorted(pols)}"
    assert {"coop", "selfish"} <= set(pols), sorted(pols)
    for name, rec in pols.items():
        t = rec["time_s"]
        assert len(t) > 0 and all(b >= a for a, b in zip(t, t[1:])), name
        for key in ("acc_mean", "acc_band_lo", "acc_band_hi", "loss_mean"):
            assert len(rec[key]) == len(t), (name, key)
        assert all(0.0 <= a <= 1.0 for a in rec["acc_mean"]), name
        assert all(lo <= hi for lo, hi in zip(rec["acc_band_lo"],
                                             rec["acc_band_hi"])), name
        assert rec["clipped_rounds"] >= 0, name   # counted, never silent
        assert rec["fleet"]["n_devices"] >= 1, name
    order = data["ordering"]
    assert set(order["auc"]) == set(pols)

    frontier = data["frontier"]
    # the dense cells must replay the duration engine bitwise -- ALWAYS,
    # tiny included: compression support must not perturb the "none" path
    assert frontier["none_bitwise"], "dense frontier cells diverged from " \
        "the duration engine"
    assert set(frontier["levels"]) >= {"none", "topk", "int8"}
    for pol, cells in frontier["cells"].items():
        assert set(cells) == set(frontier["levels"]), (pol, sorted(cells))
        for name, cell in cells.items():
            t = cell["time_s"]
            assert len(cell["acc_mean"]) == len(t) > 0, (pol, name)
            assert all(0.0 <= a <= 1.0 for a in cell["acc_mean"]), (pol, name)
            assert 0.0 < cell["ul_mult_mean"] <= 1.0, (pol, name)
            assert cell["clipped_rounds"] >= 0, (pol, name)
        # dense prices dense; every *static* lossy level prices below dense.
        # The adaptive controller may legitimately stay at 1.0: under a
        # fair allocator (es) no share ever drops below threshold x fair,
        # so never compressing IS the correct control decision.
        assert cells["none"]["ul_mult_mean"] == 1.0, pol
        for name in set(cells) - {"none"}:
            if frontier["levels"][name].get("comp_policy") == "adaptive":
                continue
            assert cells[name]["ul_mult_mean"] < 1.0, (pol, name)

    if not data["tiny"]:
        for name, rec in pols.items():
            assert rec["finished"], f"{name}: unfinished episodes"
            assert rec["clipped_rounds"] == 0, (
                f"{name}: clipped rounds equalize the comparison")
        assert order["coop_auction_consistent"], order
        assert order["equal_share_slower"], order
        # the frontier's headline: under tight, uplink-dominated bandwidth
        # topk at the benched k_frac reaches the target accuracy FASTER
        # than dense, for every benched policy
        for pol, dom in frontier["dominance"].items():
            assert dom["topk_beats_dense"], (pol, dom)
        for pol, cells in frontier["cells"].items():
            for name, cell in cells.items():
                assert cell["clipped_rounds"] == 0, (pol, name)


def run_rows(tiny: bool = False) -> list[dict]:
    """benchmarks.run adapter: execute the study, write the artifact, and
    return ``name,us_per_call,derived`` rows.  Tiny runs land in
    artifacts/bench/; full runs refresh the repo-root trajectory."""
    from benchmarks import common

    data = run(tiny=tiny)
    validate(data)
    if tiny:
        common.save_artifact("bench_cotrain_tiny", data)
    else:
        with open(os.path.join(_REPO_ROOT, DEFAULT_OUT), "w") as fp:
            json.dump(data, fp, indent=1, default=float)
            fp.write("\n")
    rows = []
    for pol, rec in data["policies"].items():
        rows.append(common.row(
            f"cotrain/{pol}", None,
            f"auc={rec['auc']:.4f} tta{data['acc_target']}="
            f"{rec['time_to_acc_mean']:.1f}s "
            f"dur={rec['avg_duration_periods']:.2f}"))
    order = data["ordering"]
    rows.append(common.row(
        "cotrain/ordering", None,
        f"coop_auction={order['coop_auction_consistent']} "
        f"equal_share_slower={order['equal_share_slower']}"))
    frontier = data["frontier"]
    for pol, cells in frontier["cells"].items():
        for name, cell in cells.items():
            rows.append(common.row(
                f"cotrain/frontier/{pol}/{name}", None,
                f"auc={cell['auc']:.4f} "
                f"tta{data['acc_target']}={cell['time_to_acc_mean']:.1f}s "
                f"ul_mult={cell['ul_mult_mean']:.3f}"))
    rows.append(common.row(
        "cotrain/frontier/none_bitwise", None,
        f"ok={frontier['none_bitwise']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (zoo transformer, 2 services, "
                         "3 periods)")
    ap.add_argument("--out", default=os.path.join(_REPO_ROOT, DEFAULT_OUT),
                    help=f"output path (default: {DEFAULT_OUT} at repo root)")
    args = ap.parse_args()
    data = run(tiny=args.tiny)
    validate(data)
    with open(args.out, "w") as fp:
        json.dump(data, fp, indent=1, default=float)
        fp.write("\n")
    for pol, rec in data["policies"].items():
        print(f"{pol}: auc={rec['auc']:.4f} "
              f"tta{data['acc_target']}={rec['time_to_acc_mean']:.1f}s "
              f"avg_duration={rec['avg_duration_periods']:.2f} periods "
              f"clipped={rec['clipped_rounds']}")
    print(f"ordering: {data['ordering']}")
    for pol, cells in data["frontier"]["cells"].items():
        for name, cell in cells.items():
            print(f"frontier {pol}/{name}: auc={cell['auc']:.4f} "
                  f"tta={cell['time_to_acc_mean']:.1f}s "
                  f"ul_mult={cell['ul_mult_mean']:.3f} "
                  f"clipped={cell['clipped_rounds']}")
    print(f"none_bitwise: {data['frontier']['none_bitwise']} "
          f"dominance: {data['frontier']['dominance']}")


if __name__ == "__main__":
    main()
