"""Accuracy-vs-wallclock co-training comparison -> repo-root
``BENCH_cotrain.json``.

The paper's bottom line is that bandwidth allocation changes *learning*
outcomes: its evaluation reads as FL accuracy against wall-clock time per
allocation regime, not just round lengths.  This benchmark reproduces that
comparison with the training-in-the-loop engine (``fl.cotrain``): every
policy co-trains real FedAvg models paced by its own allocation stream
(identical arrivals/channels across policies, Monte-Carlo over seeds via the
sharded ``run_cotrain_fleet``), and the artifact records the mean
accuracy-vs-time curve with across-seed bands, the accuracy-time AUC, time
to a target accuracy, and the realized service durations.

The configuration is chosen so the comparison is *allocation-bound and
unclipped*: client compute (``t_local`` 0.15-0.3 s) bounds the FL frequency
at ~3.3 rounds/s, so the per-period round grant can never exceed the static
training cap (``clipped_rounds == 0`` is asserted for full runs -- a clipped
sweep silently equalizes the policies), while a scarce 2 MHz band keeps the
pace bandwidth-bound so the allocator actually decides the curves.

Ordering contract (``ordering`` block, asserted by ``validate`` on full
runs, mirroring the paper):

* cooperative DISBA dominates the fairness-adjusted auction's accuracy-time
  curve (AUC) at comparable durations (the paper's coop-over-auction claim);
* both market mechanisms finish services faster than the equal-share
  benchmarks (Fig. 12's duration ordering: coop/selfish < es/pp).

``--tiny`` is the CI smoke: a smoke-scaled ``gemma3-1b`` zoo transformer
(task="zoo"), 2 services, 3 periods -- same schema, same validation path
minus the ordering/clipping asserts (a 3-period smoke proves the plumbing,
not the science).

Usage:
  PYTHONPATH=src python -m benchmarks.paper_figs_cotrain [--tiny] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os

import numpy as np

from repro.core import network
from repro.fl import cotrain, simulator

SCHEMA = "bench_cotrain/v1"
DEFAULT_OUT = "BENCH_cotrain.json"
ACC_TARGET = 0.55
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(tiny: bool):
    """(net, sim-config kwargs, train spec, seeds, policies)."""
    if tiny:
        # CI smoke: tiny zoo transformer, 2 services, 3 periods.
        net = network.NetworkConfig(mean_clients=3.0, var_clients=1.0)
        cfg = dict(n_services_total=2, rounds_required=4, p_arrive=1.0,
                   max_periods=3, k_max=5, mean_clients=3.0, var_clients=1.0)
        train = cotrain.TrainSpec(task="zoo", arch="gemma3-1b", seq_len=8,
                                  batch_size=2, eval_batch=2, rounds_cap=2,
                                  client_lr=0.1)
        return net, cfg, train, [0, 1], ("coop", "selfish", "es")
    net = network.NetworkConfig(total_bandwidth_mhz=2.0, period_s=4.0,
                                mean_clients=12.0, var_clients=12.0,
                                t_local_lo=0.15, t_local_hi=0.3)
    cfg = dict(n_services_total=5, rounds_required=48, p_arrive=3.0,
               max_periods=64, k_max=32, mean_clients=12.0, var_clients=12.0)
    train = cotrain.TrainSpec(vocab=32, seq_len=8, batch_size=4,
                              eval_batch=32, rounds_cap=14, client_lr=0.5)
    return net, cfg, train, list(range(8)), ("coop", "selfish", "es", "pp")


def _time_to_acc(acc: np.ndarray, time_s: np.ndarray, target: float):
    """(S, N) first-crossing times, censored at the horizon end."""
    s, t, n = acc.shape
    out = np.full((s, n), time_s[-1])
    for i in range(s):
        for j in range(n):
            hit = np.where(acc[i, :, j] >= target)[0]
            if len(hit):
                out[i, j] = time_s[hit[0]]
    return out


def run(tiny: bool = False) -> dict:
    from benchmarks import common

    net, cfg_kw, train, seeds, policies = _setup(tiny)
    data = {
        "schema": SCHEMA,
        "tiny": tiny,
        **common.provenance(),
        "acc_target": ACC_TARGET,
        "seeds": seeds,
        "sim": {**cfg_kw},
        "net": {"total_bandwidth_mhz": net.total_bandwidth_mhz,
                "period_s": net.period_s, "t_local_lo": net.t_local_lo,
                "t_local_hi": net.t_local_hi},
        # strict-JSON spec record: a float("inf") deadline_x would emit the
        # non-RFC-8259 token Infinity, so non-finite floats go as strings
        "train": {k: (str(v) if isinstance(v, float) and not math.isfinite(v)
                      else v)
                  for k, v in dataclasses.asdict(train).items()},
        "policies": {},
    }
    for pol in policies:
        cfg = simulator.SimConfig(policy=pol, **cfg_kw)
        out = cotrain.run_cotrain_fleet(cfg, train, seeds, net, chunk_size=4)
        acc = np.asarray(out["history"]["acc"])        # (S, T, N)
        loss = np.asarray(out["history"]["loss"])
        time_s = np.asarray(out["time_s"])
        per_seed = acc.mean(axis=2)                    # (S, T) service means
        tta = _time_to_acc(acc, time_s, ACC_TARGET)
        data["policies"][pol] = {
            "time_s": time_s.tolist(),
            "acc_mean": per_seed.mean(axis=0).tolist(),
            "acc_band_lo": per_seed.min(axis=0).tolist(),
            "acc_band_hi": per_seed.max(axis=0).tolist(),
            "loss_mean": loss.mean(axis=(0, 2)).tolist(),
            "auc": float(per_seed.mean()),
            "time_to_acc_mean": float(tta.mean()),
            "avg_duration_periods": float(np.mean(out["avg_duration"])),
            "durations": np.asarray(out["durations"]).astype(int).tolist(),
            "finished": bool(np.all(out["finished"])),
            "clipped_rounds": int(np.sum(out["clipped_rounds"])),
            "fleet": out["fleet"],
        }
    auc = {p: data["policies"][p]["auc"] for p in policies}
    dur = {p: data["policies"][p]["avg_duration_periods"] for p in policies}
    eq_share = [p for p in ("es", "pp") if p in auc]
    market = [p for p in ("coop", "selfish") if p in auc]
    data["ordering"] = {
        "auc": auc,
        "avg_duration_periods": dur,
        # coop's curve dominates the auction's at comparable durations
        "coop_auction_consistent": bool(
            auc.get("coop", 0.0) >= auc.get("selfish", 0.0) - 1e-3
            and dur.get("coop", 0.0) <= dur.get("selfish", 0.0) + 1.0),
        # the market mechanisms retire services faster than equal shares
        "equal_share_slower": bool(all(
            dur[e] >= dur[m] - 0.25 for e in eq_share for m in market)),
    }
    return data


def validate(data: dict) -> None:
    """Schema check used by CI and tests: provenance stamped, curves
    well-formed, caps accounted for, and (full runs) the paper's
    coop/auction and equal-share orderings hold."""
    from benchmarks import common

    assert data["schema"] == SCHEMA
    common.validate_provenance(data)
    assert isinstance(data["tiny"], bool)
    pols = data["policies"]
    assert len(pols) >= 3, f"need >= 3 policies, got {sorted(pols)}"
    assert {"coop", "selfish"} <= set(pols), sorted(pols)
    for name, rec in pols.items():
        t = rec["time_s"]
        assert len(t) > 0 and all(b >= a for a, b in zip(t, t[1:])), name
        for key in ("acc_mean", "acc_band_lo", "acc_band_hi", "loss_mean"):
            assert len(rec[key]) == len(t), (name, key)
        assert all(0.0 <= a <= 1.0 for a in rec["acc_mean"]), name
        assert all(lo <= hi for lo, hi in zip(rec["acc_band_lo"],
                                             rec["acc_band_hi"])), name
        assert rec["clipped_rounds"] >= 0, name   # counted, never silent
        assert rec["fleet"]["n_devices"] >= 1, name
    order = data["ordering"]
    assert set(order["auc"]) == set(pols)
    if not data["tiny"]:
        for name, rec in pols.items():
            assert rec["finished"], f"{name}: unfinished episodes"
            assert rec["clipped_rounds"] == 0, (
                f"{name}: clipped rounds equalize the comparison")
        assert order["coop_auction_consistent"], order
        assert order["equal_share_slower"], order


def run_rows(tiny: bool = False) -> list[dict]:
    """benchmarks.run adapter: execute the study, write the artifact, and
    return ``name,us_per_call,derived`` rows.  Tiny runs land in
    artifacts/bench/; full runs refresh the repo-root trajectory."""
    from benchmarks import common

    data = run(tiny=tiny)
    validate(data)
    if tiny:
        common.save_artifact("bench_cotrain_tiny", data)
    else:
        with open(os.path.join(_REPO_ROOT, DEFAULT_OUT), "w") as fp:
            json.dump(data, fp, indent=1, default=float)
            fp.write("\n")
    rows = []
    for pol, rec in data["policies"].items():
        rows.append(common.row(
            f"cotrain/{pol}", None,
            f"auc={rec['auc']:.4f} tta{data['acc_target']}="
            f"{rec['time_to_acc_mean']:.1f}s "
            f"dur={rec['avg_duration_periods']:.2f}"))
    order = data["ordering"]
    rows.append(common.row(
        "cotrain/ordering", None,
        f"coop_auction={order['coop_auction_consistent']} "
        f"equal_share_slower={order['equal_share_slower']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (zoo transformer, 2 services, "
                         "3 periods)")
    ap.add_argument("--out", default=os.path.join(_REPO_ROOT, DEFAULT_OUT),
                    help=f"output path (default: {DEFAULT_OUT} at repo root)")
    args = ap.parse_args()
    data = run(tiny=args.tiny)
    validate(data)
    with open(args.out, "w") as fp:
        json.dump(data, fp, indent=1, default=float)
        fp.write("\n")
    for pol, rec in data["policies"].items():
        print(f"{pol}: auc={rec['auc']:.4f} "
              f"tta{data['acc_target']}={rec['time_to_acc_mean']:.1f}s "
              f"avg_duration={rec['avg_duration_periods']:.2f} periods "
              f"clipped={rec['clipped_rounds']}")
    print(f"ordering: {data['ordering']}")


if __name__ == "__main__":
    main()
