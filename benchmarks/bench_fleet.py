"""Fleet-sweep throughput benchmark -> repo-root ``BENCH_fleet.json``.

PR 3's ``BENCH_allocation.json`` pinned the per-period solve; this artifact
adds the *sweep throughput* axis: episodes/sec and periods/sec of the
device-sharded, chunked ``fl.simulator.run_fleet`` engine against the flat
single-device ``run_batch`` vmap, scaled over forced-host device counts
(1 -> 8) and fleet sizes (64 -> 4096).  Two effects compose:

* **chunking** -- ``run_batch`` at fleet 1024 drags a multi-MB working set
  through every bisection trip of every period; ``run_fleet``'s O(chunk)
  inner batch stays cache-resident (measurable even on ONE device);
* **sharding** -- the seed axis splits across devices, so forced-host CPU
  devices (or real accelerators) add near-linear throughput on top.

Every row is measured in a fresh worker subprocess so each device count gets
its own ``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax
initializes (the flag is locked in at first device query).  The 8-device
worker also checks per-seed *bitwise* parity of ``run_fleet`` against
``run_batch``, records the max deviation (0.0 by construction), and runs
the headline comparison as an interleaved A/B -- alternating run_batch /
run_fleet calls, median over ``ab_reps`` -- because the DRAM-bound flat
vmap's wall time swings with host memory-bandwidth noise while the
cache-resident fleet's does not; each worker's ru_maxrss lands in the
artifact as the peak-memory proxy.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_fleet [--tiny] [--out PATH]

``--tiny`` shrinks fleets/episodes for the CI smoke step (same schema, same
validation path, seconds instead of minutes).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

SCHEMA = "bench_fleet/v1"
DEFAULT_OUT = "BENCH_fleet.json"
DEVICE_COUNTS = (1, 2, 4, 8)
REFERENCE_DEVICES = 8        # the acceptance point: 8 forced-host devices
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sim_config(tiny: bool) -> dict:
    """Episode config (SimConfig kwargs): aggregate-only coop sweeps -- the
    paper's §VI.D Monte-Carlo workload in miniature."""
    if tiny:
        return dict(policy="coop", n_services_total=4, rounds_required=2000,
                    p_arrive=2.0, mean_clients=8.0, var_clients=4.0,
                    max_periods=8, collect_history=False)
    # 32 service slots x ~70-client pad: at fleet 1024 the flat vmap drags
    # (1024, 32, 70) f32 arrays (~9 MB each, beyond this host's last-level
    # cache) through every bisection trip of every period -- DRAM-bandwidth
    # bound -- while run_fleet's 16-episode chunks (~290 KB per array) stay
    # cache-resident.
    return dict(policy="coop", n_services_total=32, rounds_required=2000,
                p_arrive=2.0, mean_clients=50.0, max_periods=6,
                collect_history=False)


def _plan(tiny: bool) -> dict:
    """What each worker measures (fleet sizes per device count)."""
    if tiny:
        return {
            "batch_fleets": [16, 64],       # 1-device run_batch baseline
            "scaling_fleet": 64,            # device-scaling point
            "fleet_fleets": [16, 64],       # fleet-size sweep at 8 devices
            "parity_fleet": 64,             # acceptance point: A/B + parity
            "device_counts": [1, REFERENCE_DEVICES],
            "reps": 1,
            "ab_reps": 2,
            "chunk_size": None,             # FLEET_CHUNK default
        }
    return {
        "batch_fleets": [64, 256, 1024],
        "scaling_fleet": 256,
        "fleet_fleets": [64, 256, 1024, 4096],
        "parity_fleet": 1024,
        "device_counts": list(DEVICE_COUNTS),
        "reps": 2,
        "ab_reps": 5,
        # Cache-tuned for the full config: 16 episodes x (32, 70) f32 keeps
        # the solver working set under the last-level cache.
        "chunk_size": 16,
    }


# ---------------------------------------------------------------------------
# Worker: runs under a fixed forced-host device count, one subprocess each.
# ---------------------------------------------------------------------------

def _time_call(fn, reps: int, warm: bool = True) -> float:
    """Best-of-reps wall seconds, after one untimed warmup/compile call."""
    if warm:
        fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _worker(devices: int, tiny: bool, out_path: str) -> None:
    # Append to (not clobber) any operator-set XLA_FLAGS, replacing only a
    # pre-existing forced device count with this worker's.
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    os.environ["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={devices}"])
    import resource

    import numpy as np

    from repro.fl import simulator
    from repro.launch.mesh import make_fleet_mesh

    cfg = simulator.SimConfig(**_sim_config(tiny))
    plan = _plan(tiny)
    reps = plan["reps"]
    chunk_size = plan["chunk_size"]
    periods = cfg.max_periods
    # The exact mesh-construction path run_fleet defaults to.
    mesh = make_fleet_mesh(devices)

    def fleet_row(fleet: int) -> dict:
        seeds = list(range(fleet))
        meta = simulator.run_fleet(cfg, seeds, mesh=mesh,
                                   chunk_size=chunk_size)["fleet"]  # warmup
        secs = _time_call(
            lambda: simulator.run_fleet(cfg, seeds, mesh=mesh,
                                        chunk_size=chunk_size),
            reps, warm=False)
        return {
            "engine": "run_fleet", "devices": devices, "fleet": fleet,
            "chunk": meta["chunk"], "n_chunks": meta["n_chunks"],
            "padded_to": meta["padded_to"],
            "seconds": secs,
            "episodes_per_sec": fleet / secs,
            "periods_per_sec": fleet * periods / secs,
        }

    rows = []
    if devices == 1:
        for fleet in plan["batch_fleets"]:
            seeds = list(range(fleet))
            secs = _time_call(lambda: simulator.run_batch(cfg, seeds), reps)
            rows.append({
                "engine": "run_batch", "devices": 1, "fleet": fleet,
                "seconds": secs,
                "episodes_per_sec": fleet / secs,
                "periods_per_sec": fleet * periods / secs,
            })
    rows.append(fleet_row(plan["scaling_fleet"]))
    parity = ab = None
    if devices == REFERENCE_DEVICES:
        rows.extend(fleet_row(f) for f in plan["fleet_fleets"]
                    if f != plan["scaling_fleet"])
        # Bitwise parity at the acceptance point: every per-seed output of
        # the sharded, chunked sweep must equal the flat vmap exactly.
        seeds = list(range(plan["parity_fleet"]))
        fleet_out = simulator.run_fleet(cfg, seeds, mesh=mesh,
                                        chunk_size=chunk_size)
        batch_out = simulator.run_batch(cfg, seeds)
        max_dev = max(
            float(np.max(np.abs(np.asarray(fleet_out[k], np.float64)
                                - np.asarray(batch_out[k], np.float64))))
            for k in ("durations", "periods")
        )
        max_dev = max(max_dev, *(
            float(np.max(np.abs(fleet_out["totals"][k]
                                - batch_out["totals"][k])))
            for k in fleet_out["totals"]))
        parity = {
            "fleet": plan["parity_fleet"], "devices": devices,
            "max_dev": max_dev,
            "durations_equal": bool(
                np.array_equal(fleet_out["durations"],
                               batch_out["durations"])),
        }
        # Interleaved A/B at the acceptance point, medians over ab_reps:
        # the flat vmap is DRAM-bandwidth bound and so hostage to host
        # noise (2x swings between consecutive runs measured), while the
        # cache-resident fleet is stable -- alternating the two engines
        # rep-by-rep exposes both to the same noise windows, and the
        # median filters the outliers a best-of-N would cherry-pick.
        batch_s, fleet_s = [], []
        for _ in range(plan["ab_reps"]):
            batch_s.append(_time_call(
                lambda: simulator.run_batch(cfg, seeds), 1, warm=False))
            fleet_s.append(_time_call(
                lambda: simulator.run_fleet(cfg, seeds, mesh=mesh,
                                            chunk_size=chunk_size), 1,
                warm=False))
        fleet_n = plan["parity_fleet"]
        ab = {
            "fleet": fleet_n,
            "protocol": f"interleaved_median{plan['ab_reps']}",
            "run_batch_eps": fleet_n / float(np.median(batch_s)),
            "run_fleet_eps": fleet_n / float(np.median(fleet_s)),
            "run_batch_seconds": batch_s,
            "run_fleet_seconds": fleet_s,
        }
        ab["speedup"] = ab["run_fleet_eps"] / ab["run_batch_eps"]
    result = {
        "devices": devices,
        "rows": rows,
        "parity": parity,
        "ab": ab,
        "peak_rss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        / 1024.0,
    }
    with open(out_path, "w") as fp:
        json.dump(result, fp)


# ---------------------------------------------------------------------------
# Orchestrator: one subprocess per device count, merged artifact.
# ---------------------------------------------------------------------------

def _spawn_worker(devices: int, tiny: bool, out_path: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.bench_fleet", "--worker",
           "--devices", str(devices), "--out", out_path]
    if tiny:
        cmd.append("--tiny")
    proc = subprocess.run(cmd, cwd=_REPO_ROOT, env=env, capture_output=True,
                          text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_fleet worker (devices={devices}) failed:\n"
            f"{proc.stderr[-4000:]}")


def run(tiny: bool = False) -> dict:
    from benchmarks import common

    plan = _plan(tiny)
    rows, peak_rss, parity, ab = [], {}, None, None
    with tempfile.TemporaryDirectory() as tmp:
        for devices in plan["device_counts"]:
            out_path = os.path.join(tmp, f"worker_{devices}.json")
            _spawn_worker(devices, tiny, out_path)
            with open(out_path) as fp:
                result = json.load(fp)
            rows.extend(result["rows"])
            peak_rss[str(devices)] = result["peak_rss_mb"]
            parity = result["parity"] or parity
            ab = result["ab"] or ab

    return {
        "schema": SCHEMA,
        "tiny": tiny,
        **common.provenance(),
        "config": _sim_config(tiny),
        "rows": rows,
        "speedup_8dev_vs_run_batch": ab,
        "parity": parity,
        "peak_rss_mb": peak_rss,
    }


def validate(data: dict) -> None:
    """Schema check used by CI and tests: provenance stamped, throughput
    rows parseable, and the sharded sweep bitwise-equal to run_batch."""
    from benchmarks import common

    assert data["schema"] == SCHEMA
    common.validate_provenance(data)
    engines = {row["engine"] for row in data["rows"]}
    assert engines == {"run_batch", "run_fleet"}, engines
    for row in data["rows"]:
        assert row["episodes_per_sec"] > 0 and row["periods_per_sec"] > 0, row
    speed = data["speedup_8dev_vs_run_batch"]
    assert speed["speedup"] and speed["speedup"] > 0
    assert speed["protocol"].startswith("interleaved_median")
    assert len(speed["run_batch_seconds"]) == len(speed["run_fleet_seconds"])
    parity = data["parity"]
    assert parity["durations_equal"] is True
    assert parity["max_dev"] == 0.0, parity
    assert data["peak_rss_mb"], "peak-memory proxy missing"


def run_rows(tiny: bool = False) -> list[dict]:
    """benchmarks.run adapter: execute the study, write the artifact, and
    return ``name,us_per_call,derived`` rows.  Tiny runs land in
    artifacts/bench/; full runs refresh the repo-root trajectory."""
    from benchmarks import common

    data = run(tiny=tiny)
    validate(data)
    if tiny:
        common.save_artifact("bench_fleet_tiny", data)
    else:
        with open(os.path.join(_REPO_ROOT, DEFAULT_OUT), "w") as fp:
            json.dump(data, fp, indent=1, default=float)
            fp.write("\n")
    rows = []
    for row in data["rows"]:
        rows.append(common.row(
            f"fleet/{row['engine']}_dev{row['devices']}_S{row['fleet']}",
            row["seconds"] * 1e6,
            f"eps={row['episodes_per_sec']:.1f} "
            f"pps={row['periods_per_sec']:.0f}"))
    speed = data["speedup_8dev_vs_run_batch"]
    rows.append(common.row(
        "fleet/speedup_8dev_vs_run_batch", None,
        f"fleet={speed['fleet']} speedup={speed['speedup']:.2f}x "
        f"parity_max_dev={data['parity']['max_dev']:.1f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds instead of minutes)")
    ap.add_argument("--out", default=os.path.join(_REPO_ROOT, DEFAULT_OUT),
                    help=f"output path (default: {DEFAULT_OUT} at repo root)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=1, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker:
        _worker(args.devices, args.tiny, args.out)
        return
    data = run(tiny=args.tiny)
    validate(data)
    with open(args.out, "w") as fp:
        json.dump(data, fp, indent=1, default=float)
        fp.write("\n")
    for row in data["rows"]:
        print(f"{row['engine']} devices={row['devices']} "
              f"fleet={row['fleet']}: {row['episodes_per_sec']:.1f} eps "
              f"({row['periods_per_sec']:.0f} periods/s)")
    speed = data["speedup_8dev_vs_run_batch"]
    print(f"speedup @fleet={speed['fleet']}: {speed['speedup']:.2f}x "
          f"(parity max_dev={data['parity']['max_dev']})")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
