"""§Roofline: derive the three roofline terms per (arch x shape) from the
dry-run artifacts, with scan-trip correction.

XLA's cost_analysis counts each while/scan body ONCE regardless of trip count
(verified on this toolchain: a 2-layer and 4-layer scanned stack report
identical FLOPs).  The whole-program numbers therefore undercount by ~L.  The
correction compiles the cell's *single block* in isolation on the same mesh
(inner chunk loops disabled so the block is loop-free) and composes:

    X_corrected = X_whole_program + (trips - 1) * X_block

per quantity (FLOPs, bytes, per-collective bytes).  Residual error is bounded
by one layer's inner-loop terms (< ~1/L relative).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

All HLO quantities from the SPMD-partitioned module are PER-CHIP (verified:
corrected per-chip train FLOPs x 256 chips reproduces 6*N*D within 0.3% on
gemma-2b), so the terms are simply

    compute term    = FLOPs_per_chip / peak
    memory term     = bytes_per_chip / HBM
    collective term = collective_bytes_per_chip / ICI

MODEL_FLOPS (global) = 6*N_active*tokens (train) or 2*N_active*tokens
(decode/prefill, fwd only); the ratio MODEL_FLOPS / (HLO_FLOPs * chips) flags
remat/redundancy waste (== useful-compute fraction).
"""
from __future__ import annotations

import json
import glob
import os

from benchmarks import common

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_ACTIVE_PARAMS_CACHE: dict[str, float] = {}


def _active_params(arch: str) -> float:
    if arch not in _ACTIVE_PARAMS_CACHE:
        from repro import configs
        _ACTIVE_PARAMS_CACHE[arch] = float(
            configs.get_config(arch).active_param_count())
    return _ACTIVE_PARAMS_CACHE[arch]


def model_flops(arch: str, shape_name: str) -> float:
    from repro.models import registry
    seq, batch, kind = registry.SHAPES[shape_name]
    n_act = _active_params(arch)
    if kind == "train":
        return 6.0 * n_act * seq * batch
    if kind == "prefill":
        return 2.0 * n_act * seq * batch
    return 2.0 * n_act * batch  # decode: one token per sequence


def load_cells(dryrun_dir: str = "artifacts/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def corrected_terms(cell: dict, block: dict | None, trips: int) -> dict:
    """Compose whole-program + (trips-1) x block costs into roofline terms.
    All inputs per-chip; terms in seconds per step."""
    n = cell["n_chips"]
    flops = cell.get("flops") or 0.0
    byts = cell.get("bytes_accessed") or 0.0
    coll = dict(cell.get("collective_bytes") or {})
    if block is not None and trips > 1:
        flops += (trips - 1) * (block.get("flops") or 0.0)
        byts += (trips - 1) * (block.get("bytes_accessed") or 0.0)
        for k, v in (block.get("collective_bytes") or {}).items():
            coll[k] = coll.get(k, 0) + (trips - 1) * v
    coll_total = sum(coll.values())
    out = {
        "flops_corrected": flops,
        "bytes_corrected": byts,
        "collective_bytes_corrected": coll_total,
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s": byts / HBM_BW,
        "collective_term_s": coll_total / ICI_BW,
    }
    mf = model_flops(cell["arch"], cell["shape"])
    out["model_flops"] = mf
    out["useful_compute_fraction"] = mf / max(flops * n, 1e-30)
    terms = {k: out[k] for k in ("compute_term_s", "memory_term_s",
                                 "collective_term_s")}
    out["bottleneck"] = max(terms, key=terms.get).replace("_term_s", "")
    out["step_time_bound_s"] = max(terms.values())
    denom = max(out["step_time_bound_s"], 1e-30)
    out["roofline_fraction"] = out["compute_term_s"] / denom
    return out


def megakernel_roofline(n: int = 8192, k_pad: int = 128, trips: int = 12,
                        inner_iters: int = 48) -> dict:
    """Analytic FLOPs/bytes model of one whole-market ``market_clear`` launch
    (kernels/market_clear.py) vs the unfused per-trip alternative.

    Per dual trip the demand+slope tile runs an ``inner_iters``-deep bisection
    (~6 flops per (n, k) lane per iteration: update f, form 1 - tCf, square,
    divide, accumulate) plus the closed-form slope sums (~12 flops/lane).
    Fused, alpha/t_comp cross HBM ONCE for the whole solve because the market
    stays resident in VMEM across trips; unfused, every trip re-reads both
    operands and writes per-service demand/slope, so HBM traffic scales with
    the trip count.  The ratio is the megakernel's raison d'etre on a
    memory-bound op (arithmetic intensity stays modest even fused)."""
    flops_per_trip = n * k_pad * (6 * inner_iters + 12)
    flops = trips * flops_per_trip
    bytes_fused = (2 * n * k_pad + 3 * n) * 4        # in: alpha,t_comp; out: b,f,lam
    bytes_unfused = trips * (2 * n * k_pad + 2 * n) * 4 + 3 * n * 4
    return {
        "n": n, "k_pad": k_pad, "trips": trips, "inner_iters": inner_iters,
        "flops_per_trip": float(flops_per_trip),
        "flops_total": float(flops),
        "hbm_bytes_fused": float(bytes_fused),
        "hbm_bytes_unfused": float(bytes_unfused),
        "hbm_bytes_ratio_unfused_over_fused": bytes_unfused / bytes_fused,
        "arithmetic_intensity_fused": flops / bytes_fused,
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s_fused": bytes_fused / HBM_BW,
        "memory_term_s_unfused": bytes_unfused / HBM_BW,
        "bottleneck_fused": ("compute" if flops / PEAK_FLOPS
                             > bytes_fused / HBM_BW else "memory"),
    }


def run() -> list[dict]:
    rows = []
    cells = load_cells()
    block_dir = "artifacts/blocks"
    summary = []
    for cell in cells:
        if cell.get("status") != "ok":
            rows.append(common.row(
                f"roofline/{cell['arch']}/{cell['shape']}/{cell['mesh']}",
                None, cell.get("status", "?")))
            continue
        tag = f"{cell['arch']}__{cell['shape']}__{cell['mesh']}"
        block_path = os.path.join(block_dir, tag + ".json")
        block, trips = None, 1
        if os.path.exists(block_path):
            with open(block_path) as f:
                bdata = json.load(f)
            block, trips = bdata, bdata.get("trips", 1)
        terms = corrected_terms(cell, block, trips)
        summary.append({**{k: cell[k] for k in ("arch", "shape", "mesh", "n_chips")},
                        **terms, "scan_corrected": block is not None})
        rows.append(common.row(
            f"roofline/{cell['arch']}/{cell['shape']}/{cell['mesh']}", None,
            f"bottleneck={terms['bottleneck']} "
            f"compute={terms['compute_term_s']:.2e}s "
            f"memory={terms['memory_term_s']:.2e}s "
            f"collective={terms['collective_term_s']:.2e}s"))
    mk = megakernel_roofline()
    common.save_artifact("roofline_megakernel", mk)
    rows.append(common.row(
        f"roofline/market_megakernel/N{mk['n']}", None,
        f"flops_per_trip={mk['flops_per_trip']:.2e} "
        f"hbm_fused={mk['hbm_bytes_fused']:.2e}B "
        f"unfused/fused={mk['hbm_bytes_ratio_unfused_over_fused']:.1f}x "
        f"bottleneck={mk['bottleneck_fused']}"))
    common.save_artifact("roofline_summary", summary)
    return rows
