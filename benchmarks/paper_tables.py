"""Paper Tables I-III: the representative 5-service period (10/12/14/16/18
clients) under cooperative DISBA (Table I), DISBA's computational complexity
vs (eps, gamma) (Table II), and the fairness-adjusted multi-bid auction with
M=5, alpha=0.5 (Table III).

Exact numbers are seed-dependent (the paper publishes no seeds); what must
reproduce are the structural facts: near-uniform bandwidth ratios with more
clients costing frequency, sum(b)=B, tens-of-iterations convergence that
speeds up with looser eps / larger gamma, and the auction tracking the
cooperative allocation at moderate M.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import auction, disba, intra, network


def run() -> list[dict]:
    rows = []
    svc, meta = network.table1_service_set(jax.random.key(0))
    B, T = network.B_TOTAL_MHZ, network.PERIOD_S

    # ---- Table I: cooperative allocation
    res = disba.solve_lambda_bisect(svc, B)
    us = common.time_fn(lambda: disba.solve_lambda_bisect(svc, B))
    tbl1 = []
    for i in range(5):
        tbl1.append({
            "service": i + 1,
            "clients": int(meta["client_counts"][i]),
            "bandwidth_ratio": round(float(res.b[i] / B), 3),
            "rounds_per_period": round(float(res.f[i] * T), 1),
        })
        rows.append(common.row(
            f"table1/coop/service{i + 1}", None,
            f"ratio={tbl1[-1]['bandwidth_ratio']} "
            f"freq={tbl1[-1]['rounds_per_period']}"))
    rows.append(common.row("table1/solve", us, f"lambda={float(res.lam):.4f}"))
    common.save_artifact("table1_coop", tbl1)

    # ---- Table II: DISBA complexity vs (eps, gamma)
    tbl2 = []
    for eps in (1e-3, 5e-3):
        for gamma in (0.1, 0.05):
            hist = disba.disba_trace(svc, B, gamma=gamma, eps=eps)
            us2 = common.time_fn(
                lambda g=gamma, e=eps: disba.disba(svc, B, gamma=g, eps=e),
                iters=5)
            tbl2.append({"eps": eps, "gamma": gamma,
                         "iterations": hist["iterations"],
                         "time_us": round(us2, 1)})
            rows.append(common.row(
                f"table2/eps{eps}/gamma{gamma}", us2,
                f"iterations={hist['iterations']}"))
    # the paper's gamma=0.5 violates our scenario's stability bound
    # gamma < 2/|D_hat'| (measured); the diminishing-step variant converges
    hist_d = disba.disba_trace(svc, B, gamma=0.5, eps=1e-3, diminishing=True)
    rows.append(common.row("table2/gamma0.5_diminishing", None,
                           f"iterations={hist_d['iterations']}"))
    common.save_artifact("table2_complexity", tbl2)

    # ---- Table III: selfish auction, M=5, alpha=0.5
    ar = auction.run_auction(svc, B, n_bids=5, alpha_fair=0.5)
    us3 = common.time_fn(
        lambda: auction.run_auction(svc, B, n_bids=5, alpha_fair=0.5), iters=5)
    tbl3 = []
    for i in range(5):
        tbl3.append({
            "service": i + 1,
            "clients": int(meta["client_counts"][i]),
            "bandwidth_ratio": round(float(ar.b[i] / B), 3),
            "rounds_per_period": round(float(ar.f[i] * T), 1),
        })
        rows.append(common.row(
            f"table3/selfish/service{i + 1}", None,
            f"ratio={tbl3[-1]['bandwidth_ratio']} "
            f"freq={tbl3[-1]['rounds_per_period']}"))
    rows.append(common.row("table3/auction", us3,
                           f"zeta={float(ar.price):.4f}"))
    common.save_artifact("table3_selfish", tbl3)
    return rows
