"""Fault-injection benchmark -> repo-root ``BENCH_fault.json``.

``BENCH_serve.json`` pinned the healthy serving path; this artifact pins the
*degraded* one: seeded chaos storms (``repro.chaos``) over the live daemon
at market capacities N in {16, 64, 256}, measuring what the hardened paths
actually cost when heartbeat, solver, checkpoint, and admission faults all
fire together -- decisions lost to restarts, recovery time (consecutive
non-fresh serves per outage), stale/degraded/fallback rates, and the
trajectory digest run twice to prove the storm replays bitwise from its
seed.  A separate checkpoint-restore drill corrupts the newest snapshot
behind an intact COMMIT and verifies the restart falls back to the older
step, counts the skip, and keeps serving finite decisions.

Every counter in the artifact is a degradation the stack refused to take
silently; the invariant harness (budget conservation, finite outputs,
retired slots never allocated, bitwise replay) must hold in every row.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_fault [--tiny] [--out PATH]

``--tiny`` shrinks capacities/periods for the CI smoke step (same schema,
same validation path).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import tempfile

SCHEMA = "bench_fault/v1"
DEFAULT_OUT = "BENCH_fault.json"
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _plan(tiny: bool) -> dict:
    if tiny:
        return {"capacities": [4, 8], "periods": 14, "k_max": 8,
                "rounds_required": 250, "seed": 42, "save_every": 3}
    return {"capacities": [16, 64, 256], "periods": 40, "k_max": 16,
            "rounds_required": 400, "seed": 42, "save_every": 5}


def _storm_cfg(capacity: int, plan: dict):
    from repro.fl.control_plane import ControlPlaneConfig

    return ControlPlaneConfig(
        capacity=capacity, k_max=plan["k_max"], policy="coop",
        warm_start=True, rounds_required=plan["rounds_required"],
        channel_process="gauss_markov", heartbeat_timeout_periods=2, seed=0)


def _storm_row(capacity: int, plan: dict) -> dict:
    """One full-catalogue storm at this capacity, run twice from the same
    seed: the second run must land on the identical digest."""
    from repro.chaos.engine import run_storm

    cfg = _storm_cfg(capacity, plan)

    def once(ckpt_dir: str) -> dict:
        return run_storm(cfg, seed=plan["seed"], n_periods=plan["periods"],
                         checkpoint_dir=ckpt_dir,
                         save_every=plan["save_every"], max_stale_streak=4)

    with tempfile.TemporaryDirectory() as d1:
        r1 = once(d1)
    with tempfile.TemporaryDirectory() as d2:
        r2 = once(d2)
    m = r1["metrics"]
    return {
        "capacity": capacity,
        "periods": plan["periods"],
        "seed": plan["seed"],
        "digest": r1["digest"],
        "digest_repeat_equal": bool(r1["digest"] == r2["digest"]),
        "n_events": r1["n_events"],
        "restarts": r1["restarts"],
        "served": r1["served"],
        "decisions_lost": r1["decisions_lost"],
        "recovery": r1["recovery"],
        "stale_rate": r1["served"]["stale"] / plan["periods"],
        "degraded_rate": r1["served"]["degraded"] / plan["periods"],
        "solver_fallbacks": m["solver_fallbacks"],
        "nonfinite_decisions": m["nonfinite_decisions"],
        "carry_repairs": m["carry_repairs"],
        "checkpoint_skips": m["checkpoint_skips"],
        "admit_retries": m["admit_retries"],
        "heartbeat_drops": m["heartbeat_drops"],
        "invariants_ok": bool(all(v["ok"]
                                  for v in r1["invariants"].values())),
        "invariants_failed": [k for k, v in r1["invariants"].items()
                              if not v["ok"]],
    }


def _restore_drill(plan: dict) -> dict:
    """Checkpoint-restore integrity: corrupt the newest snapshot behind its
    intact COMMIT, restart, and verify the daemon falls back to the older
    step, counts the skip, and keeps serving finite decisions."""
    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.launch import allocd

    cfg = _storm_cfg(4, plan)

    async def warm_up(daemon, periods):
        daemon.submit(allocd.Admit("a", 3))
        daemon.submit(allocd.Admit("b", 2))
        for _ in range(periods):
            await daemon.step_period()
        await daemon.close()

    async def resume_and_serve(daemon, periods):
        finite = True
        for _ in range(periods):
            d = await daemon.step_period()
            finite &= bool(np.all(np.isfinite(d.b))
                           and np.all(np.isfinite(d.f)))
        await daemon.close()
        return finite

    with tempfile.TemporaryDirectory() as ckpt:
        daemon = allocd.AllocDaemon(cfg, manager=CheckpointManager(ckpt),
                                    save_every=2)
        asyncio.run(warm_up(daemon, 6))
        mgr = daemon.manager
        steps = mgr.all_steps()
        newest = steps[-1]
        shard = os.path.join(mgr._step_dir(newest), "shard_0000.npz")
        with open(shard, "r+b") as f:
            f.truncate(os.path.getsize(shard) // 2)
        daemon2 = allocd.AllocDaemon(cfg, manager=CheckpointManager(ckpt),
                                     save_every=2)
        finite = asyncio.run(resume_and_serve(daemon2, 3))
        return {
            "steps_before": [int(s) for s in steps],
            "corrupted_step": int(newest),
            "resumed": bool(daemon2.resumed),
            "restored_period": int(daemon2.plane.period) - 3,
            "skipped": [int(s) for s, _ in daemon2.manager.last_skipped],
            "checkpoint_skips": int(
                daemon2.plane.metrics["checkpoint_skips"]),
            "served_finite_after_restore": bool(finite),
        }


def run(tiny: bool = False) -> dict:
    from benchmarks import common

    plan = _plan(tiny)
    rows = [_storm_row(capacity, plan) for capacity in plan["capacities"]]
    return {
        "schema": SCHEMA,
        "tiny": tiny,
        **common.provenance(),
        "plan": plan,
        "rows": rows,
        "restore_drill": _restore_drill(plan),
    }


def validate(data: dict) -> None:
    """Schema check used by CI and tests: provenance stamped, every storm
    row deterministic (digest equal across two runs from the same seed) and
    invariant-clean, the served stream fully accounted, and the restore
    drill actually skipping past the corrupted snapshot."""
    from benchmarks import common

    assert data["schema"] == SCHEMA
    common.validate_provenance(data)
    assert data["rows"], "no storm rows"
    for row in data["rows"]:
        assert row["digest_repeat_equal"] is True, (
            f"storm at N={row['capacity']} is not replayable from its seed")
        assert row["invariants_ok"] is True, (
            f"invariants violated at N={row['capacity']}: "
            f"{row['invariants_failed']}")
        s = row["served"]
        assert s["fresh"] + s["stale"] + s["degraded"] == row["periods"], row
        assert row["decisions_lost"] >= 0, row
        assert row["n_events"] > 0, "storm injected nothing"
        assert row["recovery"]["outages"] >= 0
        assert len(row["digest"]) == 64
    drill = data["restore_drill"]
    assert drill["resumed"] is True, drill
    assert drill["corrupted_step"] in drill["skipped"], (
        "corrupted snapshot was not skipped")
    assert drill["checkpoint_skips"] >= 1, (
        "checkpoint skip was absorbed silently")
    assert drill["restored_period"] < drill["corrupted_step"], (
        "restore did not fall back to an older step")
    assert drill["served_finite_after_restore"] is True, drill


def run_rows(tiny: bool = False) -> list[dict]:
    """benchmarks.run adapter: execute, write the artifact, emit CSV rows."""
    from benchmarks import common

    data = run(tiny=tiny)
    validate(data)
    if tiny:
        common.save_artifact("bench_fault_tiny", data)
    else:
        with open(os.path.join(_REPO_ROOT, DEFAULT_OUT), "w") as fp:
            json.dump(data, fp, indent=1, default=float)
            fp.write("\n")
    rows = []
    for row in data["rows"]:
        s = row["served"]
        rows.append(common.row(
            f"fault/storm_N{row['capacity']}", None,
            f"fresh={s['fresh']}/{row['periods']} lost={row['decisions_lost']} "
            f"restarts={row['restarts']} "
            f"recovery_max={row['recovery']['max_periods']}p "
            f"fallbacks={row['solver_fallbacks']} "
            f"repairs={row['carry_repairs']} deterministic="
            f"{row['digest_repeat_equal']}"))
    drill = data["restore_drill"]
    rows.append(common.row(
        "fault/restore_drill", None,
        f"skipped_step={drill['corrupted_step']} "
        f"restored_before={drill['restored_period']} "
        f"finite={drill['served_finite_after_restore']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds instead of minutes)")
    ap.add_argument("--out", default=os.path.join(_REPO_ROOT, DEFAULT_OUT),
                    help=f"output path (default: {DEFAULT_OUT} at repo root)")
    args = ap.parse_args()
    data = run(tiny=args.tiny)
    validate(data)
    with open(args.out, "w") as fp:
        json.dump(data, fp, indent=1, default=float)
        fp.write("\n")
    for row in data["rows"]:
        s = row["served"]
        print(f"N={row['capacity']}: fresh={s['fresh']} stale={s['stale']} "
              f"degraded={s['degraded']} lost={row['decisions_lost']} "
              f"restarts={row['restarts']} "
              f"deterministic={row['digest_repeat_equal']} "
              f"invariants_ok={row['invariants_ok']}")
    drill = data["restore_drill"]
    print(f"restore drill: corrupted step {drill['corrupted_step']} skipped, "
          f"resumed at {drill['restored_period']}, "
          f"finite={drill['served_finite_after_restore']}")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
