"""Byzantine-robustness benchmark -> repo-root ``BENCH_robust.json``.

``BENCH_fault.json`` pinned the stack against *infrastructure* faults; this
artifact pins it against *adversarial participants* on both markets:

* **Breakdown curves** -- the tuned co-trained episode (see EXPERIMENTS.md
  §Adversarial robustness) runs every registered aggregator against the
  client-attack catalogue (``chaos.clients``) across Byzantine fractions,
  recording final bigram accuracy, the drop vs the clean baseline, and
  whether the served model stayed finite.  The curves show plain FedAvg
  collapsing under a 20% sign-flip cohort while the robust registry
  (trimmed-mean / median / norm-clip / Krum) holds within
  ``chaos.invariants.ROBUST_ACC_DROP`` -- and a NaN cohort poisoning FedAvg
  outright while every robust aggregator masks it.
* **Manipulation-gain curves** -- seeded unilateral bid deviations
  (``chaos.bids``) against the fairness-adjusted auction, per deviation kind
  and magnitude: the empirical gain must stay under the Eq. 31 truthfulness
  gap (``invariants.regret_bounded``), which is the paper's Prop. 5 checked
  by attack rather than by algebra.
* **Determinism** -- every attacked episode runs twice from its spec; the
  trajectory digests must match bitwise (the attack rides the PR 8 chaos
  channels, so the whole adversarial trajectory replays from the seed).
  The allocation stream of every attacked run is also checked bitwise
  against the duration engine: the adversary corrupts uploads, never the
  market.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_robust [--tiny] [--out PATH]

``--tiny`` shrinks the grid to 2 attacks x 2 aggregators for the CI smoke
step (same schema, same validation path; the accuracy-separation gate is
full-size only -- tiny episodes are too short to separate).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os

SCHEMA = "bench_robust/v1"
DEFAULT_OUT = "BENCH_robust.json"
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Aggregators whose rows must pass the robustness gates (fedavg is the
# deliberately breakable seed path -- its breakage is *recorded*).
_ROBUST = ("trimmed_mean", "median", "norm_clip", "krum", "multi_krum")


def _plan(tiny: bool) -> dict:
    if tiny:
        return {
            "aggregators": ["fedavg", "median"],
            "attacks": {"sign_flip": [0.2], "nan": [0.2]},
            "scale": 20.0, "attack_seed": 1,
            "trim_frac": 0.25, "byz_f": 2,
            "episode": {"policy": "coop", "n_services_total": 2,
                        "rounds_required": 10, "p_arrive": 2.0,
                        "max_periods": 16, "k_max": 8,
                        "mean_clients": 5.0, "var_clients": 1.0},
            "train": {"vocab": 16, "seq_len": 6, "batch_size": 2,
                      "eval_batch": 8, "rounds_cap": 2},
            "bid": {"n_providers": 4, "n_trials": 6, "n_bids": 5,
                    "seed": 7, "factors": {"overbid": [2.0, 4.0],
                                           "shade": [0.3, 0.7],
                                           "free_ride": [0.0]}},
        }
    return {
        "aggregators": ["fedavg", "trimmed_mean", "median", "norm_clip",
                        "krum", "multi_krum"],
        "attacks": {"sign_flip": [0.1, 0.2, 0.3],
                    "scaled_delta": [0.1, 0.2, 0.3],
                    "nan": [0.2]},
        "scale": 20.0, "attack_seed": 1,
        "trim_frac": 0.25, "byz_f": 3,
        "episode": {"policy": "coop", "n_services_total": 2,
                    "rounds_required": 40, "p_arrive": 2.0,
                    "max_periods": 60, "k_max": 12,
                    "mean_clients": 9.0, "var_clients": 1.0},
        "train": {"vocab": 16, "seq_len": 6, "batch_size": 2,
                  "eval_batch": 32, "rounds_cap": 3},
        "bid": {"n_providers": 6, "n_trials": 24, "n_bids": 5,
                "seed": 7, "factors": {"overbid": [1.5, 2.0, 3.0, 4.0],
                                       "shade": [0.2, 0.4, 0.6, 0.8],
                                       "free_ride": [0.0]}},
    }


def _scenario(plan: dict):
    from repro.core import network
    from repro.fl import cotrain, simulator

    ep = plan["episode"]
    cfg = simulator.SimConfig(**ep)
    net = network.NetworkConfig(period_s=1.0,
                                mean_clients=ep["mean_clients"],
                                var_clients=ep["var_clients"])
    train = cotrain.TrainSpec(**plan["train"])
    return cfg, net, train


def _episode(plan: dict, aggregator: str | None, attack: str | None,
             byz_frac: float) -> dict:
    """One co-trained episode; ``aggregator=None`` is the clean FedAvg
    baseline.  Returns final accuracy, params finiteness, the duration
    stream, and a bitwise trajectory digest."""
    import dataclasses

    import jax
    import numpy as np

    from repro.chaos import invariants
    from repro.chaos.clients import AttackSpec
    from repro.fl import cotrain

    cfg, net, train = _scenario(plan)
    if aggregator is None:
        out = cotrain.run_cotrain_scan(cfg, train, net)
    else:
        spec = dataclasses.replace(train, aggregator=aggregator,
                                   trim_frac=plan["trim_frac"],
                                   byz_f=plan["byz_f"])
        atk = AttackSpec(attack=attack, byz_frac=byz_frac,
                         scale=plan["scale"], seed=plan["attack_seed"])
        out = cotrain.run_cotrain_scan(cfg, spec, net, attack=atk)
    acc_hist = np.asarray(out["history"]["acc"])
    digest = hashlib.sha256()
    digest.update(acc_hist.tobytes())
    digest.update(np.asarray(out["durations"], np.int64).tobytes())
    for leaf in jax.tree.leaves(out["params"]):
        digest.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    final_acc = float(acc_hist[out["periods"] - 1].mean())
    return {
        "final_acc": final_acc,
        "params_finite": bool(invariants.params_finite(out["params"])["ok"]),
        "durations": [int(d) for d in out["durations"]],
        "digest": digest.hexdigest(),
    }


def _breakdown_rows(plan: dict) -> tuple[dict, list[dict]]:
    """Clean baseline + every aggregator x attack x fraction, each attacked
    episode run twice (the second hits the jit cache) to pin determinism,
    and every duration stream checked bitwise against the duration engine."""
    from repro.fl import simulator

    cfg, net, _ = _scenario(plan)
    engine = simulator.run_scan(cfg, net)["durations"]

    clean = _episode(plan, None, None, 0.0)
    clean["durations_match_engine"] = clean["durations"] == engine
    rows = []
    for agg in plan["aggregators"]:
        for attack, fracs in plan["attacks"].items():
            for frac in fracs:
                r1 = _episode(plan, agg, attack, frac)
                r2 = _episode(plan, agg, attack, frac)
                rows.append({
                    "aggregator": agg, "attack": attack, "byz_frac": frac,
                    "final_acc": r1["final_acc"],
                    "drop": clean["final_acc"] - r1["final_acc"],
                    "params_finite": r1["params_finite"],
                    "digest": r1["digest"],
                    "digest_repeat_equal": r1["digest"] == r2["digest"],
                    "durations_match_engine": r1["durations"] == engine,
                })
    return clean, rows


def _bid_section(plan: dict) -> dict:
    """Manipulation-gain curves (per deviation kind and magnitude, worst
    provider) + the seeded BidChaos campaign, gated by Eq. 31."""
    import jax
    import numpy as np

    from repro.chaos import invariants
    from repro.chaos.bids import BidChaos, audit_deviation
    from repro.core import network

    bp = plan["bid"]
    svc, _ = network.sample_services(jax.random.key(0), bp["n_providers"])
    B = network.B_TOTAL_MHZ

    curves = []
    for kind, factors in bp["factors"].items():
        for factor in factors:
            audits = [audit_deviation(svc, B, n, kind, factor,
                                      n_bids=bp["n_bids"])
                      for n in range(bp["n_providers"])]
            worst = max(audits, key=lambda r: r["gain"] - r["delta_bound"])
            curves.append({
                "deviation": kind, "factor": factor,
                "max_gain": float(max(r["gain"] for r in audits)),
                "worst_excess": float(worst["gain"] - worst["delta_bound"]),
                "delta_bound": worst["delta_bound"],
                "bounded": bool(all(r["gain"] <= r["delta_bound"] + 1e-3
                                    for r in audits)),
            })

    trials = BidChaos(seed=bp["seed"]).run(svc, B, bp["n_trials"],
                                           n_bids=bp["n_bids"])
    replay = BidChaos(seed=bp["seed"]).run(svc, B, bp["n_trials"],
                                           n_bids=bp["n_bids"])
    gate = invariants.regret_bounded(trials)
    return {
        "n_providers": bp["n_providers"],
        "total_bandwidth_mhz": float(B),
        "curves": curves,
        "trials": trials,
        "trials_replay_equal": trials == replay,
        "regret_gate": {k: v for k, v in gate.items()},
        "worst_gain": float(max((r["gain"] for r in trials), default=0.0)),
    }


def run(tiny: bool = False) -> dict:
    from benchmarks import common

    plan = _plan(tiny)
    clean, rows = _breakdown_rows(plan)
    return {
        "schema": SCHEMA,
        "tiny": tiny,
        **common.provenance(),
        "plan": plan,
        "clean": clean,
        "rows": rows,
        "bids": _bid_section(plan),
    }


def validate(data: dict) -> None:
    """Schema check used by CI and tests: every attacked episode replays
    bitwise and leaves the allocation stream untouched; robust-aggregator
    rows keep finite params unconditionally; on the full grid the robust
    registry holds the ``ROBUST_ACC_DROP`` accuracy gate at <=20% Byzantine
    clients where plain FedAvg demonstrably breaks; no audited bid deviation
    beats the Eq. 31 truthfulness bound."""
    from benchmarks import common
    from repro.chaos.invariants import ROBUST_ACC_DROP

    assert data["schema"] == SCHEMA
    common.validate_provenance(data)
    assert data["rows"], "no breakdown rows"
    assert data["clean"]["durations_match_engine"] is True, (
        "clean co-trained episode perturbed the allocation stream")
    assert data["clean"]["params_finite"] is True

    for row in data["rows"]:
        key = (f"{row['aggregator']}/{row['attack']}"
               f"@{row['byz_frac']}")
        assert row["digest_repeat_equal"] is True, (
            f"attacked episode {key} is not replayable from its spec")
        assert row["durations_match_engine"] is True, (
            f"attack {key} leaked into the allocation stream")
        assert len(row["digest"]) == 64
        if row["aggregator"] in _ROBUST:
            assert row["params_finite"] is True, (
                f"robust aggregator served non-finite params: {key}")

    if not data["tiny"]:
        fedavg_broke = False
        for row in data["rows"]:
            robust = row["aggregator"] in _ROBUST
            gradient_attack = row["attack"] in ("sign_flip", "scaled_delta")
            if robust and gradient_attack and row["byz_frac"] <= 0.2:
                assert row["drop"] <= ROBUST_ACC_DROP, (
                    f"robust aggregator broke: {row}")
            if (row["aggregator"] == "fedavg" and row["attack"] == "sign_flip"
                    and row["byz_frac"] >= 0.2):
                fedavg_broke |= row["drop"] > ROBUST_ACC_DROP
        assert fedavg_broke, (
            "plain FedAvg did not break under the sign-flip cohort -- "
            "the separation the robust registry exists for is gone")
        nan_rows = [r for r in data["rows"]
                    if r["attack"] == "nan" and r["aggregator"] == "fedavg"]
        for row in nan_rows:
            assert row["params_finite"] is False, (
                "plain FedAvg absorbed a NaN cohort -- the masking "
                "asymmetry the catalogue demonstrates is gone")

    bids = data["bids"]
    assert bids["trials_replay_equal"] is True, (
        "bid-chaos campaign is not replayable from its seed")
    assert bids["regret_gate"]["ok"] is True, bids["regret_gate"]
    for pt in bids["curves"]:
        assert pt["bounded"] is True, (
            f"deviation {pt['deviation']}@{pt['factor']} beat the "
            f"truthfulness bound by {pt['worst_excess']}")


def run_rows(tiny: bool = False) -> list[dict]:
    """benchmarks.run adapter: execute, write the artifact, emit CSV rows."""
    from benchmarks import common

    data = run(tiny=tiny)
    validate(data)
    if tiny:
        common.save_artifact("bench_robust_tiny", data)
    else:
        with open(os.path.join(_REPO_ROOT, DEFAULT_OUT), "w") as fp:
            json.dump(data, fp, indent=1, default=float)
            fp.write("\n")
    rows = []
    for row in data["rows"]:
        rows.append(common.row(
            f"robust/{row['aggregator']}/{row['attack']}"
            f"@{row['byz_frac']:g}", row["final_acc"],
            f"drop={row['drop']:+.3f} finite={row['params_finite']} "
            f"deterministic={row['digest_repeat_equal']}"))
    bids = data["bids"]
    rows.append(common.row(
        "robust/bid_regret", bids["worst_gain"],
        f"trials={len(bids['trials'])} "
        f"worst_excess={bids['regret_gate']['worst_excess']:+.4f} "
        f"bounded={bids['regret_gate']['ok']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (2 attacks x 2 aggregators)")
    ap.add_argument("--out", default=os.path.join(_REPO_ROOT, DEFAULT_OUT),
                    help=f"output path (default: {DEFAULT_OUT} at repo root)")
    args = ap.parse_args()
    data = run(tiny=args.tiny)
    validate(data)
    with open(args.out, "w") as fp:
        json.dump(data, fp, indent=1, default=float)
        fp.write("\n")
    print(f"clean final acc: {data['clean']['final_acc']:.4f}")
    for row in data["rows"]:
        print(f"{row['attack']:13s} {row['aggregator']:13s} "
              f"frac={row['byz_frac']:.1f} acc={row['final_acc']:.4f} "
              f"drop={row['drop']:+.4f} finite={row['params_finite']} "
              f"deterministic={row['digest_repeat_equal']}")
    bids = data["bids"]
    print(f"bid regret: worst_gain={bids['worst_gain']:+.5f} "
          f"gate_ok={bids['regret_gate']['ok']} "
          f"replay={bids['trials_replay_equal']}")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
