"""Fleet-scale allocator benchmarks (beyond-paper, feeds EXPERIMENTS.md §Perf).

Compares, on REAL CPU wall-clock:
  * paper-faithful-sequential: per-service scalar bisection in a Python loop
    inside each dual iteration (how Algorithm 1 reads) -- small N only;
  * paper-faithful-vectorized: the same subgradient dual, all services
    solved as one batched bisection (our DISBA);
  * beyond-paper-bisect: direct market clearing on the monotone aggregate
    demand (48 fixed trips);
  * beyond-paper-newton: damped Newton with the closed-form demand slope
    (quadratic convergence, <= 12 trips);
  * beyond-paper-warm: the warm-started safeguarded Newton
    (solve_lambda_newton_warm, <= 6 fused demand+slope evaluations seeded
    from the previous period's dual price -- the multi-period fast path);
  * auction charge computation: leave-one-out clearing reruns (O(N^2 M
    log NM)) vs the closed-form prefix-sum path (O(NM log NM)).

The repo-root ``BENCH_allocation.json`` trajectory is produced by the
dedicated ``benchmarks/bench_allocation.py``; the rows here fold the same
comparisons into the full allocator study.

The Pallas bisect_alloc kernel is the TPU deployment of the inner solve; on
this CPU host it is validated in interpret mode (tests/test_kernels.py) and
not timed here.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import disba, intra, network, policy
from repro.core.types import ServiceSet
from repro.fl import simulator


def _sequential_disba(svc: ServiceSet, B: float, gamma=0.1, eps=1e-3,
                      max_iters=500) -> tuple[np.ndarray, int]:
    """Algorithm 1 as literally written: loop over providers each iteration."""
    n = svc.n_services
    lam_scale = float(jnp.max(intra.p_max(svc)))
    lam = 0.5 * lam_scale
    singles = [
        ServiceSet(alpha=svc.alpha[i:i + 1], t_comp=svc.t_comp[i:i + 1],
                   mask=svc.mask[i:i + 1])
        for i in range(n)
    ]
    demands = np.zeros(n)
    for j in range(max_iters):
        for i, s in enumerate(singles):                    # the provider loop
            demands[i] = float(intra.demand(s, jnp.float32(lam))[0])
        gap = B - demands.sum()
        lam_next = min(max(lam - gamma * lam_scale * gap / B, 0.0), lam_scale)
        if abs(lam_next - lam) <= eps * lam_scale:
            return demands, j + 1
        lam = lam_next
    return demands, max_iters


def run() -> list[dict]:
    rows = []
    B = network.B_TOTAL_MHZ

    # ---- sequential vs vectorized at small N (the honesty baseline)
    svc_small, _ = network.sample_services(jax.random.key(1), 8, k_max=30)
    import time
    t0 = time.perf_counter()
    _, iters_seq = _sequential_disba(svc_small, B)
    t_seq = (time.perf_counter() - t0) * 1e6
    us_vec = common.time_fn(lambda: disba.disba(svc_small, B, gamma=0.1), iters=5)
    rows.append(common.row("scale/sequential_N8", t_seq, f"iters={iters_seq}"))
    rows.append(common.row("scale/vectorized_N8", us_vec,
                           f"speedup={t_seq / us_vec:.1f}x"))

    # ---- fleet scale: vectorized subgradient vs bisect vs newton vs warm
    for n in (100, 1_000, 10_000):
        svc, _ = network.sample_services(jax.random.key(2), n, k_max=32)
        lam_prev = disba.solve_lambda_bisect(svc, B).lam * jnp.float32(1.03)
        us_sub = common.time_fn(lambda s=svc: disba.disba(s, B, gamma=0.1),
                                iters=3)
        us_bis = common.time_fn(lambda s=svc: disba.solve_lambda_bisect(s, B),
                                iters=3)
        us_new = common.time_fn(lambda s=svc: disba.solve_lambda_newton(s, B),
                                iters=3)
        us_warm = common.time_fn(
            lambda s=svc: disba.solve_lambda_newton_warm(s, B, lam_prev),
            iters=3)
        # cross-check they all agree
        b1 = disba.solve_lambda_bisect(svc, B).b
        b2 = disba.solve_lambda_newton(svc, B).b
        b3 = disba.solve_lambda_newton_warm(svc, B, lam_prev).b
        dev = float(jnp.max(jnp.abs(b1 - b2)))
        dev_warm = float(jnp.max(jnp.abs(b1 - b3)))
        rows.append(common.row(f"scale/subgradient_N{n}", us_sub,
                               f"us_per_service={us_sub / n:.2f}"))
        rows.append(common.row(f"scale/bisect_N{n}", us_bis,
                               f"us_per_service={us_bis / n:.2f}"))
        rows.append(common.row(f"scale/newton_N{n}", us_new,
                               f"us_per_service={us_new / n:.2f} "
                               f"max_dev_vs_bisect={dev:.2e}"))
        rows.append(common.row(f"scale/warm_newton_N{n}", us_warm,
                               f"us_per_service={us_warm / n:.2f} "
                               f"speedup_vs_bisect={us_bis / us_warm:.1f}x "
                               f"max_dev_vs_bisect={dev_warm:.2e}"))

    # ---- auction charge computation: leave-one-out rerun vs prefix sums
    from repro.core import auction
    for n in (64, 256):
        svc_a, _ = network.sample_services(jax.random.key(4), n, k_max=16)
        bid = auction.uniform_truthful_bids(svc_a, 5, 0.5)
        b_a, _ = auction.allocate(bid, B)
        rerun = jax.jit(lambda s, bd, bb: auction.charges(
            s, bd, bb, B, 0.5, method="rerun"))
        prefix = jax.jit(lambda s, bd, bb: auction.charges(
            s, bd, bb, B, 0.5, method="prefix"))
        dev_c = float(jnp.max(jnp.abs(rerun(svc_a, bid, b_a)
                                      - prefix(svc_a, bid, b_a))))
        us_rerun = common.time_fn(lambda: rerun(svc_a, bid, b_a), iters=3)
        us_prefix = common.time_fn(lambda: prefix(svc_a, bid, b_a), iters=3)
        rows.append(common.row(f"auction/charges_rerun_N{n}", us_rerun, ""))
        rows.append(common.row(
            f"auction/charges_prefix_N{n}", us_prefix,
            f"speedup_vs_rerun={us_rerun / us_prefix:.1f}x "
            f"max_dev={dev_c:.2e}"))

    # ---- intra-service solve throughput (the Pallas kernel's workload)
    svc, _ = network.sample_services(jax.random.key(3), 10_000, k_max=32)
    b = jnp.full((10_000,), B / 100)
    us_intra = common.time_fn(
        lambda: intra.client_allocation_jit(svc, b), iters=3)
    rows.append(common.row("scale/intra_alloc_N10000", us_intra,
                           f"ns_per_service={1e3 * us_intra / 10_000:.1f}"))

    # ---- AllocationPolicy registry: every policy as one jitted call, N=1000
    svc_p, _ = network.sample_services(jax.random.key(5), 1_000, k_max=32)
    for name in policy.available():
        pfn = jax.jit(policy.get_policy(name))
        us = common.time_fn(lambda f=pfn: f(svc_p, B), iters=3)
        rows.append(common.row(f"policy/{name}_N1000", us,
                               f"us_per_service={us / 1_000:.2f}"))

    # ---- multi-period engines: one-compile lax.scan vs legacy Python loop
    sim_cfg = simulator.SimConfig(
        policy="coop", n_services_total=16, rounds_required=100,
        p_arrive=1.0, max_periods=64, k_max=32, seed=0,
    )
    us_scan = common.time_fn(lambda: simulator.run_scan(sim_cfg), iters=3)
    # Same median-of-iters discipline as every other row: a single
    # un-medianed run would commit host noise straight into the artifact.
    us_legacy = common.time_fn(lambda: simulator.run(sim_cfg), iters=3)
    rows.append(common.row("sim/scan_64periods", us_scan,
                           f"us_per_period={us_scan / 64:.1f} "
                           f"speedup_vs_loop={us_legacy / us_scan:.1f}x "
                           f"(scan runs all 64 periods; loop skips inactive "
                           f"ones and exits at completion)"))
    rows.append(common.row("sim/python_loop_64periods", us_legacy, ""))

    # ---- scenario sweep: the same compiled episode vmapped over 16 seeds
    us_batch = common.time_fn(
        lambda: simulator.run_batch(sim_cfg, seeds=range(16)), iters=3)
    rows.append(common.row("sim/batch_16seeds_64periods", us_batch,
                           f"us_per_episode={us_batch / 16:.1f} "
                           f"episodes_per_s={16e6 / us_batch:.1f}"))
    common.save_artifact("allocator_scale", [r for r in rows])
    return rows
