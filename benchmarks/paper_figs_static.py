"""Paper Figs. 4-10: DISBA convergence traces (4-5), pseudo-mBDF step
functions and the pseudo clearing price (6-7), auction welfare vs bid count M
(8), clearing price and total utility vs the fairness knob alpha (9-10)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import auction, disba, fairness, intra, network


def run() -> list[dict]:
    rows = []
    svc, meta = network.table1_service_set(jax.random.key(0))
    B, T = network.B_TOTAL_MHZ, network.PERIOD_S

    # ---- Figs 4-5: convergence traces
    hist = disba.disba_trace(svc, B, gamma=0.1, eps=1e-4)
    trace = [{
        "iter": j,
        "lam": hist["lam"][j],
        "freq": [float(v) * T for v in hist["f"][j]],
        "bandwidth": [float(v) for v in hist["b"][j]],
    } for j in range(hist["iterations"])]
    common.save_artifact("fig45_convergence", trace)
    rows.append(common.row("fig45/iterations", None,
                           f"iters={hist['iterations']} "
                           f"final_gap={hist['demand_gap'][-1]:.4f}"))

    # ---- Figs 6-7: pseudo-mBDF + aggregated + clearing price
    bid = auction.uniform_truthful_bids(svc, 5, 0.5)
    zeta = auction.clearing_price(bid, B)
    grid = jnp.linspace(0.01, float(jnp.max(bid.prices)) * 1.05, 64)
    agg = [float(jnp.sum(auction.pseudo_mbdf(bid, p, "left"))) for p in grid]
    common.save_artifact("fig67_pseudo_mbdf", {
        "prices": [float(p) for p in grid],
        "aggregate_demand": agg,
        "per_provider_bids": {
            "prices": bid.prices.tolist(),
            "demands": bid.demands.tolist()},
        "zeta": float(zeta),
    })
    rows.append(common.row("fig67/clearing_price", None, f"zeta={float(zeta):.4f}"))

    # ---- Fig 8: welfare vs M (auction -> exact mMCP as M grows)
    a = 0.5
    exact = fairness.exact_mmcp(svc, B, a)
    w_exact = float(jnp.sum(fairness.g_value(exact.f, a)))
    fig8 = []
    for m in (2, 3, 5, 8, 12, 20, 40):
        ar = auction.run_auction(svc, B, n_bids=m, alpha_fair=a)
        w = float(jnp.sum(fairness.g_value(ar.f, a)))
        fig8.append({"M": m, "welfare": w, "gap_vs_exact": w_exact - w})
        rows.append(common.row(f"fig8/M{m}", None,
                               f"welfare={w:.4f} gap={w_exact - w:.4f}"))
    common.save_artifact("fig8_bid_granularity", {"exact": w_exact, "sweep": fig8})

    # ---- Figs 9-10: zeta and total utility vs alpha
    fig910 = []
    for a in (0.0, 0.2, 0.4, 0.5, 0.6, 0.8, 1.0):
        ar = auction.run_auction(svc, B, n_bids=5, alpha_fair=a)
        tot_u = float(jnp.sum(ar.utilities))
        fig910.append({"alpha": a, "zeta": float(ar.price),
                       "total_utility": tot_u,
                       "total_freq": float(jnp.sum(ar.f))})
        rows.append(common.row(f"fig910/alpha{a}", None,
                               f"zeta={float(ar.price):.4f} utility={tot_u:.4f}"))
    common.save_artifact("fig910_alpha_tradeoff", fig910)
    return rows
