import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Scan-trip cost correction for §Roofline (see benchmarks/roofline.py).
#
# XLA cost_analysis counts while-loop bodies once, so the dry-run's
# whole-program numbers miss (trips-1) copies of every scanned block.  This
# script compiles each cell's block(s) IN ISOLATION on the same production
# mesh (inner loops disabled or shape-reduced with exact linear scaling) and
# writes artifacts/blocks/<tag>.json with per-component
# {flops, bytes_accessed, collective_bytes, trips}.
#
# Usage:
#   PYTHONPATH=src python -m benchmarks.block_costs [--mesh single|multi|both]

import argparse
import functools
import json
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.distributed import api as dist_api
from repro.distributed import sharding
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import registry, transformer, xlstm as xlstm_mod, encdec as encdec_mod
from repro.models.config import ModelConfig


def _measure(fn, args_sds, in_sh, mesh) -> dict:
    dist_api.set_mesh(mesh)
    try:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args_sds).compile()
    finally:
        dist_api.set_mesh(None)
    cost = compiled.cost_analysis() or {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": collective_bytes(compiled.as_text()),
    }


def _batch_axes(mesh):
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


def _x_sharding(mesh, batch, seq):
    axes = _batch_axes(mesh)
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    if batch % n == 0 and batch >= n:
        return NamedSharding(mesh, P(axes, None, None))
    return NamedSharding(mesh, P(None, None, None))


def _layer_params_sds(cfg: ModelConfig, use_moe: bool):
    return jax.eval_shape(
        lambda: transformer.init_layer(jax.random.key(0), cfg, use_moe))


def _grad_block(cfg, use_moe, is_global, seq, batch, train: bool,
                chunk_size: int):
    """(fwd[+bwd]) of one transformer-family block at (batch, seq)."""
    flag = jnp.bool_(is_global)

    def fwd(p_l, x, positions):
        y, _, aux = transformer.apply_layer(
            p_l, cfg, x, positions, use_moe=use_moe, is_global=flag,
            cache=None, cache_len=None, chunk_size=chunk_size)
        return y, aux

    if not train:
        return fwd

    ck = jax.checkpoint(fwd)

    def train_fn(p_l, x, positions):
        (y, aux), vjp = jax.vjp(lambda p, xx: ck(p, xx, positions), p_l, x)
        dp, dx = vjp((jnp.ones_like(y), jnp.ones_like(aux)))
        return dx, dp

    return train_fn


def _decode_block(cfg, use_moe, is_global, kv_len, batch):
    flag = jnp.bool_(is_global)

    def fn(p_l, x, positions, cache_l, cache_len):
        y, new_cache, _ = transformer.apply_layer(
            p_l, cfg, x, positions, use_moe=use_moe, is_global=flag,
            cache=cache_l, cache_len=cache_len, chunk_size=1024)
        return y, new_cache

    return fn


def _cache_slice_sds(cfg: ModelConfig, batch: int, max_len: int):
    model = registry.build_model(cfg)
    full = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    return {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
            for k, v in full.items() if k != "len"}


def _cache_slice_shardings(cfg, cache_sds, mesh):
    full_like = {k: jax.ShapeDtypeStruct((1, *v.shape), v.dtype)
                 for k, v in cache_sds.items()}
    full_like["len"] = jax.ShapeDtypeStruct((), jnp.int32)
    sh = sharding.cache_shardings(cfg, full_like, mesh)

    def drop_first(ns):
        spec = list(ns.spec)
        if len(spec) < 1:
            return ns
        return NamedSharding(mesh, P(*spec[1:]))

    return {k: drop_first(sh[k]) for k in cache_sds}


def transformer_components(cfg: ModelConfig, shape_name: str, mesh) -> list[dict]:
    seq, batch, kind = registry.SHAPES[shape_name]
    train = kind == "train"
    dt = cfg.compute_dtype
    psh = lambda tree: sharding.param_shardings(cfg, tree, mesh)
    comps = []

    axes = _batch_axes(mesh)
    data_n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        data_n *= mesh.shape[a]
    b_axes = axes if (batch % data_n == 0 and batch >= data_n) else None

    def pos_specs(s_eff):
        if cfg.mrope_sections:
            return (jax.ShapeDtypeStruct((3, batch, s_eff), jnp.int32),
                    NamedSharding(mesh, P(None, b_axes, None)))
        return (jax.ShapeDtypeStruct((batch, s_eff), jnp.int32),
                NamedSharding(mesh, P(b_axes, None)))

    def measure_block(use_moe, trips, is_global=True):
        p_sds = _layer_params_sds(cfg, use_moe)
        if kind == "decode":
            x = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dt)
            pos, pos_sh = pos_specs(1)
            cache_sds = _cache_slice_sds(cfg, batch, seq)
            fn = _decode_block(cfg, use_moe, is_global, seq, batch)
            in_sh = (psh(p_sds), _x_sharding(mesh, batch, 1), pos_sh,
                     _cache_slice_shardings(cfg, cache_sds, mesh),
                     NamedSharding(mesh, P()))
            args = (p_sds, x, pos, cache_sds,
                    jax.ShapeDtypeStruct((), jnp.int32))
        else:
            s_eff = seq
            x = jax.ShapeDtypeStruct((batch, s_eff, cfg.d_model), dt)
            pos, pos_sh = pos_specs(s_eff)
            chunk = min(1024, s_eff)
            fn = _grad_block(cfg, use_moe, is_global, s_eff, batch, train, chunk)
            in_sh = (psh(p_sds), _x_sharding(mesh, batch, s_eff), pos_sh)
            args = (p_sds, x, pos)
        m = _measure(fn, args, in_sh, mesh)
        # inner q-chunk loop (train/prefill): chunked attention bodies run
        # seq/chunk times but are counted once; scale the whole block cost by
        # an attention-dominance-free approximation is NOT safe, so instead we
        # lower with chunk = min(1024, seq) and accept the undercount only on
        # the attention score term for seq > 1024; the hillclimb cells use
        # exact single-chunk lowering (chunk=seq) where memory permits.
        m["trips"] = trips
        return m

    if cfg.n_experts and cfg.moe_every == 2:
        comps.append(measure_block(False, cfg.n_layers // 2))
        comps.append(measure_block(True, cfg.n_layers // 2))
    elif cfg.n_experts:
        n_lead = cfg.n_dense_leading
        comps.append(measure_block(True, cfg.n_layers - n_lead))
    else:
        comps.append(measure_block(False, cfg.n_layers))
    return comps


def _xlstm_x_sharding(cfg, mesh, batch, s_eff):
    """xlstm batches shard over every axis (pure-DP; see sharding rules)."""
    x_sds = {"tokens": jax.ShapeDtypeStruct((batch, s_eff), jnp.int32)}
    sh = sharding.batch_shardings(cfg, x_sds, mesh)["tokens"]
    spec = list(sh.spec) + [None]
    return NamedSharding(mesh, P(*spec))


def xlstm_components(cfg: ModelConfig, shape_name: str, mesh) -> list[dict]:
    seq, batch, kind = registry.SHAPES[shape_name]
    train = kind == "train"
    dt = cfg.compute_dtype
    n_super = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    psh = lambda tree: sharding.param_shardings(cfg, tree, mesh)
    comps = []

    # mLSTM block: costs have an S-independent part (per-layer FSDP weight
    # gathers, hoisted out of the chunk loop) and an S-linear part
    # (compute + activation traffic).  Measure at two chunk counts and
    # decompose: X(S) = a + b*(S/chunk), a = 2*X(1c) - X(2c), b = X(2c)-X(1c).
    s_eff = 1 if kind == "decode" else min(256, seq)
    scale = 1 if kind == "decode" else seq // s_eff
    m_sds = jax.eval_shape(
        lambda: xlstm_mod.init_mlstm_block(jax.random.key(0), cfg))

    if kind == "decode":
        d_inner = cfg.ssm_expand * cfg.d_model
        h = cfg.n_heads
        dh = d_inner // h
        state_sds = (
            jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, d_inner), dt),
            jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((batch, h), jnp.float32),
        )

        def m_fn(p_l, x, st):
            return xlstm_mod.apply_mlstm_block(p_l, cfg, x, st)

        x = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dt)
        in_sh = (psh(m_sds), _x_sharding(mesh, batch, 1),
                 jax.tree.map(lambda _: NamedSharding(mesh, P()), state_sds))
        m = _measure(m_fn, (m_sds, x, state_sds), in_sh, mesh)
    else:
        def m_fn(p_l, x):
            if train:
                def fwd(p, xx):
                    y, _ = xlstm_mod.apply_mlstm_block(p, cfg, xx)
                    return y
                ck = jax.checkpoint(fwd)
                y, vjp = jax.vjp(ck, p_l, x)
                dp, dx = vjp(jnp.ones_like(y))
                return dx
            y, _ = xlstm_mod.apply_mlstm_block(p_l, cfg, x)
            return y

        def measure_at(s_here):
            x = jax.ShapeDtypeStruct((batch, s_here, cfg.d_model), dt)
            in_sh = (psh(m_sds), _xlstm_x_sharding(cfg, mesh, batch, s_here))
            return _measure(m_fn, (m_sds, x), in_sh, mesh)

        m1 = measure_at(s_eff)
        if seq >= 2 * s_eff:
            m2 = measure_at(2 * s_eff)
            n_chunks = seq // s_eff

            def combine(x1, x2):
                a = max(2 * x1 - x2, 0.0)      # fixed (per layer-visit)
                b = max(x2 - x1, 0.0)          # per chunk
                return a + b * n_chunks

            m = {"flops": combine(m1["flops"], m2["flops"]),
                 "bytes_accessed": combine(m1["bytes_accessed"],
                                           m2["bytes_accessed"]),
                 "collective_bytes": {
                     k: combine(m1["collective_bytes"].get(k, 0),
                                m2["collective_bytes"].get(k, 0))
                     for k in set(m1["collective_bytes"])
                     | set(m2["collective_bytes"])}}
            scale = 1  # the decomposition already covers the full sequence
        else:
            m = m1
    m["trips"] = n_super * n_m * scale
    comps.append(m)

    # sLSTM block: sequential over S, but weight gathers are loop-invariant
    # (hoisted out of the time-step while); decompose fixed vs per-step via
    # two sequence lengths, as for the mLSTM component above.
    s_sds = jax.eval_shape(
        lambda: xlstm_mod.init_slstm_block(jax.random.key(0), cfg))

    def s_fn(p_l, x):
        y, _ = xlstm_mod.apply_slstm_block(p_l, cfg, x)
        return y

    def s_measure(s_here):
        xs = jax.ShapeDtypeStruct((batch, s_here, cfg.d_model), dt)
        return _measure(s_fn, (s_sds, xs),
                        (psh(s_sds), _xlstm_x_sharding(cfg, mesh, batch, s_here)),
                        mesh)

    if kind == "decode":
        ms = s_measure(1)
        ms["trips"] = n_super
    else:
        s1, s2 = s_measure(64), s_measure(128)

        def combine(x1, x2):
            a = max(2 * x1 - x2, 0.0)
            b = max(x2 - x1, 0.0) / 64.0
            return a + b * seq

        ms = {"flops": combine(s1["flops"], s2["flops"]),
              "bytes_accessed": combine(s1["bytes_accessed"], s2["bytes_accessed"]),
              "collective_bytes": {
                  k: combine(s1["collective_bytes"].get(k, 0),
                             s2["collective_bytes"].get(k, 0))
                  for k in set(s1["collective_bytes"]) | set(s2["collective_bytes"])}}
        ms["trips"] = n_super
    comps.append(ms)
    return comps


def encdec_components(cfg: ModelConfig, shape_name: str, mesh) -> list[dict]:
    seq, batch, kind = registry.SHAPES[shape_name]
    train = kind == "train"
    dt = cfg.compute_dtype
    psh = lambda tree: sharding.param_shardings(cfg, tree, mesh)
    comps = []
    model = encdec_mod.Seq2SeqLM(cfg)

    if kind == "decode":
        # decoder block with self cache (seq) + cross KV (seq)
        d_sds = jax.eval_shape(
            lambda: encdec_mod.init_decoder_layer(jax.random.key(0), cfg))
        kshape = (batch, seq, cfg.n_kv_heads, cfg.head_dim)

        def fn(p_l, x, k, v, xk, xv, cache_len):
            # inline the per-layer decode math via the model's stack on L=1
            params = {"embed": jnp.zeros((cfg.vocab_size, cfg.d_model)),
                      "dec_blocks": jax.tree.map(lambda a: a[None], p_l),
                      "ln_f": jnp.zeros((cfg.d_model,))}
            cache = {"len": cache_len, "k": k[None], "v": v[None],
                     "xk": xk[None], "xv": xv[None]}
            y, new_cache = model._decode_stack(params, x, None, cache)
            return y

        args = (d_sds,
                jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dt),
                jax.ShapeDtypeStruct(kshape, dt), jax.ShapeDtypeStruct(kshape, dt),
                jax.ShapeDtypeStruct(kshape, dt), jax.ShapeDtypeStruct(kshape, dt),
                jax.ShapeDtypeStruct((), jnp.int32))
        ksh = NamedSharding(mesh, P(_batch_axes(mesh), None, None, None))
        in_sh = (psh(d_sds), _x_sharding(mesh, batch, 1), ksh, ksh, ksh, ksh,
                 NamedSharding(mesh, P()))
        m = _measure(fn, args, in_sh, mesh)
        m["trips"] = cfg.n_layers
        comps.append(m)
        return comps

    # train / prefill: encoder block + decoder block over full seq
    e_sds = jax.eval_shape(
        lambda: encdec_mod.init_encoder_layer(jax.random.key(0), cfg))

    def enc_fn(p_l, x):
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

        def fwd(p, xx):
            from repro.models import layers as L
            h = L.rms_norm(xx, p["ln1"], cfg.norm_eps)
            a, _, _ = encdec_mod._mha(p["attn"], cfg, h, h, causal=False,
                                      positions_q=pos, positions_kv=pos)
            xx = xx + a
            h2 = L.rms_norm(xx, p["ln2"], cfg.norm_eps)
            return xx + L.apply_mlp(p["ffn"], h2, cfg.mlp_kind, xx.dtype)

        if train:
            y, vjp = jax.vjp(jax.checkpoint(fwd), p_l, x)
            dp, dx = vjp(jnp.ones_like(y))
            return dx
        return fwd(p_l, x)

    x = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dt)
    m_e = _measure(enc_fn, (e_sds, x),
                   (psh(e_sds), _x_sharding(mesh, batch, seq)), mesh)
    m_e["trips"] = cfg.n_encoder_layers
    comps.append(m_e)

    d_sds = jax.eval_shape(
        lambda: encdec_mod.init_decoder_layer(jax.random.key(0), cfg))

    def dec_fn(p_l, x, enc_out):
        def fwd(p, xx):
            pp = {"dec_blocks": jax.tree.map(lambda a: a[None], p)}
            y, _ = model._decode_stack(pp, xx, enc_out, None)
            return y

        if train:
            y, vjp = jax.vjp(jax.checkpoint(fwd), p_l, x)
            dp, dx = vjp(jnp.ones_like(y))
            return dx
        return fwd(p_l, x)

    tgt_len = seq
    xd = jax.ShapeDtypeStruct((batch, tgt_len, cfg.d_model), dt)
    enc_out = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dt)
    m_d = _measure(dec_fn, (d_sds, xd, enc_out),
                   (psh(d_sds), _x_sharding(mesh, batch, tgt_len),
                    _x_sharding(mesh, batch, seq)), mesh)
    m_d["trips"] = cfg.n_layers
    comps.append(m_d)
    return comps


def components_for(arch: str, shape_name: str, mesh) -> list[dict]:
    cfg = configs.get_config(arch)
    if cfg.family == "ssm":
        return xlstm_components(cfg, shape_name, mesh)
    if cfg.family == "encdec":
        return encdec_components(cfg, shape_name, mesh)
    return transformer_components(cfg, shape_name, mesh)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/blocks")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in configs.ARCH_NAMES:
        cfg = configs.get_config(arch)
        for shape_name in registry.SHAPES:
            if not registry.supports(cfg, shape_name):
                continue
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    continue
                try:
                    mesh = make_production_mesh(multi_pod=multi)
                    comps = components_for(arch, shape_name, mesh)
                    # the whole-program numbers already count each body ONCE,
                    # so the additive correction is (trips_i - 1) per component
                    out = {"tag": tag, "components": comps}
                    out["flops"] = sum(
                        c["flops"] * (c["trips"] - 1) for c in comps)
                    out["bytes_accessed"] = sum(
                        c["bytes_accessed"] * (c["trips"] - 1) for c in comps)
                    cb: dict[str, float] = {}
                    for c in comps:
                        for k, v in c["collective_bytes"].items():
                            cb[k] = cb.get(k, 0) + v * (c["trips"] - 1)
                    out["collective_bytes"] = cb
                    out["trips"] = 2  # roofline.py adds (2-1) x this correction
                    print(f"[block] {tag}: flops={out['flops']:.3e}")
                except Exception as e:  # noqa: BLE001
                    out = {"tag": tag, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2500:]}
                    print(f"[block] {tag}: ERROR {e}")
                with open(path, "w") as f:
                    json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
