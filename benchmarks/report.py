"""Render the §Roofline markdown table for EXPERIMENTS.md from the dry-run +
block-correction artifacts.

  PYTHONPATH=src python -m benchmarks.report > artifacts/roofline_table.md
"""
from __future__ import annotations

import json
import glob
import os

from benchmarks import roofline


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def main() -> None:
    cells = roofline.load_cells()
    print("### §Roofline table (single-pod 16x16 unless noted; per-chip terms"
          " per step, scan-corrected)\n")
    print("| arch | shape | mesh | compute | memory | collective |"
          " bottleneck | roofline-frac | useful-compute | what would move the"
          " dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    advice = {
        ("compute",): "already compute-dominated: larger per-chip batch or"
                      " better MXU utilization (fused kernels)",
        ("memory",): "fuse/skip HBM round-trips (flash kernels on TPU),"
                     " int8 KV for decode, fp8 weights",
        ("collective",): "reshard (more DP / less TP), overlap collectives"
                         " with compute, compress gradients",
    }
    for cell in cells:
        if cell.get("status") != "ok":
            continue
        tag = f"{cell['arch']}__{cell['shape']}__{cell['mesh']}"
        bpath = os.path.join("artifacts/blocks", tag + ".json")
        block, trips = None, 1
        if os.path.exists(bpath):
            with open(bpath) as f:
                b = json.load(f)
            if "error" not in b:
                block, trips = b, b.get("trips", 1)
        t = roofline.corrected_terms(cell, block, trips)
        note = advice[(t["bottleneck"],)]
        print(f"| {cell['arch']} | {cell['shape']} | {cell['mesh']} |"
              f" {fmt_s(t['compute_term_s'])} | {fmt_s(t['memory_term_s'])} |"
              f" {fmt_s(t['collective_term_s'])} | {t['bottleneck']} |"
              f" {t['roofline_fraction']:.2f} |"
              f" {min(t['useful_compute_fraction'], 9.99):.2f} | {note} |")

    # skipped cells
    print("\nSkipped cells (long_500k on pure-full-attention archs, by"
          " design): ", end="")
    skipped = sorted({c["arch"] for c in cells if c.get("status") == "skipped"})
    print(", ".join(skipped))


if __name__ == "__main__":
    main()
