"""Benchmark entry point: one module per paper table/figure plus the
fleet-scale allocator study and the roofline summary.  Emits
``name,us_per_call,derived`` CSV rows (us empty where the metric is a derived
quantity rather than a timing).

  PYTHONPATH=src python -m benchmarks.run [--only tables,static,...] [--full]

``--only allocation`` without ``--full`` runs the tiny (CI-smoke) sizes,
including the schema-v2 market N-sweep at toy N -- same code path and schema
validation as the full 64..8192-service sweep, seconds instead of minutes.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: tables,static,longterm,scale,"
                         "allocation,fleet,cotrain,serve,fault,robust,"
                         "roofline")
    ap.add_argument("--full", action="store_true",
                    help="paper-sized long-term sims (slow)")
    args = ap.parse_args()
    wanted = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0

    def section(name, fn):
        nonlocal failures
        if wanted is not None and name not in wanted:
            return
        try:
            from benchmarks import common
            common.emit(fn())
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name}/FAILED,,{traceback.format_exc().splitlines()[-1]}",
                  flush=True)

    from benchmarks import (allocator_scale, bench_allocation, bench_fault,
                            bench_fleet, bench_robust, bench_serve,
                            paper_figs_cotrain, paper_figs_longterm,
                            paper_figs_static, paper_tables, roofline)

    section("tables", paper_tables.run)
    section("static", paper_figs_static.run)
    section("longterm", lambda: paper_figs_longterm.run(full=args.full))
    section("scale", allocator_scale.run)
    section("allocation", lambda: bench_allocation.run_rows(tiny=not args.full))
    section("fleet", lambda: bench_fleet.run_rows(tiny=not args.full))
    section("cotrain", lambda: paper_figs_cotrain.run_rows(tiny=not args.full))
    section("serve", lambda: bench_serve.run_rows(tiny=not args.full))
    section("fault", lambda: bench_fault.run_rows(tiny=not args.full))
    section("robust", lambda: bench_robust.run_rows(tiny=not args.full))
    section("roofline", roofline.run)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
