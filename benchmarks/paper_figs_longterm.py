"""Paper Figs. 11-15: per-period policy comparison (11) and the long-term
multi-period simulations -- average service duration (12), client-count
heterogeneity sweep (13), channel heterogeneity sweep (14), arrival-rate
sweep (15) -- plus two scenario sweeps beyond the paper: temporally-
correlated Gauss-Markov fading (figS1) and bursty MMPP arrivals (figS2),
driven through the ``repro.scenarios`` registries.

All policies dispatch through the ``core.policy`` registry, and the
multi-period runs use the compiled scan engine's ``run_batch``: each
(policy, sweep-point) evaluates every seed in ONE compiled call (the
allocation step is traced once and vmapped over seeds).

Scaled for CI wall-clock: rounds_required=400 (paper: 2000), services=6
(paper: 10), 6 seeds (paper: 20 runs) -- the orderings the paper reports are
scale-invariant and asserted in tests/test_benchmarks.py.  Pass --full to
benchmarks.run for the paper-sized setting.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro import scenarios
from repro.core import network, policy
from repro.fl import simulator

POLICIES = simulator.POLICIES


def _per_period(seeds=range(6)) -> tuple[dict, dict]:
    """Fig 11: per-policy mean of the PF objective sum log(1+f) and of the
    total frequency sum f over random periods (5 services, clients ~
    N(20, var 10), channels ~ N(85, var 15))."""
    cfg_net = network.NetworkConfig(mean_clients=20, var_clients=10)
    obj = {p: [] for p in POLICIES}
    tot = {p: [] for p in POLICIES}
    for seed in seeds:
        svc, _ = network.sample_services(jax.random.key(seed), 5, cfg_net)
        B = cfg_net.total_bandwidth_mhz
        for pol in POLICIES:
            _, f = policy.allocate(pol, svc, B)
            obj[pol].append(float(jnp.sum(jnp.log1p(f))))
            tot[pol].append(float(jnp.sum(f)))
    stat = lambda d: {p: (float(np.mean(v)), float(np.std(v))) for p, v in d.items()}
    return stat(obj), stat(tot)


def _durations(policy_name: str, seeds, **overrides) -> tuple[float, float]:
    """Mean/std of avg service duration over seeds -- one compiled vmapped
    call per sweep point."""
    base = dict(n_services_total=6, rounds_required=400, p_arrive=5.0,
                max_periods=600, k_max=48)
    base.update(overrides)
    out = simulator.run_batch(
        simulator.SimConfig(policy=policy_name, **base), seeds=list(seeds)
    )
    if not bool(np.all(out["finished"])):
        print(f"[warn] {policy_name} {overrides}: "
              f"{int(np.sum(~out['finished']))} episode(s) hit max_periods")
    avg = out["avg_duration"]
    return float(np.mean(avg)), float(np.std(avg))


def run(full: bool = False) -> list[dict]:
    rows = []
    seeds = range(20 if full else 4)

    # ---- Fig 11 (both metrics: PF objective and total frequency -- the
    # paper's "overall performance" reads closest to the latter for the
    # selfish mechanism at alpha=0.5)
    fig11, fig11_f = _per_period(range(20 if full else 6))
    for pol, (mean, std) in fig11.items():
        rows.append(common.row(f"fig11/{pol}", None,
                               f"objective={mean:.4f}+-{std:.4f}"))
    for pol, (mean, std) in fig11_f.items():
        rows.append(common.row(f"fig11_totalfreq/{pol}", None,
                               f"sum_f={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig11_per_period",
                         {"objective": fig11, "total_freq": fig11_f})

    # ---- Fig 12: average duration per policy
    over = {"rounds_required": 2000, "n_services_total": 10,
            "max_periods": 3000} if full else {}
    fig12 = {}
    for pol in POLICIES:
        mean, std = _durations(pol, seeds, **over)
        fig12[pol] = (mean, std)
        rows.append(common.row(f"fig12/{pol}", None,
                               f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig12_duration", fig12)

    # ---- Fig 13: client-count heterogeneity (variance sweep)
    fig13 = {}
    for var in (0.0, 5.0, 15.0):
        for pol in ("coop", "es"):
            mean, std = _durations(pol, seeds, var_clients=var, **over)
            fig13[f"{pol}/var{var}"] = (mean, std)
            rows.append(common.row(f"fig13/{pol}/var{var}", None,
                                   f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig13_client_heterogeneity", fig13)

    # ---- Fig 14: channel heterogeneity (variance sweep)
    fig14 = {}
    for var in (0.0, 5.0, 15.0):
        for pol in ("coop", "es"):
            mean, std = _durations(pol, seeds, var_channel_db=var, **over)
            fig14[f"{pol}/var{var}"] = (mean, std)
            rows.append(common.row(f"fig14/{pol}/var{var}", None,
                                   f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig14_channel_heterogeneity", fig14)

    # ---- Fig 15: arrival interval sweep
    fig15 = {}
    for p_arrive in (1.0, 3.0, 5.0, 8.0):
        mean, std = _durations("coop", seeds, p_arrive=p_arrive, **over)
        fig15[p_arrive] = (mean, std)
        rows.append(common.row(f"fig15/p_arrive{p_arrive}", None,
                               f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig15_arrival", fig15)

    # ---- Scenario sweep A (beyond the paper): temporally-correlated fading.
    # rho = 0 is the paper's i.i.d. redraw; rising correlation lengthens the
    # episodes a policy spends stuck with an unlucky channel -- exactly the
    # regime the Fig. 13-14 robustness claims should be read against.
    figS1 = {}
    for rho in (0.0, 0.9, 0.99):
        for pol in ("coop", "es"):
            mean, std = _durations(
                pol, seeds,
                channel_process=scenarios.spec("gauss_markov", rho=rho), **over)
            figS1[f"{pol}/rho{rho}"] = (mean, std)
            rows.append(common.row(f"figS1_corr_fading/{pol}/rho{rho}", None,
                                   f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("figS1_correlated_fading", figS1)

    # ---- Scenario sweep B (beyond the paper): bursty MMPP arrivals at a
    # fixed long-run rate -- the load pattern that stresses the auction's
    # fairness-under-contention claim (Fig. 15).
    figS2 = {}
    for burst in (1.0, 4.0, 8.0):
        for pol in ("coop", "selfish"):
            mean, std = _durations(
                pol, seeds,
                arrival_process=scenarios.spec("mmpp", burst=burst, stay=0.8),
                **over)
            figS2[f"{pol}/burst{burst}"] = (mean, std)
            rows.append(common.row(f"figS2_bursty_arrivals/{pol}/burst{burst}",
                                   None, f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("figS2_bursty_arrivals", figS2)
    return rows
