"""Paper Figs. 11-15: per-period policy comparison (11) and the long-term
multi-period simulations -- average service duration (12), client-count
heterogeneity sweep (13), channel heterogeneity sweep (14), arrival-rate
sweep (15).

Scaled for CI wall-clock: rounds_required=400 (paper: 2000), services=6
(paper: 10), 6 seeds (paper: 20 runs) -- the orderings the paper reports are
scale-invariant and asserted in tests/test_benchmarks.py.  Pass --full to
benchmarks.run for the paper-sized setting.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import auction, baselines, disba, intra, network
from repro.fl import simulator

POLICIES = ("coop", "selfish", "ec", "es", "pp")


def _per_period(seeds=range(6)) -> dict:
    """Fig 11: mean objective sum log(1+f) per policy over random periods
    (5 services, clients ~ N(20, var 10), channels ~ N(85, var 15))."""
    cfg_net = network.NetworkConfig(mean_clients=20, var_clients=10)
    out = {p: [] for p in POLICIES}
    for seed in seeds:
        svc, _ = network.sample_services(jax.random.key(seed), 5, cfg_net)
        B = cfg_net.total_bandwidth_mhz
        for pol in POLICIES:
            if pol == "coop":
                f = disba.solve_lambda_bisect(svc, B).f
            elif pol == "selfish":
                bid = auction.uniform_truthful_bids(svc, 5, 0.5)
                b, _ = auction.allocate(bid, B)
                f = intra.freq(svc, b)
            elif pol == "ec":
                _, f = baselines.equal_client(svc, B)
            elif pol == "es":
                _, f = baselines.equal_service(svc, B)
            else:
                _, f = baselines.proportional(svc, B)
            out[pol].append(float(jnp.sum(jnp.log1p(f))))
    return {p: (float(np.mean(v)), float(np.std(v))) for p, v in out.items()}


def _per_period_total_freq(seeds=range(6)) -> dict:
    cfg_net = network.NetworkConfig(mean_clients=20, var_clients=10)
    out = {p: [] for p in POLICIES}
    for seed in seeds:
        svc, _ = network.sample_services(jax.random.key(seed), 5, cfg_net)
        B = cfg_net.total_bandwidth_mhz
        for pol in POLICIES:
            if pol == "coop":
                f = disba.solve_lambda_bisect(svc, B).f
            elif pol == "selfish":
                bid = auction.uniform_truthful_bids(svc, 5, 0.5)
                b, _ = auction.allocate(bid, B)
                f = intra.freq(svc, b)
            elif pol == "ec":
                _, f = baselines.equal_client(svc, B)
            elif pol == "es":
                _, f = baselines.equal_service(svc, B)
            else:
                _, f = baselines.proportional(svc, B)
            out[pol].append(float(jnp.sum(f)))
    return {p: (float(np.mean(v)), float(np.std(v))) for p, v in out.items()}


def _durations(policy: str, seeds, **overrides) -> tuple[float, float]:
    durs = []
    base = dict(n_services_total=6, rounds_required=400, p_arrive=5.0)
    base.update(overrides)
    for seed in seeds:
        out = simulator.run(simulator.SimConfig(policy=policy, seed=seed, **base))
        durs.append(out["avg_duration"])
    return float(np.mean(durs)), float(np.std(durs))


def run(full: bool = False) -> list[dict]:
    rows = []
    seeds = range(20 if full else 4)

    # ---- Fig 11 (both metrics: PF objective and total frequency -- the
    # paper's "overall performance" reads closest to the latter for the
    # selfish mechanism at alpha=0.5)
    fig11 = _per_period(range(20 if full else 6))
    for pol, (mean, std) in fig11.items():
        rows.append(common.row(f"fig11/{pol}", None,
                               f"objective={mean:.4f}+-{std:.4f}"))
    fig11_f = _per_period_total_freq(range(20 if full else 6))
    for pol, (mean, std) in fig11_f.items():
        rows.append(common.row(f"fig11_totalfreq/{pol}", None,
                               f"sum_f={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig11_per_period",
                         {"objective": fig11, "total_freq": fig11_f})

    # ---- Fig 12: average duration per policy
    over = {"rounds_required": 2000, "n_services_total": 10} if full else {}
    fig12 = {}
    for pol in POLICIES:
        mean, std = _durations(pol, seeds, **over)
        fig12[pol] = (mean, std)
        rows.append(common.row(f"fig12/{pol}", None,
                               f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig12_duration", fig12)

    # ---- Fig 13: client-count heterogeneity (variance sweep)
    fig13 = {}
    for var in (0.0, 5.0, 15.0):
        for pol in ("coop", "es"):
            mean, std = _durations(pol, seeds, var_clients=var, **over)
            fig13[f"{pol}/var{var}"] = (mean, std)
            rows.append(common.row(f"fig13/{pol}/var{var}", None,
                                   f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig13_client_heterogeneity", fig13)

    # ---- Fig 14: channel heterogeneity (variance sweep)
    fig14 = {}
    for var in (0.0, 5.0, 15.0):
        for pol in ("coop", "es"):
            mean, std = _durations(pol, seeds, var_channel_db=var, **over)
            fig14[f"{pol}/var{var}"] = (mean, std)
            rows.append(common.row(f"fig14/{pol}/var{var}", None,
                                   f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig14_channel_heterogeneity", fig14)

    # ---- Fig 15: arrival interval sweep
    fig15 = {}
    for p_arrive in (1.0, 3.0, 5.0, 8.0):
        mean, std = _durations("coop", seeds, p_arrive=p_arrive, **over)
        fig15[p_arrive] = (mean, std)
        rows.append(common.row(f"fig15/p_arrive{p_arrive}", None,
                               f"avg_duration={mean:.2f}+-{std:.2f}"))
    common.save_artifact("fig15_arrival", fig15)
    return rows
