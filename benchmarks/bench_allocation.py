"""Per-period allocation micro-benchmark -> repo-root ``BENCH_allocation.json``.

The long-term simulation re-solves the inter-service allocation every period;
this benchmark pins the wall-clock of that per-period solve so future PRs
have a perf trajectory (the first entry of the repo's BENCH series).

Measured on real wall-clock (jitted, median of repeats):

* ``coop`` market clearing at N services: the cold ``solve_lambda_bisect``
  (48 dual bisection trips x 48 inner trips per demand evaluation) vs the
  warm-started safeguarded Newton ``solve_lambda_newton_warm`` (<= 6 fused
  demand+slope evaluations seeded from the previous period's dual price).
  On CPU hosts the fused demand evaluation dispatches to the pure-jnp
  reference (the ``kernels/ops.dual_demand`` convention); the Pallas kernel
  itself is additionally timed in interpret mode for the record -- interpret
  timings validate numerics, they do not represent TPU performance.
* auction charge computation across an N sweep: the leave-one-out clearing
  rerun (O(N^2 M log NM)) vs the closed-form prefix-sum path (O(NM log NM)),
  with fitted log-log scaling exponents.
* (schema v2) the market N-sweep: warm + cold clearing wall-clock at
  N = 64 .. 8192 services per dual-solve backend -- the pure-jnp reference
  vs the whole-market ``market_clear`` megakernel (ONE fused launch for the
  entire safeguarded-Newton iteration; compiled on TPU, interpret mode
  recorded off-TPU) -- with fitted log-log scaling exponents and the
  megakernel's max deviation vs the reference finals at every swept N.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_allocation [--tiny] [--out PATH]

``--tiny`` shrinks every size for the CI smoke step (same schema, same
validation path, seconds instead of minutes).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import auction, disba, network
from repro.core.types import mask_inactive

SCHEMA = "bench_allocation/v2"
DEFAULT_OUT = "BENCH_allocation.json"

# Log-spaced market sizes; the smallest sits below the megakernel's 128-row
# tile (pad-up edge), the largest is the ROADMAP's 8192-service regime.
MARKET_NS_FULL = (64, 256, 1024, 4096, 8192)
MARKET_NS_TINY = (16, 48)
MARKET_MASKED_FRACTION = 0.1   # ~10% inactive fixed-capacity slots


def _fit_exponent(ns, us) -> float:
    """Least-squares slope of log(time) vs log(N)."""
    return float(np.polyfit(np.log(np.asarray(ns, float)),
                            np.log(np.asarray(us, float)), 1)[0])


def _bench_coop(n: int, k: int, repeats: int, time_kernel: bool) -> dict:
    svc, _ = network.sample_services(jax.random.key(2), n, k_max=k)
    B = network.B_TOTAL_MHZ
    ref = disba.solve_lambda_bisect(svc, B)
    # The "previous period" seed: the same market moved a few percent, the
    # temporal coherence the warm start exploits.
    lam_prev = ref.lam * jnp.float32(1.03)

    us_cold = common.time_fn(
        lambda: disba.solve_lambda_bisect(svc, B), iters=repeats)
    us_warm = common.time_fn(
        lambda: disba.solve_lambda_newton_warm(svc, B, lam_prev),
        iters=repeats)
    us_newton_cold = common.time_fn(
        lambda: disba.solve_lambda_newton(svc, B), iters=repeats)
    warm = disba.solve_lambda_newton_warm(svc, B, lam_prev)
    dev = float(jnp.max(jnp.abs(warm.b - ref.b)))

    out = {
        "n": n,
        "k": k,
        "cold_bisect_us": us_cold,
        "warm_newton_us": us_warm,
        "cold_newton12_us": us_newton_cold,
        "speedup_warm_vs_cold": us_cold / us_warm,
        "warm_vs_cold_max_dev_mhz": dev,
        "dual_evals": {"cold_bisect": disba.BISECT_ITERS,
                       "warm_newton": disba.WARM_ITERS},
    }
    if time_kernel:
        # Interpret-mode launch of the fused kernel (numerical deployment
        # path off-TPU is the jnp reference; this row only records that the
        # kernel runs and agrees -- see EXPERIMENTS.md §Perf).
        kern = jax.jit(lambda lp: disba.solve_lambda_newton_warm(
            svc, B, lp, backend="pallas"))
        out["warm_newton_kernel_interpret_us"] = common.time_fn(
            lambda: kern(lam_prev), iters=max(2, repeats // 3))
        out["kernel_vs_reference_max_dev_mhz"] = float(
            jnp.max(jnp.abs(kern(lam_prev).b - warm.b)))
    return out


def _bench_market(ns: tuple[int, ...], k: int, repeats: int) -> dict:
    """The schema-v2 N-sweep: cold + warm whole-market clearing per backend.

    ``reference`` is the pure-jnp solver (cold: 12 safeguarded-Newton trips
    with full-depth inner bisections; warm: the 6-trip warm-started variant).
    ``megakernel`` is the single fused ``market_clear`` Pallas launch behind
    ``backend="megakernel"`` with the *same* trip configuration -- compiled
    on TPU, interpret mode elsewhere (interpret timings validate numerics
    and scaling shape, not absolute TPU performance).  Every swept N also
    records the kernel's max deviation vs the reference finals on a masked
    market (~10% inactive fixed-capacity slots riding in the padding).
    """
    B = network.B_TOTAL_MHZ
    kernel_mode = ("compiled" if jax.default_backend() == "tpu"
                   else "interpret")
    sweep = []
    for n in ns:
        svc, _ = network.sample_services(jax.random.key(5), n, k_max=k)
        n_off = max(1, round(n * MARKET_MASKED_FRACTION))
        svc = mask_inactive(svc, jnp.arange(n) >= n_off)

        ref_cold = jax.jit(lambda s=svc: disba.solve_lambda_newton(s, B))
        ref_warm = jax.jit(lambda lp, s=svc: disba.solve_lambda_newton_warm(
            s, B, lp))
        kern_cold = jax.jit(lambda s=svc: disba.solve_lambda_newton_warm(
            s, B, disba.WARM_COLD, iters=12,
            newton_inner_iters=disba.BISECT_ITERS, backend="megakernel"))
        kern_warm = jax.jit(lambda lp, s=svc: disba.solve_lambda_newton_warm(
            s, B, lp, backend="megakernel"))

        # The "previous period" seed the warm paths exploit.
        lam_prev = ref_cold().lam * jnp.float32(1.03)
        warm = ref_warm(lam_prev)
        kwarm = kern_warm(lam_prev)
        dev = float(jnp.max(jnp.abs(kwarm.b - warm.b)))
        dev = max(dev, float(jnp.max(jnp.abs(kern_cold().b - ref_cold().b))))

        row = {
            "n": n,
            "k": k,
            "reference": {
                "cold_us": common.time_fn(ref_cold, iters=repeats),
                "warm_us": common.time_fn(lambda: ref_warm(lam_prev),
                                          iters=repeats),
            },
            "megakernel": {
                "mode": kernel_mode,
                "cold_us": common.time_fn(kern_cold, iters=repeats),
                "warm_us": common.time_fn(lambda: kern_warm(lam_prev),
                                          iters=repeats),
            },
            "max_dev_vs_reference_mhz": dev,
        }
        row["speedup_warm_vs_cold_reference"] = (
            row["reference"]["cold_us"] / row["reference"]["warm_us"])
        sweep.append(row)

    ns_list = [r["n"] for r in sweep]
    return {
        "ns": list(ns),
        "k": k,
        "masked_fraction": MARKET_MASKED_FRACTION,
        "kernel_mode": kernel_mode,
        "dual_trips": {"cold": 12, "warm": disba.WARM_ITERS},
        "sweep": sweep,
        "scaling_exponent": {
            "reference_cold": _fit_exponent(
                ns_list, [r["reference"]["cold_us"] for r in sweep]),
            "reference_warm": _fit_exponent(
                ns_list, [r["reference"]["warm_us"] for r in sweep]),
            "megakernel_cold": _fit_exponent(
                ns_list, [r["megakernel"]["cold_us"] for r in sweep]),
            "megakernel_warm": _fit_exponent(
                ns_list, [r["megakernel"]["warm_us"] for r in sweep]),
        },
        "note": ("interpret-mode megakernel timings exercise the exact "
                 "launch geometry off-TPU; absolute numbers are not TPU "
                 "performance"),
    }


def _bench_auction(ns: tuple[int, ...], k: int, n_bids: int,
                   repeats: int) -> dict:
    B = network.B_TOTAL_MHZ
    sweep = []
    for n in ns:
        svc, _ = network.sample_services(jax.random.key(3), n, k_max=k)
        bid = auction.uniform_truthful_bids(svc, n_bids, 0.5)
        b, _ = auction.allocate(bid, B)
        rerun = jax.jit(lambda s, bd, bb: auction.charges(
            s, bd, bb, B, 0.5, method="rerun"))
        prefix = jax.jit(lambda s, bd, bb: auction.charges(
            s, bd, bb, B, 0.5, method="prefix"))
        np.testing.assert_allclose(
            np.asarray(rerun(svc, bid, b)), np.asarray(prefix(svc, bid, b)),
            rtol=1e-3, atol=1e-3)
        us_rerun = common.time_fn(lambda: rerun(svc, bid, b), iters=repeats)
        us_prefix = common.time_fn(lambda: prefix(svc, bid, b), iters=repeats)
        sweep.append({"n": n, "rerun_us": us_rerun, "prefix_us": us_prefix,
                      "speedup": us_rerun / us_prefix})
    return {
        "n_bids": n_bids,
        "k": k,
        "sweep": sweep,
        "scaling_exponent": {
            "rerun": _fit_exponent([r["n"] for r in sweep],
                                   [r["rerun_us"] for r in sweep]),
            "prefix": _fit_exponent([r["n"] for r in sweep],
                                    [r["prefix_us"] for r in sweep]),
        },
    }


def run(tiny: bool = False, time_kernel: bool | None = None) -> dict:
    if time_kernel is None:
        time_kernel = tiny or jax.default_backend() == "tpu"
    coop_n, coop_k = (16, 8) if tiny else (64, 32)
    auction_ns = (8, 16, 32) if tiny else (32, 64, 128, 256, 512)
    market_ns = MARKET_NS_TINY if tiny else MARKET_NS_FULL
    repeats = 3 if tiny else 10
    return {
        "schema": SCHEMA,
        "tiny": tiny,
        **common.provenance(),
        "b_total_mhz": network.B_TOTAL_MHZ,
        "coop": _bench_coop(coop_n, coop_k, repeats, time_kernel),
        "auction_charges": _bench_auction(auction_ns, 8 if tiny else 16,
                                          5, repeats),
        "market_sweep": _bench_market(market_ns, 8 if tiny else 32,
                                      3 if tiny else 5),
    }


def validate(data: dict) -> None:
    """Schema check used by CI and tests: required keys present + parseable
    numbers."""
    assert data["schema"] == SCHEMA
    common.validate_provenance(data)
    coop = data["coop"]
    for key in ("cold_bisect_us", "warm_newton_us", "speedup_warm_vs_cold",
                "warm_vs_cold_max_dev_mhz"):
        assert isinstance(coop[key], (int, float)), key
    assert coop["dual_evals"]["warm_newton"] < coop["dual_evals"]["cold_bisect"]
    sweep = data["auction_charges"]["sweep"]
    assert len(sweep) >= 2
    for row in sweep:
        assert row["rerun_us"] > 0 and row["prefix_us"] > 0
    assert isinstance(
        data["auction_charges"]["scaling_exponent"]["prefix"], float)
    market = data["market_sweep"]
    assert market["kernel_mode"] in ("interpret", "compiled")
    assert len(market["sweep"]) >= 2
    if not data["tiny"]:
        assert max(market["ns"]) >= 4096, \
            "full runs must sweep the >=4096-service regime"
    for row in market["sweep"]:
        for backend in ("reference", "megakernel"):
            assert row[backend]["cold_us"] > 0 and row[backend]["warm_us"] > 0
        # exact-to-dtype across the whole sweep; the committed value is the
        # measured deviation, this is only the sanity ceiling
        assert row["max_dev_vs_reference_mhz"] < 1e-2, row["n"]
    for key in ("reference_cold", "reference_warm",
                "megakernel_cold", "megakernel_warm"):
        assert isinstance(market["scaling_exponent"][key], float), key


def run_rows(tiny: bool = False) -> list[dict]:
    """benchmarks.run adapter: execute the study, write the JSON, and return
    the usual ``name,us_per_call,derived`` rows.  Tiny (CI-sized) runs land
    in artifacts/bench/ so they never clobber the committed repo-root
    trajectory; full runs refresh ``BENCH_allocation.json`` itself."""
    data = run(tiny=tiny)
    validate(data)
    if tiny:
        common.save_artifact("bench_allocation_tiny", data)
    else:
        with open(DEFAULT_OUT, "w") as fp:
            json.dump(data, fp, indent=1, default=float)
            fp.write("\n")
    coop = data["coop"]
    rows = [
        common.row(f"allocation/coop_cold_bisect_N{coop['n']}",
                   coop["cold_bisect_us"], ""),
        common.row(f"allocation/coop_warm_newton_N{coop['n']}",
                   coop["warm_newton_us"],
                   f"speedup={coop['speedup_warm_vs_cold']:.1f}x "
                   f"max_dev={coop['warm_vs_cold_max_dev_mhz']:.2e}"),
    ]
    for row in data["auction_charges"]["sweep"]:
        rows.append(common.row(
            f"allocation/charges_prefix_N{row['n']}", row["prefix_us"],
            f"rerun_us={row['rerun_us']:.0f} speedup={row['speedup']:.1f}x"))
    exps = data["auction_charges"]["scaling_exponent"]
    rows.append(common.row(
        "allocation/charges_scaling", None,
        f"rerun_exp={exps['rerun']:.2f} prefix_exp={exps['prefix']:.2f}"))
    market = data["market_sweep"]
    for row in market["sweep"]:
        rows.append(common.row(
            f"allocation/market_megakernel_warm_N{row['n']}",
            row["megakernel"]["warm_us"],
            f"ref_warm_us={row['reference']['warm_us']:.0f} "
            f"mode={row['megakernel']['mode']} "
            f"max_dev={row['max_dev_vs_reference_mhz']:.2e}"))
    mexp = market["scaling_exponent"]
    rows.append(common.row(
        "allocation/market_scaling", None,
        f"ref_warm=N^{mexp['reference_warm']:.2f} "
        f"kernel_warm=N^{mexp['megakernel_warm']:.2f} "
        f"({market['kernel_mode']})"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, interpret-mode kernel row)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output path (default: {DEFAULT_OUT} at repo root)")
    args = ap.parse_args()
    data = run(tiny=args.tiny)
    validate(data)
    with open(args.out, "w") as fp:
        json.dump(data, fp, indent=1, default=float)
        fp.write("\n")
    coop = data["coop"]
    print(f"coop N={coop['n']}: cold {coop['cold_bisect_us']:.0f}us -> "
          f"warm {coop['warm_newton_us']:.0f}us "
          f"({coop['speedup_warm_vs_cold']:.1f}x)")
    for row in data["auction_charges"]["sweep"]:
        print(f"auction charges N={row['n']}: rerun {row['rerun_us']:.0f}us "
              f"prefix {row['prefix_us']:.0f}us ({row['speedup']:.1f}x)")
    exps = data["auction_charges"]["scaling_exponent"]
    print(f"charge scaling exponents: rerun N^{exps['rerun']:.2f} "
          f"prefix N^{exps['prefix']:.2f}")
    market = data["market_sweep"]
    for row in market["sweep"]:
        print(f"market N={row['n']}: ref cold "
              f"{row['reference']['cold_us']:.0f}us warm "
              f"{row['reference']['warm_us']:.0f}us | megakernel "
              f"({row['megakernel']['mode']}) cold "
              f"{row['megakernel']['cold_us']:.0f}us warm "
              f"{row['megakernel']['warm_us']:.0f}us "
              f"max_dev={row['max_dev_vs_reference_mhz']:.2e}")
    mexp = market["scaling_exponent"]
    print(f"market scaling exponents: ref cold N^"
          f"{mexp['reference_cold']:.2f} warm N^{mexp['reference_warm']:.2f} "
          f"| megakernel cold N^{mexp['megakernel_cold']:.2f} "
          f"warm N^{mexp['megakernel_warm']:.2f}")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
