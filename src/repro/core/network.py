"""Wireless-network and workload samplers reproducing the paper's §VI.A setup.

Defaults (paper values):
  * total bandwidth B = 10 MHz, period T = 20 s
  * noise power N0 = 1e-12 W
  * client count K_n ~ Normal(25, var 15), clipped to >= 2
  * path loss [dB]  ~ Normal(85, var 15)  (per-service mean, then per-client)
  * model size      ~ U[0.2, 0.5] Mbit (download = upload payload)
  * local training time ~ U[0.01, 0.05] s ; global aggregation 1e-5 s
  * uplink power   ~ U[0.05, 0.15] W ; downlink power ~ U[0.1, 0.3] W

Units follow repro.core.types: MHz / Mbit / seconds, so base rates are
bit/s/Hz and alpha = size/rate is in MHz*s.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import ServiceSet

B_TOTAL_MHZ = 10.0
PERIOD_S = 20.0
NOISE_W = 1e-12


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    total_bandwidth_mhz: float = B_TOTAL_MHZ
    period_s: float = PERIOD_S
    noise_w: float = NOISE_W
    mean_clients: float = 25.0
    var_clients: float = 15.0
    mean_pathloss_db: float = 85.0
    var_pathloss_db: float = 15.0       # across-service variance
    var_pathloss_client_db: float = 4.0  # within-service client spread
    model_mbit_lo: float = 0.2
    model_mbit_hi: float = 0.5
    t_local_lo: float = 0.01
    t_local_hi: float = 0.05
    t_global: float = 1e-5
    p_ul_lo: float = 0.05
    p_ul_hi: float = 0.15
    p_dl_lo: float = 0.1
    p_dl_hi: float = 0.3
    k_min: int = 2


def base_rate(power_w: jax.Array, pathloss_db: jax.Array, noise_w: float = NOISE_W) -> jax.Array:
    """Shannon spectral efficiency log2(1 + P*g/N0), g = 10^(-PL/10)."""
    gain = jnp.power(10.0, -pathloss_db / 10.0)
    return jnp.log2(1.0 + power_w * gain / noise_w)


def sample_client_counts(key, n: int, cfg: NetworkConfig) -> jax.Array:
    k = cfg.mean_clients + jnp.sqrt(cfg.var_clients) * jax.random.normal(key, (n,))
    return jnp.clip(jnp.round(k), cfg.k_min, None).astype(jnp.int32)


def channel_innovations(key: jax.Array, n_services: int, k_max: int) -> tuple[jax.Array, jax.Array]:
    """The exact standard-normal path-loss draws ``sample_services`` consumes.

    Returns ``(eps_service (N, 1), eps_client (N, K))`` from the same key
    split as ``sample_services(key, ...)`` -- this is the single definition
    of those draws; sample_services' i.i.d. branch calls it, so a stateful
    channel process (``repro.scenarios.channel``) that feeds these through
    an AR(1) filter with correlation 0 reproduces the i.i.d. draw bitwise
    by construction.
    """
    keys = jax.random.split(key, 8)
    return (jax.random.normal(keys[1], (n_services, 1)),
            jax.random.normal(keys[2], (n_services, k_max)))


def sample_services(
    key: jax.Array,
    n_services: int,
    cfg: NetworkConfig = NetworkConfig(),
    k_max: int | None = None,
    client_counts: jax.Array | None = None,
    channel_normals: tuple[jax.Array, jax.Array] | None = None,
    extra_pathloss_db: jax.Array | None = None,
) -> tuple[ServiceSet, dict]:
    """Draw a padded batch of services per §VI.A.  Returns (ServiceSet, meta).

    meta carries the raw draws (sizes, rates, powers) for benchmarks that need
    them (e.g. Table I reporting).  Shapes are rectangular (N, K_max) with a
    validity mask derived from the sampled client counts.

    ``channel_normals`` optionally replaces the path-loss standard normals
    (the pair ``channel_innovations`` returns) with externally-evolved ones —
    the hook used by temporally-correlated shadowing processes.
    ``extra_pathloss_db`` is an additive (N, K) dB term applied on top (fast
    fading).  Every other draw (sizes, powers, compute times) stays on the
    same key stream, so both hooks perturb *only* the channel.
    """
    keys = jax.random.split(key, 8)
    if client_counts is None:
        client_counts = sample_client_counts(keys[0], n_services, cfg)
    client_counts = jnp.asarray(client_counts, dtype=jnp.int32)
    if k_max is None:
        k_max = int(jnp.max(client_counts))
    mask = jnp.arange(k_max)[None, :] < client_counts[:, None]

    shape = (n_services, k_max)
    # Per-service average path loss, then per-client spread around it (Fig. 14).
    if channel_normals is None:
        eps_service, eps_client = channel_innovations(key, n_services, k_max)
    else:
        eps_service, eps_client = channel_normals
    pl_service = cfg.mean_pathloss_db + jnp.sqrt(cfg.var_pathloss_db) * eps_service
    pl_clients = pl_service + jnp.sqrt(cfg.var_pathloss_client_db) * eps_client
    if extra_pathloss_db is not None:
        pl_clients = pl_clients + extra_pathloss_db

    size_mbit = jax.random.uniform(
        keys[3], (n_services, 1), minval=cfg.model_mbit_lo, maxval=cfg.model_mbit_hi
    )
    p_ul = jax.random.uniform(keys[4], shape, minval=cfg.p_ul_lo, maxval=cfg.p_ul_hi)
    p_dl = jax.random.uniform(keys[5], (n_services, 1), minval=cfg.p_dl_lo, maxval=cfg.p_dl_hi)
    t_local = jax.random.uniform(keys[6], shape, minval=cfg.t_local_lo, maxval=cfg.t_local_hi)

    r_dl = base_rate(p_dl, pl_clients, cfg.noise_w)
    r_ul = base_rate(p_ul, pl_clients, cfg.noise_w)

    alpha = size_mbit / r_dl + size_mbit / r_ul
    alpha_ul = size_mbit / r_ul
    t_comp = t_local + cfg.t_global
    alpha = jnp.where(mask, alpha, 0.0).astype(jnp.float32)
    alpha_ul = jnp.where(mask, alpha_ul, 0.0).astype(jnp.float32)
    t_comp = jnp.where(mask, t_comp, 0.0).astype(jnp.float32)

    svc = ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask, alpha_ul=alpha_ul)
    meta = {
        "client_counts": client_counts,
        "pathloss_db": pl_clients,
        "size_mbit": size_mbit,
        "r_dl": r_dl,
        "r_ul": r_ul,
        "p_ul": p_ul,
        "p_dl": p_dl,
        "t_local": t_local,
    }
    return svc, meta


def table1_service_set(key: jax.Array, cfg: NetworkConfig = NetworkConfig()) -> tuple[ServiceSet, dict]:
    """The representative period of §VI.B: 5 services with 10/12/14/16/18 clients."""
    counts = jnp.array([10, 12, 14, 16, 18], dtype=jnp.int32)
    return sample_services(key, 5, cfg, k_max=18, client_counts=counts)
