"""Fairness-adjusted utilities and exact market clearing (paper §V.B).

The fairness-adjusted benefit of provider n is

    g_n(b) = (1 - alpha_fair) * f*_n(b) + alpha_fair * log(1 + f*_n(b))

(Eq. 21).  Its derivative defines the modified marginal valuation function
(mMVF)  q_n(b) = g'_n(b)  and its inverse the modified bandwidth demand
function (mBDF)  d_n(p) = (g'_n)^{-1}(p).  The modified market clearing price
(mMCP) zeta solves  sum_n d_n(zeta) = B  and the induced allocation maximizes
sum_n g_n(b_n) (Prop. 3).  alpha_fair = 0 recovers total-frequency
maximization (Prop. 2's MCP); alpha_fair = 1 recovers proportional fairness,
i.e. the cooperative DISBA solution.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import intra
from repro.core.types import BISECT_ITERS, ServiceSet

_TINY = 1e-30


def g_value(f: jax.Array, alpha_fair: float) -> jax.Array:
    """g_n expressed at frequency f (Eq. 21's benefit part)."""
    return (1.0 - alpha_fair) * f + alpha_fair * jnp.log1p(f)


def g_prime_at_f(svc: ServiceSet, f: jax.Array, alpha_fair: float) -> jax.Array:
    """q_n(b) = g'_n(b) at frequency f: [(1-a) + a/(1+f)] * f*'(b)."""
    w = (1.0 - alpha_fair) + alpha_fair / (1.0 + f)
    return w * intra.freq_prime_at_f(svc, f)


def fairness_cost(f: jax.Array, alpha_fair: float) -> jax.Array:
    """The ex-post fairness-adjusted charge alpha * (f - log(1+f)) (§V.B.2)."""
    return alpha_fair * (f - jnp.log1p(f))


def mbdf(
    svc: ServiceSet,
    price: jax.Array,
    alpha_fair: float,
    iters: int = BISECT_ITERS,
) -> jax.Array:
    """Modified bandwidth demand d_n(p) = (g'_n)^{-1}(p), batched over services.

    g'_n(b) is decreasing in b (concavity), so we bisect on f in
    [0, f_max): find f with q(f) = p, then map to b via Eq. 7.
    Demand is 0 for p >= q(0) = g'_n(0) = f*'(0) = 1/sum(alpha) (the weight
    [(1-a) + a/(1+f)] equals 1 at f=0, for any a).
    price: scalar or (N,).
    """
    price = jnp.broadcast_to(jnp.asarray(price, dtype=svc.alpha.dtype), (svc.n_services,))
    f_hi = intra.f_max(svc) * (1.0 - 1e-6)

    def h(f):  # q is decreasing in f; root of q(f) - p fits _bisect's convention
        return g_prime_at_f(svc, f, alpha_fair) - price

    f_star = intra._bisect(h, jnp.zeros_like(f_hi), f_hi, iters)
    f_star = jnp.where(price >= intra.p_max(svc), 0.0, f_star)
    return intra.bandwidth_from_freq(svc, f_star)


MBDF_BACKENDS = ("reference", "pallas")


def mbdf_grid(
    svc: ServiceSet,
    prices: jax.Array,
    alpha_fair: float,
    iters: int = BISECT_ITERS,
    backend: str = "reference",
) -> jax.Array:
    """Modified bandwidth demand at a whole (N, M) price grid in ONE joint
    bisection: the grid is flattened to an (N*M)-row replicated ServiceSet
    and handed to the scalar-price ``mbdf`` itself -- a single ``fori_loop``
    over the joint bracket instead of a vmap of M per-column solves, with
    the mMVF arithmetic keeping exactly one home.  Per element the ops are
    identical to the vmapped path, so the result matches it bitwise.

    ``backend="pallas"`` dispatches to the ``kernels/market_clear``
    (N, M)-grid kernel on the market tiling conventions instead: each
    (TILE_N, K) service tile streams from HBM once for all M price columns
    (no N*M row replication is ever materialized).  Exact-to-dtype against
    the reference (tests/test_market_clear.py).
    """
    prices = jnp.asarray(prices, dtype=svc.alpha.dtype)          # (N, M)
    if backend == "pallas":
        from repro.kernels import ops

        return ops.mbdf_demand(svc.alpha, svc.t_comp, prices, alpha_fair,
                               use_pallas=True, iters=iters)
    if backend != "reference":
        raise ValueError(f"unknown mbdf backend {backend!r}; "
                         f"expected one of {MBDF_BACKENDS}")
    n, m = prices.shape
    rep = ServiceSet(
        alpha=jnp.repeat(svc.alpha, m, axis=0),
        t_comp=jnp.repeat(svc.t_comp, m, axis=0),
        mask=jnp.repeat(svc.mask, m, axis=0),
    )
    return mbdf(rep, prices.reshape(-1), alpha_fair, iters).reshape(n, m)


class ClearingResult(NamedTuple):
    b: jax.Array      # (N,) allocation
    f: jax.Array      # (N,) resulting frequencies
    price: jax.Array  # () clearing price


@functools.partial(jax.jit, static_argnames=("alpha_fair", "iters", "inner_iters"))
def exact_mmcp(
    svc: ServiceSet,
    total_bandwidth: float,
    alpha_fair: float,
    iters: int = BISECT_ITERS,
    inner_iters: int = BISECT_ITERS,
) -> ClearingResult:
    """Full-information modified market clearing (Prop. 3): bisect the price
    until aggregate modified demand equals B.  The reference the multi-bid
    auction is an M-bid approximation of."""
    b_total = jnp.asarray(total_bandwidth, dtype=jnp.float32)
    p_hi = jnp.max(intra.p_max(svc))

    def h(p):
        return jnp.sum(mbdf(svc, p, alpha_fair, inner_iters)) - b_total

    price = intra._bisect(h, jnp.zeros_like(p_hi), p_hi, iters)
    b = mbdf(svc, price, alpha_fair, inner_iters)
    b = b * (b_total / jnp.maximum(jnp.sum(b), _TINY))
    return ClearingResult(b=b, f=intra.freq(svc, b, inner_iters), price=price)


def provider_utility(
    svc: ServiceSet, b: jax.Array, price: jax.Array, alpha_fair: float
) -> jax.Array:
    """u_n = f*(b) - p*b - alpha*(f*(b) - log(1+f*(b)))  (Eq. 21 with both charges)."""
    f = intra.freq(svc, b)
    return f - price * b - fairness_cost(f, alpha_fair)
