"""Benchmark allocation policies from §VI.D.

  * Equal-Client (EC):  every client network-wide gets B / sum_n K_n; no
    intra-service optimization (round gated by the worst client).
  * Equal-Service (ES): every service gets B / N, then splits it optimally.
  * Proportional (PP):  service n gets B * K_n / sum_j K_j, split optimally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import intra
from repro.core.types import ServiceSet, round_time_given_alloc


def equal_client(svc: ServiceSet, total_bandwidth: float) -> tuple[jax.Array, jax.Array]:
    """Returns (b_service, f) under uniform per-client bandwidth."""
    counts = svc.client_counts().astype(svc.alpha.dtype)
    per_client = total_bandwidth / jnp.maximum(jnp.sum(counts), 1.0)
    b_clients = jnp.where(svc.mask, per_client, 0.0)
    t = round_time_given_alloc(svc, b_clients)
    return counts * per_client, 1.0 / t


def equal_service(svc: ServiceSet, total_bandwidth: float) -> tuple[jax.Array, jax.Array]:
    active = svc.service_active()
    n_active = jnp.maximum(jnp.sum(active.astype(svc.alpha.dtype)), 1.0)
    b = jnp.where(active, total_bandwidth / n_active, 0.0).astype(svc.alpha.dtype)
    return b, intra.freq(svc, b)


def proportional(svc: ServiceSet, total_bandwidth: float) -> tuple[jax.Array, jax.Array]:
    counts = svc.client_counts().astype(svc.alpha.dtype)
    b = total_bandwidth * counts / jnp.maximum(jnp.sum(counts), 1.0)
    return b, intra.freq(svc, b)
