"""Unified AllocationPolicy interface over every inter-service allocator.

The paper evaluates five bandwidth-allocation regimes -- cooperative DISBA
(§IV), the fairness-adjusted selfish auction (§V), and the EC / ES / PP
benchmarks (§VI.D) -- and its long-term simulation re-runs the chosen one
every period.  Related work (e.g. arXiv:2011.12469) frames all of them as
instances of one periodic allocation step; this module is that frame:

    policy(svc: ServiceSet, b_total) -> (b, f)        # both (N,)

Every policy is a *pure jittable function* of a (possibly fixed-capacity,
mask-padded) ServiceSet.  Whole-service inactivity is expressed through the
client mask (see ``types.mask_inactive``): an all-masked row receives
b = f = 0 from every policy, so arrivals/departures in the multi-period
simulator are mask flips, not shape changes, and the whole episode compiles
once.

Policies are registered under string keys (``register`` /
``get_policy`` / ``available``), replacing the old if/elif dispatch in
``fl/simulator.py`` and ``launch/train.py``.

The intra-service sub-problem (Eq. 7: optimal round time + per-client
water-filling) is selectable via ``intra_backend``:

  * ``"reference"``  -- the pure-jnp fixed-trip bisection in ``core/intra``;
  * ``"pallas"``     -- the Pallas TPU kernel ``kernels/bisect_alloc`` (runs
                        in interpret mode off-TPU), the deployment path for
                        fleet-scale solves (EXPERIMENTS.md §Perf);
  * ``"megakernel"`` -- same intra-service kernel path, but ``coop``'s
                        *inter*-service dual solve additionally runs as ONE
                        fused ``kernels/market_clear`` launch (the whole
                        safeguarded-Newton iteration in VMEM) instead of one
                        ``dual_demand`` launch per trip -- the 1024-8192
                        service regime (EXPERIMENTS.md §Market scaling).

All backends solve the same equations with the same trip counts; parity is
asserted in tests/test_policy_simulator.py and tests/test_market_clear.py.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, NamedTuple, Protocol

import jax
import jax.numpy as jnp

from repro.core import auction, baselines, disba, intra
from repro.core.types import BISECT_ITERS, ServiceSet

INTRA_BACKENDS = ("reference", "pallas", "megakernel")

FreqFn = Callable[[ServiceSet, jax.Array], jax.Array]


class AllocationPolicy(Protocol):
    """A pure inter-service allocation step: (ServiceSet, B) -> (b, f)."""

    def __call__(
        self, svc: ServiceSet, b_total: jax.Array | float
    ) -> tuple[jax.Array, jax.Array]:
        ...


class StatefulPolicy(NamedTuple):
    """A policy with an optional fixed-shape carry threaded between periods.

    ``init_state(n) -> state`` builds the carry for an n-slot fixed-capacity
    set (an arbitrary pytree of arrays -- or ``()`` for stateless policies);
    ``step(svc, B, state) -> (b, f, state')`` is the per-period allocation.
    The carry's tree structure and array shapes are fixed at init, so the
    multi-period simulator threads it through its ``lax.scan`` carry and the
    period step still traces exactly once.

    Warm-started policies (``warm_start=True``) carry solver state -- e.g.
    ``coop`` carries the previous period's dual price, seeding a safeguarded
    Newton clear that replaces the 48-trip cold bisection.  Policies without
    a warm variant get the trivial wrapper (empty carry), so every
    (policy, warm_start) combination is valid.

    Batching contract: ``init_state`` must be a *pure, key-free* function of
    the slot count -- no RNG, no data-dependent shapes.  The sweep engines
    (``run_batch``'s vmap, ``run_fleet``'s shard_map of chunked vmaps) trace
    it once per episode batch, broadcasting the constant init across the
    seed axis and each device shard; a stateful init would need a key
    threaded per episode and would break the bitwise equivalence between
    sharded/chunked and flat sweeps.
    """

    init_state: Callable[[int], Any]
    step: Callable[..., tuple[jax.Array, jax.Array, Any]]


# ---------------------------------------------------------------------------
# Intra-service backend selection (reference jnp vs Pallas kernel).
# ---------------------------------------------------------------------------

def _pallas_solve(svc: ServiceSet, b: jax.Array, iters: int):
    """(t*, per-client split) via the kernel -- compiled on TPU, interpret
    elsewhere (the ``ops.intra_allocate`` dispatch convention)."""
    from repro.kernels import ops

    return ops.intra_allocate(svc.alpha, svc.t_comp, b, use_pallas=True,
                              iters=iters)


def _intra_impl(intra_backend: str) -> str:
    """Collapse the backend name to the intra-service implementation.

    ``"megakernel"`` changes only the *inter*-service dual solve (one fused
    ``market_clear`` launch); its intra-service sub-problems (round time /
    client split) ride the same ``bisect_alloc`` kernel as ``"pallas"``.
    """
    return "pallas" if intra_backend == "megakernel" else intra_backend


def freq_fn(intra_backend: str = "reference", iters: int = BISECT_ITERS) -> FreqFn:
    """f*(b) with the chosen intra-service solver backend."""
    intra_backend = _intra_impl(intra_backend)
    if intra_backend == "reference":
        return lambda svc, b: intra.freq(svc, b, iters)
    if intra_backend == "pallas":

        def _freq(svc: ServiceSet, b: jax.Array) -> jax.Array:
            t_star, _ = _pallas_solve(svc, b, iters)
            # kernel reports t* ~ 1/TINY for b <= 0 rows; map those to f = 0
            return jnp.where(
                jnp.logical_and(b > 0.0, t_star < 1e20),
                1.0 / jnp.maximum(t_star, 1e-30), 0.0,
            )

        return _freq
    raise ValueError(f"unknown intra backend {intra_backend!r}; "
                     f"expected one of {INTRA_BACKENDS}")


def client_split_fn(
    intra_backend: str = "reference", iters: int = BISECT_ITERS
) -> Callable[[ServiceSet, jax.Array], jax.Array]:
    """Per-client water-filling split b_{n,k} with the chosen backend."""
    intra_backend = _intra_impl(intra_backend)
    if intra_backend == "reference":
        return lambda svc, b: intra.client_allocation(svc, b, iters)
    if intra_backend == "pallas":
        return lambda svc, b: _pallas_solve(svc, b, iters)[1]
    raise ValueError(f"unknown intra backend {intra_backend!r}; "
                     f"expected one of {INTRA_BACKENDS}")


def round_time_fn(
    intra_backend: str = "reference", iters: int = BISECT_ITERS
) -> Callable[[ServiceSet, jax.Array], jax.Array]:
    """Optimal round time t*_n(b_n) with the chosen backend ((N,) seconds;
    +inf for b <= 0 rows).  The co-simulation derives per-round straggler
    deadlines from this -- same solver family as the allocation itself, so
    the deadline is consistent with the allocated latencies."""
    intra_backend = _intra_impl(intra_backend)
    if intra_backend == "reference":
        return lambda svc, b: intra.solve_round_time(svc, b, iters)
    if intra_backend == "pallas":

        def _t(svc: ServiceSet, b: jax.Array) -> jax.Array:
            t_star, _ = _pallas_solve(svc, b, iters)
            # kernel reports t* ~ 1/TINY for b <= 0 rows; map those to +inf
            return jnp.where(
                jnp.logical_and(b > 0.0, t_star < 1e20), t_star, jnp.inf)

        return _t
    raise ValueError(f"unknown intra backend {intra_backend!r}; "
                     f"expected one of {INTRA_BACKENDS}")


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., AllocationPolicy]] = {}


def register(name: str):
    """Register a policy factory under ``name``.

    A factory takes keyword options (n_bids, alpha_fair, intra_backend, ...)
    and returns the pure allocation function.  Factories are free to ignore
    options they don't use.
    """

    def deco(factory: Callable[..., AllocationPolicy]):
        _REGISTRY[name] = factory
        return factory

    return deco


def available() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_policy(
    name: str,
    *,
    n_bids: int = 5,
    alpha_fair: float = 0.5,
    intra_backend: str = "reference",
    iters: int = BISECT_ITERS,
    **unknown,
) -> AllocationPolicy:
    """Build the named policy, wrapped so inactive slots get b = f = 0.

    Unknown keyword options raise a ValueError: factories ignore options
    they don't use, so a typo (``alpha_fiar=...``) would otherwise be
    silently swallowed and the default used instead.
    """
    if name not in _REGISTRY:
        raise ValueError(f"unknown policy {name!r}; available: {available()}")
    if unknown:
        raise ValueError(
            f"unknown option(s) {sorted(unknown)} for policy {name!r}; "
            f"known options: {list(KNOWN_OPTIONS)}")
    raw = _REGISTRY[name](
        n_bids=n_bids, alpha_fair=alpha_fair,
        intra_backend=intra_backend, iters=iters,
    )

    def wrapped(svc: ServiceSet, b_total):
        b, f = raw(svc, b_total)
        active = svc.service_active()
        # EC's min-rate round time is -inf on an empty row -> clamp, then mask.
        b = jnp.where(active, b, 0.0)
        f = jnp.where(active, jnp.maximum(f, 0.0), 0.0)
        return b, f

    return wrapped


# Derived from the signature so the unknown-option error can never list a
# stale set of known options.
KNOWN_OPTIONS = tuple(sorted(
    p.name for p in inspect.signature(get_policy).parameters.values()
    if p.kind == inspect.Parameter.KEYWORD_ONLY))


def allocate(name: str, svc: ServiceSet, b_total, **options):
    """One-shot convenience: ``get_policy(name, **options)(svc, b_total)``."""
    return get_policy(name, **options)(svc, b_total)


# ---------------------------------------------------------------------------
# Stateful (warm-startable) policies.
# ---------------------------------------------------------------------------

_STATEFUL_REGISTRY: dict[str, Callable[..., StatefulPolicy]] = {}


def register_stateful(name: str):
    """Register the warm-started (carry-threading) variant of a policy.

    The factory takes the same keyword options as the stateless one and
    returns a ``StatefulPolicy``.  Only policies that can exploit temporal
    coherence register here; every other name falls back to the trivial
    empty-carry wrapper in ``get_stateful_policy``.
    """

    def deco(factory: Callable[..., StatefulPolicy]):
        _STATEFUL_REGISTRY[name] = factory
        return factory

    return deco


def get_stateful_policy(
    name: str,
    *,
    warm_start: bool = False,
    n_bids: int = 5,
    alpha_fair: float = 0.5,
    intra_backend: str = "reference",
    iters: int = BISECT_ITERS,
    **unknown,
) -> StatefulPolicy:
    """Build the named policy in carry-threading form.

    ``warm_start=False`` (or a policy without a registered warm variant)
    wraps the stateless policy with an empty carry, so the step function is
    *identical* to ``get_policy``'s -- the default simulator path stays
    bitwise-unchanged.  ``warm_start=True`` selects the registered stateful
    variant where one exists (``coop``: previous-period dual price seeding a
    safeguarded-Newton market clear).
    """
    if name not in _REGISTRY:
        raise ValueError(f"unknown policy {name!r}; available: {available()}")
    if unknown:
        raise ValueError(
            f"unknown option(s) {sorted(unknown)} for policy {name!r}; "
            f"known options: {list(STATEFUL_KNOWN_OPTIONS)}")
    if warm_start and name in _STATEFUL_REGISTRY:
        raw = _STATEFUL_REGISTRY[name](
            n_bids=n_bids, alpha_fair=alpha_fair,
            intra_backend=intra_backend, iters=iters,
        )

        def step(svc: ServiceSet, b_total, state):
            b, f, state = raw.step(svc, b_total, state)
            active = svc.service_active()
            b = jnp.where(active, b, 0.0)
            f = jnp.where(active, jnp.maximum(f, 0.0), 0.0)
            return b, f, state

        return StatefulPolicy(init_state=raw.init_state, step=step)

    fn = get_policy(name, n_bids=n_bids, alpha_fair=alpha_fair,
                    intra_backend=intra_backend, iters=iters)

    def stateless_step(svc: ServiceSet, b_total, state):
        b, f = fn(svc, b_total)
        return b, f, state

    return StatefulPolicy(init_state=lambda n: (), step=stateless_step)


STATEFUL_KNOWN_OPTIONS = tuple(sorted(
    p.name for p in inspect.signature(get_stateful_policy).parameters.values()
    if p.kind == inspect.Parameter.KEYWORD_ONLY))


# ---------------------------------------------------------------------------
# The five paper policies.
# ---------------------------------------------------------------------------

@register("coop")
def _coop(*, intra_backend: str = "reference", iters: int = BISECT_ITERS, **_):
    """Cooperative DISBA via direct market clearing (same optimum as Alg. 1)."""
    _freq = freq_fn(intra_backend, iters)

    def fn(svc: ServiceSet, b_total):
        if intra_backend == "megakernel":
            # Cold fused clear: one launch runs 12 safeguarded-Newton trips
            # (matches solve_lambda_newton's cold configuration, which
            # reaches the bisect optimum to solver tolerance).
            res = disba.solve_lambda_newton_warm(
                svc, b_total, disba.WARM_COLD, iters=12, inner_iters=iters,
                newton_inner_iters=iters, backend="megakernel")
            return res.b, res.f
        res = disba.solve_lambda_bisect(svc, b_total, inner_iters=iters)
        # the dual solve is backend-independent; only the final f*(b)
        # evaluation goes through the selected intra backend
        f = res.f if intra_backend == "reference" else _freq(svc, res.b)
        return res.b, f

    return fn


class WarmDualState(NamedTuple):
    """Carry of the warm-started coop policy: the previous period's dual
    price plus a running count of cold-bisection rescues
    (``DisbaResult.fallback`` events -- non-finite inputs/seed/outputs).
    Fixed-shape, so it threads through ``lax.scan`` and checkpoints like the
    old scalar carry did."""

    lam: jax.Array        # () float32 dual price (WARM_COLD = no seed)
    fallbacks: jax.Array  # () int32 cumulative solver fallbacks


def fallback_count(pol_state) -> int:
    """Cumulative solver-fallback count carried in a policy state (0 for
    policies without one) -- the control plane mirrors this into its
    ``solver_fallbacks`` metric."""
    if isinstance(pol_state, WarmDualState):
        return int(pol_state.fallbacks)
    return 0


@register_stateful("coop")
def _coop_warm(*, intra_backend: str = "reference", iters: int = BISECT_ITERS,
               **_):
    """Warm-started cooperative DISBA: the previous period's dual price rides
    in the scan carry and seeds a safeguarded-Newton market clear
    (``disba.solve_lambda_newton_warm``), cutting the ~48 cold bisection
    trips to <= ``disba.WARM_ITERS`` fused demand evaluations.  With the
    ``pallas`` backend each dual iteration is one ``dual_demand`` kernel
    launch; with ``megakernel`` the WHOLE warm clear -- every trip plus the
    final demand/frequency evaluation -- is one ``market_clear`` launch."""
    _freq = freq_fn(intra_backend, iters)
    backend = (intra_backend if intra_backend in ("pallas", "megakernel")
               else "reference")

    def init_state(n: int):
        return WarmDualState(lam=jnp.float32(disba.WARM_COLD),
                             fallbacks=jnp.int32(0))

    def step(svc: ServiceSet, b_total, state):
        res = disba.solve_lambda_newton_warm(
            svc, b_total, state.lam, inner_iters=iters, backend=backend)
        # megakernel emits f from the same launch; reference's res.f is
        # already the reference evaluation.
        f = (res.f if intra_backend in ("reference", "megakernel")
             else _freq(svc, res.b))
        # Only carry the price out of periods that actually cleared a market;
        # an all-inactive period would otherwise poison the seed with 0.
        lam_next = jnp.where(jnp.any(svc.service_active()), res.lam, state.lam)
        state_next = WarmDualState(
            lam=lam_next,
            fallbacks=state.fallbacks
            + jnp.asarray(res.fallback, jnp.int32))
        return res.b, f, state_next

    return StatefulPolicy(init_state=init_state, step=step)


@register("selfish")
def _selfish(*, n_bids: int = 5, alpha_fair: float = 0.5,
             intra_backend: str = "reference", iters: int = BISECT_ITERS, **_):
    """Fairness-adjusted multi-bid auction with truthful uniform bids (§V.E)."""
    _freq = freq_fn(intra_backend, iters)

    def fn(svc: ServiceSet, b_total):
        bid = auction.uniform_truthful_bids(svc, n_bids, alpha_fair, iters=iters)
        b, _ = auction.allocate(bid, b_total)
        return b, _freq(svc, b)

    return fn


@register("ec")
def _ec(**_):
    """Equal-Client benchmark: uniform per-client bandwidth, no intra solve."""

    def fn(svc: ServiceSet, b_total):
        return baselines.equal_client(svc, b_total)

    return fn


@register("es")
def _es(*, intra_backend: str = "reference", iters: int = BISECT_ITERS, **_):
    """Equal-Service benchmark: B / N_active each, optimal intra split."""
    _freq = freq_fn(intra_backend, iters)

    def fn(svc: ServiceSet, b_total):
        b, f = baselines.equal_service(svc, b_total)
        if intra_backend != "reference":
            f = _freq(svc, b)
        return b, f

    return fn


@register("pp")
def _pp(*, intra_backend: str = "reference", iters: int = BISECT_ITERS, **_):
    """Proportional benchmark: B * K_n / sum K, optimal intra split."""
    _freq = freq_fn(intra_backend, iters)

    def fn(svc: ServiceSet, b_total):
        b, f = baselines.proportional(svc, b_total)
        if intra_backend != "reference":
            f = _freq(svc, b)
        return b, f

    return fn
