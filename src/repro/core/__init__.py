"""Core contribution of the paper: two-level bandwidth allocation for
multiple concurrent FL services (intra-service water-filling, cooperative
DISBA, fairness-adjusted multi-bid auction)."""

from repro.core.types import (  # noqa: F401
    BISECT_ITERS,
    RawServiceParams,
    ServiceSet,
    make_service_set,
    mask_inactive,
    round_time_given_alloc,
    stack_services,
)
from repro.core import (  # noqa: F401
    auction,
    baselines,
    disba,
    fairness,
    intra,
    network,
    policy,
)
