"""Fairness-adjusted multi-bid auction (paper §V.A-§V.E).

Each provider n submits M bids s_n = {(b^m_n, p^m_n)} with prices ascending.
A truthful bid satisfies p^m = g'_n(b^m) (Definition 1), i.e. the demands are
the modified-BDF evaluated at the price grid.  The operator:

  1. builds per-provider *pseudo-mBDF* step functions (Eq. 22),
  2. aggregates them and finds the pseudo market clearing price
     zeta = sup{ p : d_bar(p) > B }  (Eq. 25),
  3. allocates demand-at-zeta+ plus a proportional split of the surplus
     (Eq. 26),
  4. charges the exclusion-compensation (second-price) term plus the ex-post
     fairness cost (Eq. 27).

Everything is vectorized over (N providers, M bids): clearing is a sort +
prefix-sum over the N*M bid prices (O(NM log NM)); leave-one-out reruns for
the charges are a vmap over exclusion masks.  No Python loops over providers.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fairness, intra
from repro.core.types import BISECT_ITERS, ServiceSet

_TINY = 1e-30


class MultiBid(NamedTuple):
    prices: jax.Array   # (N, M) ascending in m
    demands: jax.Array  # (N, M) non-increasing in m (mBDF is decreasing)


class AuctionResult(NamedTuple):
    b: jax.Array          # (N,) allocated bandwidth
    f: jax.Array          # (N,) realized FL frequencies
    price: jax.Array      # () pseudo-mMCP zeta
    charges: jax.Array    # (N,) total payments (Eq. 27)
    utilities: jax.Array  # (N,) f - charges (Eq. 28)


# ---------------------------------------------------------------------------
# Bidding (§V.E uniform multi-bid example).
# ---------------------------------------------------------------------------

def uniform_truthful_bids(
    svc: ServiceSet,
    n_bids: int,
    alpha_fair: float,
    p_reserve: float = 0.0,
    p_max_bound: jax.Array | None = None,
    iters: int = BISECT_ITERS,
    backend: str = "reference",
) -> MultiBid:
    """Operator announces M prices uniformly on (p0, p_max_n) (Eq. 34); a
    truthful provider answers with its mBDF demand at each price.

    ``backend`` selects the joint-bisection implementation
    (``fairness.mbdf_grid``): ``"reference"`` (default, pinned paths stay
    bitwise-unchanged) or ``"pallas"`` (the tiled (N, M) grid kernel for
    thousand-service books)."""
    pmax = intra.p_max(svc) if p_max_bound is None else jnp.asarray(p_max_bound)
    m = jnp.arange(1, n_bids + 1, dtype=svc.alpha.dtype)
    prices = p_reserve + m[None, :] * (pmax[:, None] - p_reserve) / (n_bids + 1)
    # One joint (N, M) bisection (bitwise-equal to the per-column vmap it
    # replaced, single fused fori_loop instead of M solves).
    demands = fairness.mbdf_grid(svc, prices, alpha_fair, iters,
                                 backend=backend)
    return MultiBid(prices=prices, demands=demands)


# ---------------------------------------------------------------------------
# Pseudo step functions (Eqns. 22-23).
# ---------------------------------------------------------------------------

def pseudo_mbdf(bid: MultiBid, p: jax.Array, side: str = "left") -> jax.Array:
    """Evaluate every provider's pseudo-mBDF at scalar price p -> (N,).

    side='left'  : the (left-continuous) value  d_bar(p)   (Eq. 22)
    side='right' : the limit from above         d_bar(p+)
    """
    idx = jax.vmap(lambda pr: jnp.searchsorted(pr, p, side=side))(bid.prices)
    ext = jnp.concatenate(
        [bid.demands, jnp.zeros_like(bid.demands[:, :1])], axis=1
    )  # demand above the top bid price is 0
    return jnp.take_along_axis(ext, idx[:, None], axis=1)[:, 0]


def pseudo_mmvf_integral(bid: MultiBid, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """integral_{lo}^{hi} q_bar_n(b) db per provider -> (N,).

    q_bar_n (Eq. 23) is piecewise constant: value p^m on (b^{m+1}, b^m]
    (with b^{M+1} = 0), and 0 above b^1.  lo, hi: (N,) with hi >= lo.
    """
    upper = bid.demands                                        # (N, M)  b^m
    lower = jnp.concatenate(
        [bid.demands[:, 1:], jnp.zeros_like(bid.demands[:, :1])], axis=1
    )                                                          # (N, M)  b^{m+1}
    seg = jnp.clip(jnp.minimum(hi[:, None], upper) - jnp.maximum(lo[:, None], lower), 0.0)
    return jnp.sum(bid.prices * seg, axis=1)


# ---------------------------------------------------------------------------
# Clearing + allocation (Eqns. 25-26).
# ---------------------------------------------------------------------------

def clearing_price(
    bid: MultiBid, total_bandwidth: float, p_reserve: float = 0.0,
    weights: jax.Array | None = None,
) -> jax.Array:
    """zeta = sup{ p : d_bar(p) > B } via descending-price prefix sums.

    As the price drops past p^m_n, provider n's aggregate contribution jumps
    by delta = b^m_n - b^{m+1}_n >= 0.  Sorting all N*M (price, delta) pairs by
    descending price (``_sorted_book``, shared with the leave-one-out /
    prefix-charge paths), the prefix sum at a price equals d_bar at that
    price.  Ties are handled by validating only the last entry of each
    equal-price run.  ``weights`` (N,) in {0,1} excludes providers
    (leave-one-out reruns) by reweighting the sorted deltas -- the price
    order itself is weight-independent.
    """
    n, m = bid.prices.shape
    book = _sorted_book(bid)
    p_sorted = book.p_sorted
    if weights is None:
        csum = book.csum
    else:
        w_sorted = jnp.broadcast_to(
            weights[:, None], (n, m)).reshape(-1)[book.order]
        csum = jnp.cumsum(book.d_sorted * w_sorted)            # d_bar at each price
    # d_bar(p_i) must include *all* bids at price == p_i -> only the last
    # element of an equal-price run carries the correct prefix sum.
    is_last = jnp.concatenate([p_sorted[:-1] > p_sorted[1:], jnp.ones((1,), bool)])
    exceeds = jnp.logical_and(jnp.logical_and(csum > total_bandwidth, is_last),
                              p_sorted > p_reserve)
    # Highest price whose run exceeds B.  (exceeds is monotone along the
    # descending order once true, so the first True has the largest price.)
    any_exceeds = jnp.any(exceeds)
    first_idx = jnp.argmax(exceeds)
    zeta = jnp.where(any_exceeds, p_sorted[first_idx],
                     jnp.asarray(p_reserve, p_sorted.dtype))
    return zeta


def _allocate_at_price(
    bid: MultiBid, zeta: jax.Array, total_bandwidth: float, weights: jax.Array
) -> jax.Array:
    """The Eq. 26 allocation rule evaluated at a *known* clearing price."""
    d_left = pseudo_mbdf(bid, zeta, side="left") * weights
    d_right = pseudo_mbdf(bid, zeta, side="right") * weights
    agg_right = jnp.sum(d_right)
    jump = d_left - d_right
    agg_jump = jnp.sum(jump)
    surplus = jnp.maximum(total_bandwidth - agg_right, 0.0)
    share = jnp.where(agg_jump > _TINY, jump / jnp.maximum(agg_jump, _TINY) * surplus, 0.0)
    return d_right + share


def allocate(
    bid: MultiBid,
    total_bandwidth: float,
    p_reserve: float = 0.0,
    weights: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Bandwidth allocation rule (Eq. 26).  Returns (b, zeta).

    b_n = d_bar_n(zeta+) + [d_bar_n(zeta) - d_bar_n(zeta+)] /
          [d_bar(zeta) - d_bar(zeta+)] * (B - d_bar(zeta+))
    """
    w = jnp.ones((bid.prices.shape[0],), bid.prices.dtype) if weights is None else weights
    zeta = clearing_price(bid, total_bandwidth, p_reserve, weights=w)
    return _allocate_at_price(bid, zeta, total_bandwidth, w), zeta


class _SortedBook(NamedTuple):
    """The joint bid book sorted once by descending price, plus the prefix
    sums every clearing / leave-one-out quantity is read from.  The single
    home of the book construction: ``clearing_price``, the leave-one-out
    prices, and the prefix-sum charges all consume this."""

    delta: jax.Array     # (N, M) demand increments b^m - b^{m+1} >= 0
    order: jax.Array     # (NM,) flat index -> sorted position permutation
    p_sorted: jax.Array  # (NM,) descending prices
    d_sorted: jax.Array  # (NM,) delta in sorted order
    csum: jax.Array      # (NM,) prefix demand:  d_bar at each sorted entry
    vsum: jax.Array      # (NM,) prefix of p * delta: sum_j F_j(d_j(p+))
    pos_desc: jax.Array  # (N, M) each provider's entry ranks, descending price


def _sorted_book(bid: MultiBid) -> _SortedBook:
    nxt = jnp.concatenate(
        [bid.demands[:, 1:], jnp.zeros_like(bid.demands[:, :1])], axis=1)
    delta = bid.demands - nxt                                  # (N, M) >= 0
    flat_p = bid.prices.reshape(-1)
    order = jnp.argsort(-flat_p)                               # descending
    p_sorted = flat_p[order]
    d_sorted = delta.reshape(-1)[order]
    inv = jnp.argsort(order)                                   # flat -> rank
    # n's entries in processing (descending-price) order = ascending rank;
    # prices ascend in m, so reverse the bid axis.
    n, m = bid.prices.shape
    return _SortedBook(
        delta=delta, order=order, p_sorted=p_sorted, d_sorted=d_sorted,
        csum=jnp.cumsum(d_sorted),
        vsum=jnp.cumsum(p_sorted * d_sorted),
        pos_desc=inv.reshape(n, m)[:, ::-1],
    )


def _prefix_at(prefix: jax.Array, count: jax.Array) -> jax.Array:
    """Prefix-sum value after ``count`` sorted entries (0 for count == 0)."""
    return jnp.where(count > 0, prefix[jnp.maximum(count - 1, 0)], 0.0)


def _count_above(book: _SortedBook, zeta: jax.Array, strict: bool) -> jax.Array:
    """How many sorted entries have price > zeta (strict) or >= zeta."""
    nm = book.p_sorted.shape[0]
    asc = book.p_sorted[::-1]
    side = "right" if strict else "left"
    return nm - jnp.searchsorted(asc, zeta, side=side)


def leave_one_out_prices(
    bid: MultiBid, total_bandwidth: float, p_reserve: float = 0.0
) -> jax.Array:
    """All N leave-one-out clearing prices zeta(s_{-n}) from ONE sorted book.

    The rerun formulation re-sorts the N*M bid book once per excluded
    provider: O(N^2 M log NM).  This computes every zeta_{-n} from a single
    descending-price sort + prefix sums: the excluded aggregate
    d_bar_{-n}(p_i) = csum_i - cn_i is non-decreasing along the sorted order,
    and cn_i (provider n's own cumulative demand) is piecewise constant with
    steps only at n's M bid positions -- so within each of n's M+1 segments a
    ``searchsorted`` against the global prefix sums finds the first index
    whose excluded demand exceeds B.  The minimum over segments is the
    leave-one-out clearing index: O(NM log NM) total.

    Ties are safe: the first raw index whose excluded prefix exceeds B shares
    its price with the last entry of its equal-price run (the excluded prefix
    is monotone within a run), which is exactly the entry ``clearing_price``
    validates.
    """
    return _loo_prices(_sorted_book(bid), total_bandwidth, p_reserve)


def _loo_prices(
    book: _SortedBook, total_bandwidth: float, p_reserve: float = 0.0
) -> jax.Array:
    n, m = book.delta.shape
    nm = n * m
    # cn on segment s (= rank ranges holding exactly s of n's entries):
    # cumulative own demand above that point; v[:, 0] = 0 above n's top bid.
    zero_col = jnp.zeros((n, 1), dtype=book.delta.dtype)
    own_cum = jnp.cumsum(book.delta[:, ::-1], axis=1)               # (N, M)
    v = jnp.concatenate([zero_col, own_cum], axis=1)                # (N, M+1)
    izero = jnp.zeros((n, 1), dtype=book.pos_desc.dtype)
    lo = jnp.concatenate([izero, book.pos_desc], axis=1)            # (N, M+1)
    hi = jnp.concatenate(
        [book.pos_desc, jnp.full((n, 1), nm, book.pos_desc.dtype)], axis=1)
    # First rank with csum > B + cn_s (strict, matching clearing_price).
    first_in_seg = jnp.searchsorted(book.csum, total_bandwidth + v,
                                    side="right")
    cand = jnp.maximum(first_in_seg.astype(book.pos_desc.dtype), lo)
    valid = cand < hi
    first = jnp.min(jnp.where(valid, cand, nm), axis=1)             # (N,)
    p_at = book.p_sorted[jnp.minimum(first, nm - 1)]
    found = jnp.logical_and(first < nm, p_at > p_reserve)
    return jnp.where(found, p_at,
                     jnp.asarray(p_reserve, book.p_sorted.dtype))


# ---------------------------------------------------------------------------
# Charging (Eq. 27) + full auction run.
# ---------------------------------------------------------------------------

CHARGE_METHODS = ("prefix", "rerun")


def charges(
    svc: ServiceSet,
    bid: MultiBid,
    b_alloc: jax.Array,
    total_bandwidth: float,
    alpha_fair: float,
    p_reserve: float = 0.0,
    method: str = "prefix",
) -> jax.Array:
    """c_n = sum_{j != n} int_{b_j(s)}^{b_j(s_-n)} q_bar_j + alpha*(f_n - log(1+f_n)).

    The leave-one-out allocations b_j(s_{-n}) need the clearing outcome with
    provider n's bids excluded.  ``method="prefix"`` (default) computes every
    exclusion's social cost in closed form from ONE sorted book
    (``_social_cost_prefix``): O(NM log NM) total, nothing rescans, re-sorts,
    or materializes an (N, N) matrix per provider.  ``method="rerun"`` is the
    original formulation (a vmap of full clearing reruns over the N exclusion
    masks, O(N^2 M log NM)), kept as the parity reference and benchmark
    baseline."""
    n = bid.prices.shape[0]

    if method == "rerun":
        eye = jnp.eye(n, dtype=bid.prices.dtype)

        def without(mask_row):
            b_wo, _ = allocate(bid, total_bandwidth, p_reserve,
                               weights=1.0 - mask_row)
            return b_wo

        b_without = jax.vmap(without)(eye)                      # (N excl, N provider)
        lo = jnp.minimum(b_alloc[None, :], b_without)
        hi = jnp.maximum(b_alloc[None, :], b_without)
        # Social opportunity cost: others' valuation of the bandwidth they
        # lose to n's presence.  b_j(s_-n) >= b_j(s) for j != n (n's absence
        # frees bandwidth), so the integral is taken on [b_j(s), b_j(s_-n)].
        integrals = jax.vmap(
            lambda l, h: pseudo_mmvf_integral(bid, l, h))(lo, hi)  # (N, N)
        off_diag = integrals * (1.0 - jnp.eye(n, dtype=integrals.dtype))
        social_cost = jnp.sum(off_diag, axis=1)
    elif method == "prefix":
        social_cost = _social_cost_prefix(bid, b_alloc, total_bandwidth,
                                          p_reserve)
    else:
        raise ValueError(f"unknown charges method {method!r}; "
                         f"expected one of {CHARGE_METHODS}")
    f_real = intra.freq(svc, b_alloc)
    return social_cost + fairness.fairness_cost(f_real, alpha_fair)


def _social_cost_prefix(
    bid: MultiBid, b_alloc: jax.Array, total_bandwidth: float,
    p_reserve: float = 0.0,
) -> jax.Array:
    """sum_{j != n} [F_j(b_j(s_{-n})) - F_j(b_j(s))] for every n, in
    O(NM log NM), where F_j(x) = int_0^x q_bar_j is the cumulative pseudo-mMVF.

    Three identities collapse the leave-one-out rerun to prefix-sum reads at
    the N excluded clearing prices zeta_n (``_loo_prices``):

    * F_j(b^m_j) - F_j(b^{m+1}_j) = p^m_j * delta^m_j, so the aggregate
      G(zeta) = sum_j F_j(d_j(zeta+)) is the prefix sum of p*delta along the
      SAME descending-price order the clearing uses;
    * every non-jumping provider (no bid priced exactly zeta_n) is allocated
      exactly d_j(zeta_n+), so sum_{j!=n} F_j(b_j(s_{-n})) starts from
      G(zeta_n) - F_n(d_n(zeta_n+));
    * jumping providers split the surplus *within* the price-zeta_n segment
      where q_bar_j == zeta_n exactly, so their corrections sum to
      zeta_n * s_n * aggjump_n = zeta_n * surplus_n in closed form.

    Exact-arithmetic equality with ``method="rerun"`` holds for books whose
    prices sit strictly above ``p_reserve`` and whose surplus share stays
    within the jump segment -- both guaranteed for ``uniform_truthful_bids``
    books; float reassociation differs at tolerance level.
    """
    book = _sorted_book(bid)
    zetas = _loo_prices(book, total_bandwidth, p_reserve)        # (N,)
    cnt_gt = _count_above(book, zetas, strict=True)
    cnt_ge = _count_above(book, zetas, strict=False)
    g_at = _prefix_at(book.vsum, cnt_gt)       # sum_j F_j(d_j(zeta+))
    agg_right_all = _prefix_at(book.csum, cnt_gt)   # d_bar(zeta+)
    agg_left_all = _prefix_at(book.csum, cnt_ge)    # d_bar(zeta)

    own_gt = bid.prices > zetas[:, None]                          # (N, M)
    own_eq = bid.prices == zetas[:, None]
    d_right_own = jnp.sum(jnp.where(own_gt, book.delta, 0.0), axis=1)
    f_own = jnp.sum(jnp.where(own_gt, bid.prices * book.delta, 0.0), axis=1)
    jump_own = jnp.sum(jnp.where(own_eq, book.delta, 0.0), axis=1)

    agg_right = agg_right_all - d_right_own    # sum_{j!=n} d_j(zeta_n+)
    agg_jump = agg_left_all - agg_right_all - jump_own
    surplus = jnp.maximum(total_bandwidth - agg_right, 0.0)
    jump_corr = jnp.where(agg_jump > _TINY, zetas * surplus, 0.0)

    # F_j at the actual full-book allocation, summed once.
    f_at_alloc = pseudo_mmvf_integral(
        bid, jnp.zeros_like(b_alloc), b_alloc)                   # (N,)
    others_at_alloc = jnp.sum(f_at_alloc) - f_at_alloc

    social = (g_at - f_own + jump_corr) - others_at_alloc
    # >= 0 in exact arithmetic (n's absence can only free bandwidth for the
    # others); clamp the float residue.
    return jnp.maximum(social, 0.0)


@functools.partial(jax.jit, static_argnames=("n_bids", "alpha_fair"))
def run_auction(
    svc: ServiceSet,
    total_bandwidth: float,
    n_bids: int = 5,
    alpha_fair: float = 0.5,
    p_reserve: float = 0.0,
) -> AuctionResult:
    """End-to-end fairness-adjusted multi-bid auction with truthful bidders."""
    bid = uniform_truthful_bids(svc, n_bids, alpha_fair, p_reserve)
    b, zeta = allocate(bid, total_bandwidth, p_reserve)
    c = charges(svc, bid, b, total_bandwidth, alpha_fair, p_reserve)
    f = intra.freq(svc, b)
    return AuctionResult(b=b, f=f, price=zeta, charges=c, utilities=f - c)


# ---------------------------------------------------------------------------
# Incentive diagnostics (Prop. 5, Eq. 31).
# ---------------------------------------------------------------------------

def delta_bound(
    svc: ServiceSet,
    bid: MultiBid,
    alpha_fair: float,
    p_reserve: float = 0.0,
) -> jax.Array:
    """The truthfulness gap Delta_n = max_m int_{d(p^{m+1})}^{d(p^m)} (q(b) - p^m) db
    (Eq. 31) against the *true* mBDF/mMVF.  Because q = g', the integral is
    exact in closed form:

        int_lo^hi (q(b) - p) db = [g(b_hi) - g(b_lo)] - p * (b_hi - b_lo),

    with g evaluated through f*(b).  Small Delta ==> truthful bidding is an
    ex-post Delta-Nash equilibrium (Prop. 5)."""
    n, m = bid.prices.shape
    pmax = intra.p_max(svc)
    # p^0 = p_reserve, p^1..p^M from the bids, p^{M+1} = q(0) = p_max.
    prices_ext = jnp.concatenate(
        [jnp.full((n, 1), p_reserve, bid.prices.dtype), bid.prices, pmax[:, None]], axis=1
    )  # (N, M+2)
    d_ext = jax.vmap(
        lambda p_col: fairness.mbdf(svc, p_col, alpha_fair), in_axes=1, out_axes=1
    )(prices_ext)                                                 # (N, M+2)
    f_ext = jax.vmap(
        lambda b_col: intra.freq(svc, b_col), in_axes=1, out_axes=1
    )(d_ext)
    g_ext = fairness.g_value(f_ext, alpha_fair)                   # (N, M+2)

    b_hi, b_lo = d_ext[:, :-1], d_ext[:, 1:]                      # segments m=0..M
    g_hi, g_lo = g_ext[:, :-1], g_ext[:, 1:]
    seg = (g_hi - g_lo) - prices_ext[:, :-1] * (b_hi - b_lo)
    return jnp.max(seg, axis=1)
