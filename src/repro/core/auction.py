"""Fairness-adjusted multi-bid auction (paper §V.A-§V.E).

Each provider n submits M bids s_n = {(b^m_n, p^m_n)} with prices ascending.
A truthful bid satisfies p^m = g'_n(b^m) (Definition 1), i.e. the demands are
the modified-BDF evaluated at the price grid.  The operator:

  1. builds per-provider *pseudo-mBDF* step functions (Eq. 22),
  2. aggregates them and finds the pseudo market clearing price
     zeta = sup{ p : d_bar(p) > B }  (Eq. 25),
  3. allocates demand-at-zeta+ plus a proportional split of the surplus
     (Eq. 26),
  4. charges the exclusion-compensation (second-price) term plus the ex-post
     fairness cost (Eq. 27).

Everything is vectorized over (N providers, M bids): clearing is a sort +
prefix-sum over the N*M bid prices (O(NM log NM)); leave-one-out reruns for
the charges are a vmap over exclusion masks.  No Python loops over providers.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fairness, intra
from repro.core.types import BISECT_ITERS, ServiceSet

_TINY = 1e-30


class MultiBid(NamedTuple):
    prices: jax.Array   # (N, M) ascending in m
    demands: jax.Array  # (N, M) non-increasing in m (mBDF is decreasing)


class AuctionResult(NamedTuple):
    b: jax.Array          # (N,) allocated bandwidth
    f: jax.Array          # (N,) realized FL frequencies
    price: jax.Array      # () pseudo-mMCP zeta
    charges: jax.Array    # (N,) total payments (Eq. 27)
    utilities: jax.Array  # (N,) f - charges (Eq. 28)


# ---------------------------------------------------------------------------
# Bidding (§V.E uniform multi-bid example).
# ---------------------------------------------------------------------------

def uniform_truthful_bids(
    svc: ServiceSet,
    n_bids: int,
    alpha_fair: float,
    p_reserve: float = 0.0,
    p_max_bound: jax.Array | None = None,
    iters: int = BISECT_ITERS,
) -> MultiBid:
    """Operator announces M prices uniformly on (p0, p_max_n) (Eq. 34); a
    truthful provider answers with its mBDF demand at each price."""
    pmax = intra.p_max(svc) if p_max_bound is None else jnp.asarray(p_max_bound)
    m = jnp.arange(1, n_bids + 1, dtype=svc.alpha.dtype)
    prices = p_reserve + m[None, :] * (pmax[:, None] - p_reserve) / (n_bids + 1)
    demands = jax.vmap(
        lambda p: fairness.mbdf(svc, p, alpha_fair, iters), in_axes=1, out_axes=1
    )(prices)
    return MultiBid(prices=prices, demands=demands)


# ---------------------------------------------------------------------------
# Pseudo step functions (Eqns. 22-23).
# ---------------------------------------------------------------------------

def pseudo_mbdf(bid: MultiBid, p: jax.Array, side: str = "left") -> jax.Array:
    """Evaluate every provider's pseudo-mBDF at scalar price p -> (N,).

    side='left'  : the (left-continuous) value  d_bar(p)   (Eq. 22)
    side='right' : the limit from above         d_bar(p+)
    """
    idx = jax.vmap(lambda pr: jnp.searchsorted(pr, p, side=side))(bid.prices)
    ext = jnp.concatenate(
        [bid.demands, jnp.zeros_like(bid.demands[:, :1])], axis=1
    )  # demand above the top bid price is 0
    return jnp.take_along_axis(ext, idx[:, None], axis=1)[:, 0]


def pseudo_mmvf_integral(bid: MultiBid, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """integral_{lo}^{hi} q_bar_n(b) db per provider -> (N,).

    q_bar_n (Eq. 23) is piecewise constant: value p^m on (b^{m+1}, b^m]
    (with b^{M+1} = 0), and 0 above b^1.  lo, hi: (N,) with hi >= lo.
    """
    upper = bid.demands                                        # (N, M)  b^m
    lower = jnp.concatenate(
        [bid.demands[:, 1:], jnp.zeros_like(bid.demands[:, :1])], axis=1
    )                                                          # (N, M)  b^{m+1}
    seg = jnp.clip(jnp.minimum(hi[:, None], upper) - jnp.maximum(lo[:, None], lower), 0.0)
    return jnp.sum(bid.prices * seg, axis=1)


# ---------------------------------------------------------------------------
# Clearing + allocation (Eqns. 25-26).
# ---------------------------------------------------------------------------

def clearing_price(
    bid: MultiBid, total_bandwidth: float, p_reserve: float = 0.0,
    weights: jax.Array | None = None,
) -> jax.Array:
    """zeta = sup{ p : d_bar(p) > B } via descending-price prefix sums.

    As the price drops past p^m_n, provider n's aggregate contribution jumps
    by delta = b^m_n - b^{m+1}_n >= 0.  Sorting all N*M (price, delta) pairs by
    descending price, the prefix sum at a price equals d_bar at that price.
    Ties are handled by validating only the last entry of each equal-price run.
    ``weights`` (N,) in {0,1} excludes providers (leave-one-out reruns).
    """
    n, m = bid.prices.shape
    nxt = jnp.concatenate([bid.demands[:, 1:], jnp.zeros_like(bid.demands[:, :1])], axis=1)
    delta = bid.demands - nxt                                  # (N, M) >= 0
    if weights is not None:
        delta = delta * weights[:, None]
    flat_p = bid.prices.reshape(-1)
    flat_d = delta.reshape(-1)
    order = jnp.argsort(-flat_p)                               # descending prices
    p_sorted = flat_p[order]
    csum = jnp.cumsum(flat_d[order])                           # d_bar at each price
    # d_bar(p_i) must include *all* bids at price == p_i -> only the last
    # element of an equal-price run carries the correct prefix sum.
    is_last = jnp.concatenate([p_sorted[:-1] > p_sorted[1:], jnp.ones((1,), bool)])
    exceeds = jnp.logical_and(jnp.logical_and(csum > total_bandwidth, is_last),
                              p_sorted > p_reserve)
    # Highest price whose run exceeds B.  (exceeds is monotone along the
    # descending order once true, so the first True has the largest price.)
    any_exceeds = jnp.any(exceeds)
    first_idx = jnp.argmax(exceeds)
    zeta = jnp.where(any_exceeds, p_sorted[first_idx], jnp.asarray(p_reserve, flat_p.dtype))
    return zeta


def allocate(
    bid: MultiBid,
    total_bandwidth: float,
    p_reserve: float = 0.0,
    weights: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Bandwidth allocation rule (Eq. 26).  Returns (b, zeta).

    b_n = d_bar_n(zeta+) + [d_bar_n(zeta) - d_bar_n(zeta+)] /
          [d_bar(zeta) - d_bar(zeta+)] * (B - d_bar(zeta+))
    """
    w = jnp.ones((bid.prices.shape[0],), bid.prices.dtype) if weights is None else weights
    zeta = clearing_price(bid, total_bandwidth, p_reserve, weights=w)
    d_left = pseudo_mbdf(bid, zeta, side="left") * w
    d_right = pseudo_mbdf(bid, zeta, side="right") * w
    agg_right = jnp.sum(d_right)
    jump = d_left - d_right
    agg_jump = jnp.sum(jump)
    surplus = jnp.maximum(total_bandwidth - agg_right, 0.0)
    share = jnp.where(agg_jump > _TINY, jump / jnp.maximum(agg_jump, _TINY) * surplus, 0.0)
    b = d_right + share
    return b, zeta


# ---------------------------------------------------------------------------
# Charging (Eq. 27) + full auction run.
# ---------------------------------------------------------------------------

def charges(
    svc: ServiceSet,
    bid: MultiBid,
    b_alloc: jax.Array,
    total_bandwidth: float,
    alpha_fair: float,
    p_reserve: float = 0.0,
) -> jax.Array:
    """c_n = sum_{j != n} int_{b_j(s)}^{b_j(s_-n)} q_bar_j + alpha*(f_n - log(1+f_n)).

    The leave-one-out allocations b_j(s_{-n}) come from re-running the
    allocation with provider n's bids excluded -- one vmap over the N
    exclusion masks (no Python loop)."""
    n = bid.prices.shape[0]
    eye = jnp.eye(n, dtype=bid.prices.dtype)

    def without(mask_row):
        b_wo, _ = allocate(bid, total_bandwidth, p_reserve, weights=1.0 - mask_row)
        return b_wo

    b_without = jax.vmap(without)(eye)                          # (N excl, N provider)
    lo = jnp.minimum(b_alloc[None, :], b_without)
    hi = jnp.maximum(b_alloc[None, :], b_without)
    # Social opportunity cost: others' valuation of the bandwidth they lose
    # to n's presence.  b_j(s_-n) >= b_j(s) for j != n (n's absence frees
    # bandwidth), so the integral is taken on [b_j(s), b_j(s_-n)].
    integrals = jax.vmap(lambda l, h: pseudo_mmvf_integral(bid, l, h))(lo, hi)  # (N, N)
    off_diag = integrals * (1.0 - jnp.eye(n, dtype=integrals.dtype))
    social_cost = jnp.sum(off_diag, axis=1)
    f_real = intra.freq(svc, b_alloc)
    return social_cost + fairness.fairness_cost(f_real, alpha_fair)


@functools.partial(jax.jit, static_argnames=("n_bids", "alpha_fair"))
def run_auction(
    svc: ServiceSet,
    total_bandwidth: float,
    n_bids: int = 5,
    alpha_fair: float = 0.5,
    p_reserve: float = 0.0,
) -> AuctionResult:
    """End-to-end fairness-adjusted multi-bid auction with truthful bidders."""
    bid = uniform_truthful_bids(svc, n_bids, alpha_fair, p_reserve)
    b, zeta = allocate(bid, total_bandwidth, p_reserve)
    c = charges(svc, bid, b, total_bandwidth, alpha_fair, p_reserve)
    f = intra.freq(svc, b)
    return AuctionResult(b=b, f=f, price=zeta, charges=c, utilities=f - c)


# ---------------------------------------------------------------------------
# Incentive diagnostics (Prop. 5, Eq. 31).
# ---------------------------------------------------------------------------

def delta_bound(
    svc: ServiceSet,
    bid: MultiBid,
    alpha_fair: float,
    p_reserve: float = 0.0,
) -> jax.Array:
    """The truthfulness gap Delta_n = max_m int_{d(p^{m+1})}^{d(p^m)} (q(b) - p^m) db
    (Eq. 31) against the *true* mBDF/mMVF.  Because q = g', the integral is
    exact in closed form:

        int_lo^hi (q(b) - p) db = [g(b_hi) - g(b_lo)] - p * (b_hi - b_lo),

    with g evaluated through f*(b).  Small Delta ==> truthful bidding is an
    ex-post Delta-Nash equilibrium (Prop. 5)."""
    n, m = bid.prices.shape
    pmax = intra.p_max(svc)
    # p^0 = p_reserve, p^1..p^M from the bids, p^{M+1} = q(0) = p_max.
    prices_ext = jnp.concatenate(
        [jnp.full((n, 1), p_reserve, bid.prices.dtype), bid.prices, pmax[:, None]], axis=1
    )  # (N, M+2)
    d_ext = jax.vmap(
        lambda p_col: fairness.mbdf(svc, p_col, alpha_fair), in_axes=1, out_axes=1
    )(prices_ext)                                                 # (N, M+2)
    f_ext = jax.vmap(
        lambda b_col: intra.freq(svc, b_col), in_axes=1, out_axes=1
    )(d_ext)
    g_ext = fairness.g_value(f_ext, alpha_fair)                   # (N, M+2)

    b_hi, b_lo = d_ext[:, :-1], d_ext[:, 1:]                      # segments m=0..M
    g_hi, g_lo = g_ext[:, :-1], g_ext[:, 1:]
    seg = (g_hi - g_lo) - prices_ext[:, :-1] * (b_hi - b_lo)
    return jnp.max(seg, axis=1)
