"""DISBA: Distributed Inter-Service Bandwidth Allocation (paper §IV, Algorithm 1).

Maximize  sum_n log(1 + f*_n(b_n))  s.t.  sum_n b_n = B   (Eq. 2)

via dual decomposition: each provider answers the price lam with its demand
b*_n(lam) (Eq. 12-14, solved in closed bisection form in repro.core.intra), and
the operator runs the projected subgradient update

    lam <- [ lam - gamma * (B - sum_n b_n(lam)) ]^+          (Eq. 16)

Three solvers are provided:

  * ``disba``        -- the paper-faithful subgradient loop (fixed step gamma,
                        stop when |lam_j - lam_{j-1}| <= eps), as a single
                        jitted ``lax.while_loop``.
  * ``disba_trace``  -- same iteration in Python, returning per-iteration
                        (lam, b, f) history for Figs. 4-5 / Table II.
  * ``solve_lambda_bisect`` / ``solve_lambda_newton`` -- beyond-paper fast
                        paths exploiting that aggregate demand D(lam) is
                        monotone decreasing: market clearing by bisection
                        (globally convergent, ~48 iterations) or by damped
                        Newton using the closed-form dD/dlam (quadratic local
                        convergence, typically <= 8 iterations).  Both return
                        the same allocation as ``disba`` to solver tolerance.

``disba_sharded`` wires the paper's operator<->provider message pattern onto a
device mesh with shard_map: services are sharded over one or more mesh axes,
each shard solves its residents' inner problems locally, and the only cross-
device traffic is the scalar psum of demands -- exactly Algorithm 1's
communication structure (and its privacy property: client-level alpha/t_comp
never leave the shard).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import flat_mesh, shard_map_unchecked
from repro.core import intra
from repro.core.types import BISECT_ITERS, ServiceSet

_TINY = 1e-30


class DisbaResult(NamedTuple):
    b: jax.Array          # (N,) allocated bandwidth
    f: jax.Array          # (N,) resulting FL frequency
    lam: jax.Array        # () final dual price
    iterations: jax.Array  # () iterations used
    converged: jax.Array  # () bool
    # () bool: True when the warm solver detected non-finite inputs/outputs
    # and served the cold-bisection rescue instead (never silent -- the
    # control plane mirrors this into its ``solver_fallbacks`` metric).
    fallback: jax.Array | bool = False


def sanitize_service_set(svc: ServiceSet) -> tuple[ServiceSet, jax.Array]:
    """(cleaned set, poisoned?) -- non-finite alpha/t_comp entries are masked
    out and replaced with benign placeholders so every downstream bisection
    keeps a finite bracket.  ``poisoned`` is True iff any *masked-in* entry
    was non-finite (placeholder rows of inactive slots never count)."""
    ok = jnp.logical_and(jnp.isfinite(svc.alpha), jnp.isfinite(svc.t_comp))
    poisoned = jnp.any(jnp.logical_and(svc.mask, ~ok))
    clean = ServiceSet(
        alpha=jnp.where(ok, svc.alpha, 1.0),
        t_comp=jnp.where(ok, svc.t_comp, 1.0),
        mask=jnp.logical_and(svc.mask, ok),
    )
    return clean, poisoned


def _objective(svc: ServiceSet, b: jax.Array) -> jax.Array:
    return jnp.sum(jnp.log1p(intra.freq(svc, b)))


# ---------------------------------------------------------------------------
# Paper-faithful subgradient loop (Algorithm 1).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iters", "inner_iters", "diminishing"))
def disba(
    svc: ServiceSet,
    total_bandwidth: float,
    gamma: float = 0.1,
    eps: float = 1e-3,
    lam0: float | None = None,
    max_iters: int = 10_000,
    inner_iters: int = BISECT_ITERS,
    diminishing: bool = False,
) -> DisbaResult:
    """Algorithm 1 with a unit-invariant step.

    The paper's raw update lam <- [lam - gamma*(B - D(lam))]^+ ties gamma to the
    unit system (lam and B have unrelated scales).  We use the equivalent
    normalized form

        lam_hat <- proj_[0,1] ( lam_hat - gamma * (1 - D/B) ),  lam = lam_hat * p_bar

    where p_bar = max_n p_max_n (the price above which aggregate demand is 0;
    the dual optimum provably lies in [0, p_bar], so the projection is exact,
    not a heuristic).  gamma and eps are then dimensionless; the paper's
    gamma in {0.1, 0.5} maps onto the same range.  Local convergence requires
    gamma * |dD/dlam| * p_bar / B < 2 -- benchmarks report the measured slope.
    ``diminishing=True`` uses gamma_j = gamma/sqrt(j+1) (classic subgradient
    schedule; converges for any gamma at a sublinear rate).
    """
    b_total = jnp.asarray(total_bandwidth, dtype=jnp.float32)
    lam_scale = jnp.max(intra.p_max(svc))
    lam_init = jnp.asarray(
        0.5 * lam_scale if lam0 is None else lam0, dtype=jnp.float32
    )

    def demand_sum(lam):
        return jnp.sum(intra.demand(svc, lam, inner_iters))

    def cond(state):
        lam, lam_prev, j, first = state
        return jnp.logical_and(
            j < max_iters,
            jnp.logical_or(first, jnp.abs(lam - lam_prev) > eps * lam_scale),
        )

    def body(state):
        lam, _, j, _ = state
        grad = 1.0 - demand_sum(lam) / b_total    # normalized dual gradient
        step = jnp.where(diminishing, gamma * jax.lax.rsqrt(1.0 + j.astype(jnp.float32)), gamma)
        lam_next = jnp.clip(lam - step * lam_scale * grad, 0.0, lam_scale)
        return lam_next, lam, j + 1, False

    lam, lam_prev, iters, _ = jax.lax.while_loop(
        cond, body, (lam_init, lam_init, jnp.int32(0), True)
    )
    b = intra.demand(svc, lam, inner_iters)
    # Project the (near-cleared) demands onto the simplex sum b = B so the
    # primal iterate is feasible regardless of the dual tolerance.
    b = b * (b_total / jnp.maximum(jnp.sum(b), _TINY))
    return DisbaResult(
        b=b,
        f=intra.freq(svc, b, inner_iters),
        lam=lam,
        iterations=iters,
        converged=jnp.abs(lam - lam_prev) <= eps * lam_scale,
    )


# Module-level jitted entry points for the Python-loop tracer.  Keyed on the
# (shape, dtype, static iters) signature by jax.jit's cache, so repeated
# ``disba_trace`` calls reuse one compilation instead of rebuilding a fresh
# ``jax.jit(lambda ...)`` wrapper (and recompiling) per invocation.
_TRACE_DEMAND = jax.jit(intra.demand, static_argnames=("iters",))
_TRACE_FREQ = jax.jit(intra.freq, static_argnames=("iters",))


def disba_trace(
    svc: ServiceSet,
    total_bandwidth: float,
    gamma: float = 0.1,
    eps: float = 1e-3,
    lam0: float | None = None,
    max_iters: int = 10_000,
    diminishing: bool = False,
) -> dict:
    """Python-loop variant of ``disba`` recording per-iteration history
    (Figs. 4-5, Table II).  Same normalized update as ``disba``."""
    lam_scale = float(jnp.max(intra.p_max(svc)))
    lam = 0.5 * lam_scale if lam0 is None else float(lam0)
    demand_fn = functools.partial(_TRACE_DEMAND, svc)
    freq_fn = functools.partial(_TRACE_FREQ, svc)
    hist = {"lam": [], "b": [], "f": [], "demand_gap": []}
    j = 0
    converged = False
    while j < max_iters:
        b = demand_fn(jnp.float32(lam))
        hist["lam"].append(lam)
        hist["b"].append(b)
        hist["f"].append(freq_fn(b))
        gap = float(total_bandwidth - jnp.sum(b))
        hist["demand_gap"].append(gap)
        step = gamma / (1.0 + j) ** 0.5 if diminishing else gamma
        lam_next = min(max(lam - step * lam_scale * gap / total_bandwidth, 0.0), lam_scale)
        lam_prev, lam = lam, lam_next
        j += 1
        # Same stopping rule as the jitted ``disba``: the *last executed*
        # update moved less than eps (checked against the pre-update iterate,
        # never a stale or overwritten value).
        if abs(lam - lam_prev) <= eps * lam_scale:
            converged = True
            break
    hist["iterations"] = j
    hist["converged"] = converged
    # Final primal at the *final* lam (matching ``disba``, which evaluates
    # demand at the converged price), projected onto sum b = B.
    b_last = demand_fn(jnp.float32(lam))
    hist["b_final"] = b_last * (total_bandwidth / jnp.sum(b_last))
    hist["f_final"] = freq_fn(hist["b_final"])
    return hist


# ---------------------------------------------------------------------------
# Beyond-paper fast paths: market clearing by bisection / Newton.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("iters", "inner_iters"))
def solve_lambda_bisect(
    svc: ServiceSet,
    total_bandwidth: float,
    iters: int = BISECT_ITERS,
    inner_iters: int = BISECT_ITERS,
) -> DisbaResult:
    """Clear the market directly: D(lam) = sum_n b_n(lam) is strictly decreasing,
    so the optimal dual price is the root of D(lam) - B on (0, max_n p_max)."""
    b_total = jnp.asarray(total_bandwidth, dtype=jnp.float32)
    lam_hi = jnp.max(intra.p_max(svc))   # demand is exactly 0 above this
    lam_lo = jnp.zeros_like(lam_hi)

    def h(lam):  # decreasing in lam -> root of D - B with _bisect's convention
        return jnp.sum(intra.demand(svc, lam, inner_iters)) - b_total

    lam = intra._bisect(h, lam_lo, lam_hi, iters)
    b = intra.demand(svc, lam, inner_iters)
    b = b * (b_total / jnp.maximum(jnp.sum(b), _TINY))
    return DisbaResult(
        b=b, f=intra.freq(svc, b, inner_iters), lam=lam,
        iterations=jnp.int32(iters), converged=jnp.bool_(True),
    )


def demand_slope_values(svc: ServiceSet, lam, inner_iters: int = BISECT_ITERS):
    """Per-service (b(lam), db/dlam) in closed form -- the single home of the
    slope formula (the Pallas ``dual_demand`` kernel's in-VMEM copy is the
    only other implementation, and its oracle ``ref.dual_demand_ref``
    delegates here).

    From Eq. 13, lam = psi(f) = f'(b(f))/(1+f); db/dlam = b'(f)/psi'(f) with
    b'(f) = 1/f'(b)  (Eq. 8) and, via the chain rule d(f')/df = f''*b'(f) =
    f''/f',
    psi'(f) = (f''*(1+f)/f' - f') / (1+f)^2, all closed-form at f (Eqns. 9-10).
    Opted-out providers (f = 0 because lam >= p_max) contribute zero slope.
    """
    f = intra.freq_from_price(svc, lam, inner_iters)
    b = intra.bandwidth_from_freq(svc, f)
    fp = jnp.maximum(intra.freq_prime_at_f(svc, f), _TINY)
    fpp = intra.freq_second_at_f(svc, f)
    psi_p = (fpp * (1.0 + f) / fp - fp) / (1.0 + f) ** 2
    slope = jnp.where(f > 0.0, (1.0 / fp) / psi_p, 0.0)
    return b, slope


def _demand_and_slope(svc: ServiceSet, lam, inner_iters: int):
    """(D(lam), dD/dlam, b(lam)) -- the aggregates a dual iteration needs."""
    b, slope = demand_slope_values(svc, lam, inner_iters)
    return jnp.sum(b), jnp.sum(slope), b


def solve_lambda_newton(
    svc: ServiceSet,
    total_bandwidth: float,
    iters: int = 12,
    inner_iters: int = BISECT_ITERS,
) -> DisbaResult:
    """Damped Newton on D(lam) - B = 0 with bisection safeguarding.

    The cold special case of ``solve_lambda_newton_warm``: midpoint seed
    (the ``WARM_COLD`` sentinel) and the full ``inner_iters`` trip count
    inside every Newton iteration.  One loop body serves both solvers.
    """
    return solve_lambda_newton_warm(
        svc, total_bandwidth, WARM_COLD, iters=iters,
        inner_iters=inner_iters, newton_inner_iters=inner_iters,
    )


# ---------------------------------------------------------------------------
# Warm-started market clearing: the fast path of the multi-period simulator.
# ---------------------------------------------------------------------------

WARM_COLD = -1.0   # dual-price sentinel meaning "no previous solve to reuse"
WARM_ITERS = 6     # safeguarded-Newton trips from a warm seed (quadratic
                   # convergence: <= 6 reach float32 resolution when the
                   # service population changed slowly since the last period)
WARM_INNER_ITERS = 24  # inner price->frequency trips *inside* the Newton
                       # loop: 24 halvings put the bracket at ~6e-8 of its
                       # width, at float32 resolution already -- the final
                       # demand/frequency evaluations still run the full
                       # ``inner_iters`` so the returned allocation is
                       # exact-to-dtype like every other solver here

DEMAND_BACKENDS = ("reference", "pallas", "megakernel")


def _demand_slope_backend(svc: ServiceSet, lam, inner_iters: int, backend: str):
    """(D(lam), dD/dlam, b(lam)) through the selected demand backend.

    ``"reference"`` is the pure-jnp closed form (``_demand_and_slope``);
    ``"pallas"`` launches the fused ``dual_demand`` kernel: one launch solves
    the Eq. 14 price->frequency bisection for the whole tile in VMEM and
    emits demand and its closed-form slope together, so each dual iteration
    is a single kernel call instead of ~48 jnp array sweeps.
    """
    if backend == "reference":
        return _demand_and_slope(svc, lam, inner_iters)
    if backend == "pallas":
        from repro.kernels import ops

        b, slope = ops.dual_demand(svc.alpha, svc.t_comp, lam,
                                   use_pallas=True, iters=inner_iters)
        return jnp.sum(b), jnp.sum(slope), b
    raise ValueError(f"unknown demand backend {backend!r}; "
                     f"expected one of {DEMAND_BACKENDS}")


@functools.partial(jax.jit, static_argnames=("iters", "inner_iters",
                                             "newton_inner_iters", "backend"))
def solve_lambda_newton_warm(
    svc: ServiceSet,
    total_bandwidth: float,
    lam_prev: jax.Array | float = WARM_COLD,
    iters: int = WARM_ITERS,
    inner_iters: int = BISECT_ITERS,
    newton_inner_iters: int = WARM_INNER_ITERS,
    backend: str = "reference",
) -> DisbaResult:
    """Safeguarded Newton on D(lam) - B = 0, seeded from the previous solve.

    The periodic re-solve of the long-term simulation changes the service
    population slowly, so the previous period's dual optimum ``lam_prev`` is
    an excellent seed: Newton's quadratic local convergence then clears the
    market in <= ``WARM_ITERS`` trips where the cold bisection pays
    ``BISECT_ITERS`` (48).  The bracket [0, max_n p_max] (recomputed for the
    *current* set, where the dual optimum provably lies) safeguards every
    step, so a badly stale seed degrades to plain safeguarded Newton, never
    diverges.  ``lam_prev <= 0`` (e.g. the ``WARM_COLD`` sentinel) or a seed
    at/above the bracket top falls back to the cold midpoint seed.

    ``backend`` selects how the dual trips are evaluated: ``"reference"``
    (pure jnp), ``"pallas"`` (one fused ``dual_demand`` launch per trip), or
    ``"megakernel"`` -- the whole solve (seed, every Newton trip, final
    demand, projection, Eq. 7 frequencies) as ONE ``ops.market_clear``
    launch keeping the service tensors resident in VMEM across trips.

    Non-finite hardening: NaN/Inf anywhere in the masked-in service tensors,
    a non-finite warm seed, or a non-finite solver output triggers a
    cold-bisection rescue on the sanitized set (``sanitize_service_set``) --
    flagged in ``DisbaResult.fallback``, never silent.  The healthy path is
    bitwise unchanged: the rescue sits behind a ``lax.cond`` whose predicate
    is False on finite inputs.
    """
    if backend not in DEMAND_BACKENDS:
        raise ValueError(f"unknown demand backend {backend!r}; "
                         f"expected one of {DEMAND_BACKENDS}")
    b_total = jnp.asarray(total_bandwidth, dtype=jnp.float32)
    lam_prev = jnp.asarray(lam_prev, dtype=jnp.float32)
    svc_clean, poisoned = sanitize_service_set(svc)
    if backend == "megakernel":
        from repro.kernels import ops

        b, f, lam = ops.market_clear(
            svc.alpha, svc.t_comp, b_total, lam_prev, use_pallas=True,
            iters=iters, inner_iters=inner_iters,
            newton_inner_iters=newton_inner_iters)
    else:
        lam_hi0 = jnp.max(intra.p_max(svc))
        warm_ok = jnp.logical_and(lam_prev > 0.0, lam_prev < lam_hi0)
        lam0 = jnp.where(warm_ok, lam_prev, 0.5 * lam_hi0)

        def body(_, state):
            lam, lo, hi = state
            d, slope, _ = _demand_slope_backend(svc, lam, newton_inner_iters,
                                                backend)
            resid = d - b_total
            lo = jnp.where(resid > 0, lam, lo)  # demand too high: raise price
            hi = jnp.where(resid > 0, hi, lam)
            step = resid / jnp.where(jnp.abs(slope) > _TINY, slope, -_TINY)
            lam_newton = lam - step
            # Non-strict bounds: a converged float32 iterate reproduces
            # itself (lam_newton == lam == the endpoint just folded into the
            # bracket); strict bounds would bounce it to the midpoint.
            in_bracket = jnp.logical_and(lam_newton >= lo, lam_newton <= hi)
            lam_next = jnp.where(in_bracket, lam_newton, 0.5 * (lo + hi))
            return lam_next, lo, hi

        lam, _, _ = jax.lax.fori_loop(
            0, iters, body, (lam0, jnp.zeros_like(lam_hi0), lam_hi0))
        if backend == "reference":
            b = intra.demand(svc, lam, inner_iters)
        else:
            _, _, b = _demand_slope_backend(svc, lam, inner_iters, backend)
        b = b * (b_total / jnp.maximum(jnp.sum(b), _TINY))
        f = intra.freq(svc, b, inner_iters)

    out_finite = jnp.logical_and(
        jnp.isfinite(lam),
        jnp.logical_and(jnp.all(jnp.isfinite(b)), jnp.all(jnp.isfinite(f))))
    bad = jnp.logical_or(poisoned,
                         jnp.logical_or(~jnp.isfinite(lam_prev), ~out_finite))

    def _rescue(_):
        lam_hi = jnp.max(intra.p_max(svc_clean))

        def h(lam_r):
            return (jnp.sum(intra.demand(svc_clean, lam_r, inner_iters))
                    - b_total)

        lam_r = intra._bisect(h, jnp.zeros_like(lam_hi), lam_hi, BISECT_ITERS)
        b_r = intra.demand(svc_clean, lam_r, inner_iters)
        b_r = b_r * (b_total / jnp.maximum(jnp.sum(b_r), _TINY))
        return b_r, intra.freq(svc_clean, b_r, inner_iters), lam_r

    b, f, lam = jax.lax.cond(bad, _rescue, lambda _: (b, f, lam), None)
    return DisbaResult(
        b=b, f=f, lam=lam, iterations=jnp.int32(iters),
        converged=jnp.bool_(True), fallback=bad,
    )


# ---------------------------------------------------------------------------
# Distributed DISBA under shard_map: services sharded across mesh axes.
# ---------------------------------------------------------------------------

SHARDED_METHODS = ("bisect", "newton")


def disba_sharded(
    mesh: Mesh | None,
    svc: ServiceSet,
    total_bandwidth: float,
    axis_names: tuple[str, ...] = ("data",),
    iters: int = BISECT_ITERS,
    inner_iters: int = BISECT_ITERS,
    method: str = "bisect",
    lam_prev: jax.Array | float = WARM_COLD,
    newton_inner_iters: int = WARM_INNER_ITERS,
    demand_backend: str = "reference",
) -> DisbaResult:
    """Market-clearing DISBA with the service axis sharded over ``axis_names``.

    Mirrors Algorithm 1's communication pattern exactly: per-shard local
    solves (the providers' Eq.-12 problems) + one scalar reduction per dual
    iteration (the operator's demand aggregation).  N must be divisible by the
    product of the mesh axis sizes (pad with empty services otherwise --
    all-masked rows demand exactly zero bandwidth, so padding never perturbs
    the clearing price).

    ``method="bisect"`` runs the cold 48-trip dual bisection (one scalar
    demand ``psum`` per trip).  ``method="newton"`` runs the warm-startable
    safeguarded Newton of ``solve_lambda_newton_warm`` with ``iters`` trips
    seeded from ``lam_prev``: each trip evaluates the local shard's fused
    demand+slope (``demand_backend="reference"`` jnp closed form or
    ``"pallas"`` -- one ``dual_demand`` kernel launch per shard per trip) and
    crosses devices with a single 2-scalar ``psum`` of (demand, slope); the
    dual update itself is replicated.  Only scalar aggregate traffic ever
    leaves a shard, so multi-device markets scale the N axis for free.

    ``mesh=None`` builds a one-axis mesh over every visible device via
    ``compat.flat_mesh`` -- the same mesh-construction path as
    ``fl.simulator.run_fleet`` (requires ``len(axis_names) == 1``).
    """
    if method not in SHARDED_METHODS:
        raise ValueError(f"unknown method {method!r}; "
                         f"expected one of {SHARDED_METHODS}")
    if mesh is None:
        if len(axis_names) != 1:
            raise ValueError(
                f"mesh=None builds a one-axis mesh; pass an explicit mesh "
                f"for multi-axis sharding over {axis_names}")
        mesh = flat_mesh(axis_name=axis_names[0])

    def _local_demand_slope(local: ServiceSet, lam):
        if demand_backend == "pallas":
            from repro.kernels import ops

            return ops.dual_demand(local.alpha, local.t_comp, lam,
                                   use_pallas=True, iters=newton_inner_iters)
        return demand_slope_values(local, lam, newton_inner_iters)

    def shard_fn(alpha, t_comp, mask, lam_seed):
        local = ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask)
        b_total = jnp.asarray(total_bandwidth, dtype=jnp.float32)
        lam_hi_local = jnp.max(intra.p_max(local))
        lam_hi = jax.lax.pmax(lam_hi_local, axis_names[0])
        for ax in axis_names[1:]:
            lam_hi = jax.lax.pmax(lam_hi, ax)

        if method == "bisect":
            def h(lam):
                d_local = jnp.sum(intra.demand(local, lam, inner_iters))
                d = jax.lax.psum(d_local, axis_names)
                return d - b_total

            lam = intra._bisect(h, jnp.zeros_like(lam_hi), lam_hi, iters)
        else:
            warm_ok = jnp.logical_and(lam_seed > 0.0, lam_seed < lam_hi)
            lam0 = jnp.where(warm_ok, lam_seed, 0.5 * lam_hi)

            def body(_, state):
                lam, lo, hi = state
                b_l, s_l = _local_demand_slope(local, lam)
                # ONE collective per trip: the (demand, slope) scalar pair.
                d, slope = jax.lax.psum(
                    jnp.stack([jnp.sum(b_l), jnp.sum(s_l)]), axis_names)
                resid = d - b_total
                lo = jnp.where(resid > 0, lam, lo)
                hi = jnp.where(resid > 0, hi, lam)
                step = resid / jnp.where(jnp.abs(slope) > _TINY, slope,
                                         -_TINY)
                lam_newton = lam - step
                in_bracket = jnp.logical_and(lam_newton >= lo,
                                             lam_newton <= hi)
                lam_next = jnp.where(in_bracket, lam_newton, 0.5 * (lo + hi))
                return lam_next, lo, hi

            lam, _, _ = jax.lax.fori_loop(
                0, iters, body, (lam0, jnp.zeros_like(lam_hi), lam_hi))
        b = intra.demand(local, lam, inner_iters)
        total = jax.lax.psum(jnp.sum(b), axis_names)
        b = b * (b_total / jnp.maximum(total, _TINY))
        f = intra.freq(local, b, inner_iters)
        return b, f, lam

    fn = shard_map_unchecked(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis_names), P(axis_names), P(axis_names), P()),
        out_specs=(P(axis_names), P(axis_names), P()),
    )
    lam_seed = jnp.asarray(lam_prev, dtype=jnp.float32)
    b, f, lam = jax.jit(fn)(svc.alpha, svc.t_comp, svc.mask, lam_seed)
    return DisbaResult(
        b=b, f=f, lam=lam, iterations=jnp.int32(iters), converged=jnp.bool_(True)
    )


def objective(svc: ServiceSet, b: jax.Array) -> jax.Array:
    """The proportional-fairness objective sum_n log(1 + f*_n(b_n))."""
    return _objective(svc, b)
