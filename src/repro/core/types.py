"""Core data structures for the multi-service FL bandwidth-allocation problem.

Canonical units (matching the paper's §VI.A setup so that all quantities are
O(1) in float32):

  * bandwidth ........ MHz
  * data sizes ....... Mbit
  * base rates r ..... bit/s/Hz   (dimensionless spectral efficiency)
  * times ............ seconds
  * frequencies ...... rounds / second

A *service* n is the paper's tuple <s_DT, {w_LC_k}, s_UT, w_GC> combined with its
clients' channel state.  For allocation purposes only two per-client scalars
matter (Eqns. 3-7):

    alpha_{n,k} = s_DT/r_DT_k + s_UT/r_UT_k       [MHz * s]  (transmission load)
    t_comp_{n,k} = w_LC_k/phi_k + w_GC/phi_n      [s]        (compute latency)

Services are batched into rectangular (N, K_max) arrays with a validity mask so
the solvers vectorize on TPU; padded slots carry alpha=0 and are excluded from
maxima via the mask.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Fixed-trip bisection count.  48 halvings shrink any O(1) bracket to ~4e-15 of
# its width -- far below float32 resolution, so the solve is exact-to-dtype.
BISECT_ITERS = 48

_NEG_INF = -1e30


class ServiceSet(NamedTuple):
    """A padded batch of FL services.

    Attributes:
      alpha:  (N, K) float -- per-client transmission load alpha_{n,k} [MHz*s].
              Exactly 0 for padded client slots.
      t_comp: (N, K) float -- per-client compute latency t^C_{n,k} [s].
              Ignored (masked) for padded slots.
      mask:   (N, K) bool  -- True for real clients.
      alpha_ul: (N, K) float or None -- the *dense* uplink component
              s^UT/r^UT_k of alpha [MHz*s].  Optional: solvers never read it;
              it exists so uplink compression can rescale s^UT per period
              (``scale_uplink``) without re-deriving channel rates.  ``None``
              (the default everywhere it is not needed) keeps the pytree and
              every traced graph identical to the historical 3-field set.
    """

    alpha: jax.Array
    t_comp: jax.Array
    mask: jax.Array
    alpha_ul: jax.Array | None = None

    @property
    def n_services(self) -> int:
        return self.alpha.shape[0]

    @property
    def k_max(self) -> int:
        return self.alpha.shape[1]

    def alpha_sum(self) -> jax.Array:
        """Sum_k alpha_{n,k} -> (N,).  Padding contributes 0 by construction."""
        return jnp.sum(self.alpha, axis=-1)

    def t_comp_max(self) -> jax.Array:
        """max_k t^C_{n,k} over valid clients -> (N,)."""
        return jnp.max(jnp.where(self.mask, self.t_comp, _NEG_INF), axis=-1)

    def client_counts(self) -> jax.Array:
        return jnp.sum(self.mask, axis=-1)

    def service_active(self) -> jax.Array:
        """(N,) bool -- True for services with at least one real client.

        A fully-masked row is an *inactive slot* of a fixed-capacity set (a
        service that has not arrived yet or has already departed); every
        allocation policy gives it b = f = 0.
        """
        return jnp.any(self.mask, axis=-1)


def make_service_set(alpha, t_comp, mask=None, alpha_ul=None) -> ServiceSet:
    alpha = jnp.asarray(alpha, dtype=jnp.float32)
    t_comp = jnp.asarray(t_comp, dtype=jnp.float32)
    if alpha.ndim == 1:
        alpha, t_comp = alpha[None], t_comp[None]
    if mask is None:
        mask = jnp.ones(alpha.shape, dtype=bool)
    else:
        mask = jnp.asarray(mask, dtype=bool)
        if mask.ndim == 1:
            mask = mask[None]
    alpha = jnp.where(mask, alpha, 0.0)
    if alpha_ul is not None:
        alpha_ul = jnp.asarray(alpha_ul, dtype=jnp.float32)
        if alpha_ul.ndim == 1:
            alpha_ul = alpha_ul[None]
        alpha_ul = jnp.where(mask, alpha_ul, 0.0)
    return ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask, alpha_ul=alpha_ul)


@dataclasses.dataclass(frozen=True)
class RawServiceParams:
    """Physical-layer description of one service before reduction to (alpha, t_comp).

    All arrays are (K,) over this service's clients.
    """

    s_dl_mbit: float          # download payload s^DT_n  [Mbit]
    s_ul_mbit: float          # upload payload  s^UT_n  [Mbit]
    r_dl: jax.Array           # downlink base rate log2(1 + P_n g^dl_k / N0)
    r_ul: jax.Array           # uplink base rate  log2(1 + P_k g^ul_k / N0)
    t_local: jax.Array        # local-computation latency w^LC_{n,k} / phi_k  [s]
    t_global: float           # aggregation latency w^GC_n / phi_n  [s]

    def reduce(self) -> tuple[jax.Array, jax.Array]:
        alpha = self.s_dl_mbit / self.r_dl + self.s_ul_mbit / self.r_ul
        t_comp = self.t_local + self.t_global
        return alpha, t_comp

    def reduce_parts(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Like ``reduce`` but also returns the uplink component s^UT/r^UT
        separately, for ServiceSets that carry the dynamic-s^UT column."""
        alpha_ul = self.s_ul_mbit / self.r_ul
        alpha = self.s_dl_mbit / self.r_dl + self.s_ul_mbit / self.r_ul
        t_comp = self.t_local + self.t_global
        return alpha, t_comp, alpha_ul


def stack_services(params: list[RawServiceParams], k_max: int | None = None) -> ServiceSet:
    """Pad a heterogeneous list of services into one rectangular ServiceSet."""
    reduced = [p.reduce_parts() for p in params]
    counts = [int(a.shape[0]) for a, _, _ in reduced]
    k_pad = k_max if k_max is not None else max(counts)
    n = len(params)
    alpha = jnp.zeros((n, k_pad), dtype=jnp.float32)
    t_comp = jnp.zeros((n, k_pad), dtype=jnp.float32)
    alpha_ul = jnp.zeros((n, k_pad), dtype=jnp.float32)
    mask = jnp.zeros((n, k_pad), dtype=bool)
    for i, (a, tc, aul) in enumerate(reduced):
        k = counts[i]
        alpha = alpha.at[i, :k].set(a.astype(jnp.float32))
        t_comp = t_comp.at[i, :k].set(tc.astype(jnp.float32))
        alpha_ul = alpha_ul.at[i, :k].set(aul.astype(jnp.float32))
        mask = mask.at[i, :k].set(True)
    return ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask, alpha_ul=alpha_ul)


def mask_inactive(svc: ServiceSet, active: jax.Array) -> ServiceSet:
    """Deactivate whole services in a fixed-capacity set by flipping masks.

    ``active``: (N,) bool.  Inactive rows keep their shape but drop every
    client (alpha -> 0, mask -> False), so arrivals/departures are pure mask
    flips -- no shape change, no retrace.  This is the core device of the
    multi-period simulator: one (capacity, K) ServiceSet serves every period.
    """
    row = jnp.asarray(active, dtype=bool)[:, None]
    keep = jnp.logical_and(svc.mask, row)
    return ServiceSet(
        alpha=jnp.where(keep, svc.alpha, 0.0),
        t_comp=jnp.where(keep, svc.t_comp, 0.0),
        mask=keep,
        alpha_ul=(None if svc.alpha_ul is None
                  else jnp.where(keep, svc.alpha_ul, 0.0)),
    )


def mask_clients(svc: ServiceSet, available: jax.Array) -> ServiceSet:
    """Drop individual clients of a padded set by flipping mask bits.

    ``available``: (N, K) bool.  Unavailable clients are removed exactly like
    padding (alpha -> 0, mask -> False); a row whose every client drops
    becomes an inactive slot (b = f = 0 from every policy).  This is the
    per-period churn perturbation of ``repro.scenarios.churn`` — like
    ``mask_inactive`` it is a pure mask flip, so the simulator's compiled
    step never retraces.
    """
    keep = jnp.logical_and(svc.mask, jnp.asarray(available, dtype=bool))
    return ServiceSet(
        alpha=jnp.where(keep, svc.alpha, 0.0),
        t_comp=jnp.where(keep, svc.t_comp, 0.0),
        mask=keep,
        alpha_ul=(None if svc.alpha_ul is None
                  else jnp.where(keep, svc.alpha_ul, 0.0)),
    )


def scale_uplink(svc: ServiceSet, ul_mult: jax.Array) -> ServiceSet:
    """Rescale each service's uplink payload s^UT by a per-service multiplier.

    ``ul_mult``: (N,) float in (0, 1] -- the ``compression_ratio`` of the
    level each service transmits at this period.  The effective load becomes

        alpha' = alpha - (1 - ul_mult_n) * alpha_ul

    i.e. the downlink component is untouched and the uplink component shrinks
    to ``ul_mult_n`` of dense.  ``alpha_ul`` itself stays the *dense* uplink
    load so the scaling is absolute, never compounding across periods.
    Requires the dynamic-s^UT column (``alpha_ul is not None``).
    """
    if svc.alpha_ul is None:
        raise ValueError(
            "scale_uplink needs ServiceSet.alpha_ul (the dynamic s^UT "
            "column); build the set via sample_services/stack_services or "
            "pass alpha_ul to make_service_set")
    m = jnp.clip(jnp.asarray(ul_mult, dtype=svc.alpha.dtype), 0.0, 1.0)
    alpha = svc.alpha - (1.0 - m[:, None]) * svc.alpha_ul
    return svc._replace(alpha=alpha)


def round_time_given_alloc(svc: ServiceSet, b_clients: jax.Array) -> jax.Array:
    """Round length t_n = max_k (t^C_{n,k} + alpha_{n,k}/b_{n,k}) for an arbitrary
    (possibly suboptimal) per-client allocation.  Used by the Equal-Client
    baseline and by tests.  b_clients: (N, K) MHz."""
    safe_b = jnp.maximum(b_clients, 1e-30)
    per_client = svc.t_comp + svc.alpha / safe_b
    return jnp.max(jnp.where(svc.mask, per_client, _NEG_INF), axis=-1)
