"""Intra-service bandwidth allocation (paper §III.A / §IV.A, Eqns. 1-10, 14).

Given a service's bandwidth budget b_n, the optimal per-client split equalizes
completion times (Eq. 6); the optimal round time t*_n is the unique root of

    h(t) = sum_k alpha_{n,k} / (t - t^C_{n,k}) - b_n = 0        (Eq. 7)

on (max_k t^C_{n,k}, inf).  All solvers here are fixed-trip bisections written
array-wise over a batched ServiceSet, so one call solves every service at once;
they are jit/vmap/shard_map-friendly and free of data-dependent shapes.

Also provided: the frequency function f*_n(b) = 1/t*_n and its first/second
derivatives (Lemma 1), the price->frequency inverse of the per-provider
Lagrangian stationarity condition (Eq. 14), and the frequency->bandwidth map
(Eq. 7 rewritten in f).  These are the primitives DISBA and the auction build on.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import BISECT_ITERS, ServiceSet

_TINY = 1e-30


def _bisect(fn, lo, hi, iters: int = BISECT_ITERS):
    """Batched bisection for a decreasing-in-root sign convention.

    Finds x with fn(x) = 0 where fn is monotone *decreasing* (fn(lo) >= 0 >=
    fn(hi)).  lo/hi/fn-output share an arbitrary batch shape.  Fixed trip count
    -> constant-time, fully vectorized.
    """

    def body(_, state):
        lo_, hi_ = state
        mid = 0.5 * (lo_ + hi_)
        val = fn(mid)
        go_right = val > 0.0
        return jnp.where(go_right, mid, lo_), jnp.where(go_right, hi_, mid)

    lo_f, hi_f = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo_f + hi_f)


# ---------------------------------------------------------------------------
# t*(b) / f*(b): the intra-service optimum.
# ---------------------------------------------------------------------------

def solve_round_time(svc: ServiceSet, b: jax.Array, iters: int = BISECT_ITERS) -> jax.Array:
    """Optimal round length t*_n(b_n) for every service.  b: (N,) MHz -> (N,) s.

    Solves Eq. 7 by bisection on u = t - max_k t^C, bracketed by
    (0, sum_k alpha / b]: at u->0+ the slowest client's term diverges (+inf);
    at u_hi = sum(alpha)/b, sum_k alpha/(u + tCmax - tC_k) <= sum(alpha)/u_hi = b.
    Services with b<=0 get t* = +inf.
    """
    t_cmax = svc.t_comp_max()                       # (N,)
    a_sum = svc.alpha_sum()                         # (N,)
    safe_b = jnp.maximum(b, _TINY)
    u_hi = a_sum / safe_b

    # Loop-invariant masking, hoisted out of the bisection body: alpha is
    # exactly 0 at masked slots (0 / positive = 0 contributes nothing), and
    # the masked gap is set to 1 (any positive value) so the denominator
    # never needs a per-trip ``where``.  Each of the ``iters`` trips is then
    # a single fused multiply-sum over the (N, K) tile.
    alpha_m = jnp.where(svc.mask, svc.alpha, 0.0)
    # Gap of each client's pole below the slowest client's pole (>= 0).
    gap = jnp.where(svc.mask, t_cmax[:, None] - svc.t_comp, 1.0)  # (N, K)

    def h(u):  # u: (N,)
        return jnp.sum(alpha_m / (u[:, None] + gap), axis=-1) - b

    u_star = _bisect(h, jnp.zeros_like(u_hi), u_hi, iters)
    t_star = t_cmax + u_star
    return jnp.where(b > 0.0, t_star, jnp.inf)


def client_allocation(svc: ServiceSet, b: jax.Array, iters: int = BISECT_ITERS) -> jax.Array:
    """Optimal per-client split b_{n,k} = alpha_{n,k} / (t* - t^C_{n,k}).  (N,K)."""
    t_star = solve_round_time(svc, b, iters)
    denom = jnp.maximum(t_star[:, None] - svc.t_comp, _TINY)
    raw = svc.alpha / denom
    raw = jnp.where(svc.mask, raw, 0.0)
    # Renormalize the residual bisection error so the budget holds exactly.
    total = jnp.maximum(jnp.sum(raw, axis=-1, keepdims=True), _TINY)
    return raw * (b[:, None] / total)


def freq(svc: ServiceSet, b: jax.Array, iters: int = BISECT_ITERS) -> jax.Array:
    """Optimal FL frequency f*_n(b_n) = 1 / t*_n(b_n).  (N,)."""
    t_star = solve_round_time(svc, b, iters)
    return jnp.where(jnp.isfinite(t_star), 1.0 / t_star, 0.0)


# ---------------------------------------------------------------------------
# Derivatives of f*(b) (Lemma 1) -- closed-form given f.
# ---------------------------------------------------------------------------

def _masked_sum(svc: ServiceSet, x) -> jax.Array:
    return jnp.sum(jnp.where(svc.mask, x, 0.0), axis=-1)


def freq_prime_at_f(svc: ServiceSet, f: jax.Array) -> jax.Array:
    """f*'(b) expressed at frequency f (Eq. 9): ( sum_k alpha/(1 - tC f)^2 )^-1."""
    one_m = 1.0 - svc.t_comp * f[:, None]
    s = _masked_sum(svc, svc.alpha / jnp.maximum(one_m, _TINY) ** 2)
    return 1.0 / jnp.maximum(s, _TINY)


def freq_second_at_f(svc: ServiceSet, f: jax.Array) -> jax.Array:
    """f*''(b) at frequency f (Eq. 10)."""
    one_m = jnp.maximum(1.0 - svc.t_comp * f[:, None], _TINY)
    s2 = _masked_sum(svc, svc.alpha / one_m**2)
    s3 = _masked_sum(svc, svc.alpha * svc.t_comp / one_m**3)
    return -2.0 * s3 / jnp.maximum(s2, _TINY) ** 3


def bandwidth_from_freq(svc: ServiceSet, f: jax.Array) -> jax.Array:
    """Invert Eq. 7: b(f) = sum_k alpha_k * f / (1 - t^C_k f).  f in [0, 1/max tC)."""
    one_m = jnp.maximum(1.0 - svc.t_comp * f[:, None], _TINY)
    return _masked_sum(svc, svc.alpha * f[:, None] / one_m)


def f_max(svc: ServiceSet) -> jax.Array:
    """Supremum frequency 1 / max_k t^C_{n,k} (approached as b -> inf)."""
    return 1.0 / jnp.maximum(svc.t_comp_max(), _TINY)


def p_max(svc: ServiceSet) -> jax.Array:
    """f*'(0) = 1/sum_k alpha (Eq. 32): the price above which demand is zero.

    Inactive slots of a fixed-capacity set (alpha_sum = 0) get p_max = 0, so
    they opt out of every market (demand 0 at any price) instead of blowing
    up the dual bracket max_n p_max with a 1/0.
    """
    a_sum = svc.alpha_sum()
    return jnp.where(a_sum > 0.0, 1.0 / jnp.maximum(a_sum, _TINY), 0.0)


# ---------------------------------------------------------------------------
# Price -> (frequency, bandwidth): the DISBA inner problem (Eq. 12-14).
# ---------------------------------------------------------------------------

_F_CEIL = 1.0 - 1e-6  # stay strictly inside the 1 - tC*f > 0 region


def freq_from_price(svc: ServiceSet, lam: jax.Array, iters: int = BISECT_ITERS) -> jax.Array:
    """Solve the stationarity condition (Eq. 14) for f given the dual price lam:

        (1 + f) * sum_k alpha_k / (1 - t^C_k f)^2 = 1 / lam.

    The LHS is increasing on [0, 1/max tC); LHS(0) = sum(alpha) = 1/p_max.
    For lam >= p_max the provider demands nothing (f = 0, b = 0).
    lam may be scalar or (N,).
    """
    lam = jnp.broadcast_to(jnp.asarray(lam, dtype=svc.alpha.dtype), (svc.n_services,))
    f_hi = f_max(svc) * _F_CEIL
    target = 1.0 / jnp.maximum(lam, _TINY)

    # Hoisted loop-invariant masking: alpha_m is exactly 0 at masked slots, so
    # they contribute 0 to the sum without a per-trip ``where``.
    alpha_m = jnp.where(svc.mask, svc.alpha, 0.0)

    def h(f):  # decreasing convention: target - LHS(f)
        one_m = jnp.maximum(1.0 - svc.t_comp * f[:, None], _TINY)
        lhs = (1.0 + f) * jnp.sum(alpha_m / one_m**2, axis=-1)
        return target - lhs

    f_star = _bisect(h, jnp.zeros_like(f_hi), f_hi, iters)
    opt_out = lam >= p_max(svc)
    return jnp.where(opt_out, 0.0, f_star)


def demand(svc: ServiceSet, lam: jax.Array, iters: int = BISECT_ITERS) -> jax.Array:
    """b*_n(lam) = argmax_b [ log(1 + f*(b)) - lam*b ]  (Eq. 12), per service."""
    f_star = freq_from_price(svc, lam, iters)
    return bandwidth_from_freq(svc, f_star)


def price_at_freq(svc: ServiceSet, f: jax.Array) -> jax.Array:
    """lam(f) = f*'(b)/(1+f*) evaluated at frequency f (inverse of Eq. 13)."""
    return freq_prime_at_f(svc, f) / (1.0 + f)


# Convenience jitted entry points ------------------------------------------------

solve_round_time_jit = jax.jit(solve_round_time, static_argnames=("iters",))
freq_jit = jax.jit(freq, static_argnames=("iters",))
demand_jit = jax.jit(demand, static_argnames=("iters",))
client_allocation_jit = jax.jit(client_allocation, static_argnames=("iters",))
