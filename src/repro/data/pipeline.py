"""Synthetic-but-learnable data pipeline.

``SyntheticLM`` generates token sequences from a fixed random bigram chain so
models have real signal to fit (loss decreases measurably during the examples'
training runs) while requiring no datasets in the image.  Batches are produced
deterministically from (seed, step) -- restart-safe by construction, which is
what checkpoint-resume tests rely on.

``dirichlet_partition`` splits class-like token groups across FL clients with
a Dirichlet(alpha) prior -- the standard non-IID federated benchmark split.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Bigram-chain language: next ~ Cat(softmax(T[prev])), T fixed by seed."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    temperature: float = 0.7

    def _transition_logits(self) -> jax.Array:
        key = jax.random.key(self.seed)
        return jax.random.normal(key, (self.vocab_size, self.vocab_size)) / self.temperature

    def batch(self, step: int, batch_size: int, client_id: int = 0) -> dict:
        """Deterministic batch for (step, client): tokens + next-token labels."""
        logits = self._transition_logits()
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.seed + 1), step), client_id
        )
        k0, kseq = jax.random.split(key)
        first = jax.random.randint(k0, (batch_size,), 0, self.vocab_size)

        def step_fn(tok, k):
            nxt = jax.random.categorical(k, logits[tok], axis=-1)
            return nxt, nxt

        keys = jax.random.split(kseq, self.seq_len)
        _, seq = jax.lax.scan(step_fn, first, keys)
        seq = jnp.concatenate([first[None], seq], axis=0).T  # (B, S+1)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def dirichlet_partition(key, n_samples: int, n_clients: int, n_classes: int,
                        alpha: float = 0.5) -> jax.Array:
    """Assign each of n_samples (with sample class = i % n_classes) to a client
    via per-class Dirichlet(alpha) proportions.  Returns (n_samples,) client ids.
    Smaller alpha = more skewed (non-IID) clients."""
    props = jax.random.dirichlet(key, alpha * jnp.ones((n_clients,)), (n_classes,))
    classes = jnp.arange(n_samples) % n_classes
    keys = jax.random.split(jax.random.fold_in(key, 1), n_samples)
    return jax.vmap(lambda k, c: jax.random.choice(k, n_clients, p=props[c]))(
        keys, classes
    )


def federated_batches(source: SyntheticLM, step: int, client_ids, batch_size: int):
    """Stacked per-client batches: (n_clients, B, S) tokens/labels.  Each
    client's stream is independent and deterministic -- the data-parallel axis
    of the FL train step."""
    batches = [source.batch(step, batch_size, int(c)) for c in client_ids]
    return {
        "tokens": jnp.stack([b["tokens"] for b in batches]),
        "labels": jnp.stack([b["labels"] for b in batches]),
    }
