"""Data pipeline: synthetic token streams, deterministic shardable iterators,
and Dirichlet non-IID federated partitioning."""
from repro.data.pipeline import (  # noqa: F401
    SyntheticLM,
    dirichlet_partition,
    federated_batches,
)
