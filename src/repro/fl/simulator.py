"""Multi-period wireless-network simulator (paper §VI.D long-term setting).

Services arrive via a Poisson(p_arrive) process, live for a fixed number of
FL rounds (2000 in the paper), and exit on completion.  Each period the
active set is (re-)allocated bandwidth by the selected policy -- this periodic
re-solve is the paper's elasticity mechanism: arrivals/departures change the
allocation without disturbing the surviving services' state.

Engines
-------

``run_scan`` -- the production engine.  The episode state lives in a
*fixed-capacity* ServiceSet (capacity = ``n_services_total``); a service that
has not arrived yet or has already finished is an all-masked row
(``types.mask_inactive``), so arrivals/departures are mask flips, never shape
changes.  The entire multi-period loop is one ``jax.lax.scan`` whose body --
sample channels, flip activity masks, run the ``AllocationPolicy`` -- is
traced exactly once per (policy, shape) combination, no matter how many
periods or episodes run (see ``trace_count``).  ``run_batch`` vmaps the same
compiled episode over a batch of seeds for scenario sweeps: one compiled call
evaluates many network conditions.

``run_fleet`` -- the device-sharded, memory-bounded sweep engine for
Monte-Carlo fleets of 10k+ episodes per call.  The fleet's seed axis is
sharded over a one-axis device mesh (``launch.mesh.make_fleet_mesh`` /
``compat.flat_mesh``) with ``compat.shard_map_unchecked``; inside each
device the local batch is processed in fixed-size chunks by an outer
``lax.map`` whose body is the vmapped compiled episode, so the episode
working set is O(chunk), not O(fleet) -- at fleet sizes where one flat vmap
thrashes the cache (a (4096, N, K) solver working set is tens of MB per
array), the chunked sweep keeps every bisection trip L2-resident.  Episode
input buffers are donated at the jit boundary and the period-step carry is
reused in place by XLA; beyond the O(chunk) working set only the requested
outputs are allocated, so a ``collect_history=False`` sweep never
materializes any (S, T) array.  Every episode stays bitwise identical to its
own ``run_scan`` regardless of sharding/chunking, and the period step still
traces exactly once (``trace_count()``).  Fleet setup is O(1) dispatches:
arrivals and client counts for all seeds come from one compiled, vmapped
device-side draw (``_static_draws_batch``).

``run`` -- the legacy per-period Python loop, kept as the checkpointable
reference engine (plain-dict state survives crashes; exercised by
tests/test_fl_runtime.py).  It consumes the *same* per-period step math as
the scan engine, so the two produce identical durations on the same seed
(asserted in tests/test_policy_simulator.py).

``fl.cotrain`` builds the training-in-the-loop engines
(``run_cotrain_scan`` / ``_batch`` / ``_fleet``) on the same period step:
``_period_step`` returns the period's allocation record as ``extras``
(dead-code-eliminated by every duration-only engine), and the co-trained
episode consumes it to pace real FedAvg rounds -- with durations bitwise
identical to the engines here (tests/test_cotrain.py).

Policies: coop (DISBA), selfish (multi-bid auction), ec / es / pp benchmarks
-- all resolved through the string-keyed ``core.policy`` registry, including
the selectable intra-service backend (reference bisection or the Pallas
``bisect_alloc`` kernel).

Scenarios
---------

The stochastic environment is selected per axis through the
``repro.scenarios`` registries (see EXPERIMENTS.md "Scenario catalogue"):
``channel_process`` (i.i.d. redraw, Gauss-Markov shadowing, correlated
Rayleigh block fading), ``arrival_process`` (Poisson, periodic, batched,
bursty MMPP), and ``churn_process`` (none, Bernoulli, Gilbert client
dropout).  Channel and churn processes are stateful ``(key, state, svc) ->
(state, svc')`` transforms whose state rides in the scan carry, so every
scenario combination still compiles the period step exactly once.  Arrival
processes are device-side per-episode draws (see ``_draws``), batched over
the fleet's seed axis.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat, scenarios
from repro.core import network, policy as policy_mod
from repro.core.types import (ServiceSet, mask_clients, mask_inactive,
                              scale_uplink)
from repro.launch import mesh as mesh_lib

POLICIES = ("coop", "selfish", "ec", "es", "pp")

# Default per-device chunk of run_fleet: small enough that the period step's
# (chunk, N, K) solver working set stays cache-resident through the
# bisection/Newton trips, large enough to amortize the chunk loop.
FLEET_CHUNK = 64

# Incremented each time the per-period allocation step is *traced* (not run).
# The scan engine's acceptance bar is exactly one trace per episode shape --
# mask flips must never retrigger compilation.
_TRACE_COUNTS = {"allocation_step": 0}


def trace_count() -> int:
    return _TRACE_COUNTS["allocation_step"]


def reset_trace_count() -> None:
    _TRACE_COUNTS["allocation_step"] = 0


@dataclasses.dataclass
class SimConfig:
    policy: str = "coop"
    n_services_total: int = 10
    rounds_required: int = 2000
    p_arrive: float = 5.0              # mean arrival interval in periods
    mean_clients: float = 25.0
    var_clients: float = 15.0
    mean_channel_db: float = 85.0
    var_channel_db: float = 15.0
    n_bids: int = 5
    alpha_fair: float = 0.5
    max_periods: int = 4000
    seed: int = 0
    intra_backend: str = "reference"   # "reference" | "pallas" | "megakernel"
    k_max: int | None = None           # client-capacity pad; None -> derived
    # Warm-start the allocation across periods: policy solver state (e.g.
    # coop's dual price) rides in the scan carry and seeds the next period's
    # solve.  Off by default -- the cold path is pinned by the goldens.
    warm_start: bool = False
    # When False the scan emits no per-period stacked history -- only scalar
    # aggregates accumulated in the carry -- cutting HBM traffic and host
    # transfer for large run_batch sweeps.
    collect_history: bool = True
    # When True (requires collect_history) the history additionally stacks
    # the per-period allocation record itself -- b, f, active, rounds -- so a
    # replay exposes the full served-allocation stream.  This is the
    # reference side of the control plane's differential check
    # (fl.control_plane / tests/test_control_plane.py).
    collect_alloc: bool = False
    # Scenario processes: registry keys or scenarios.spec(name, **params).
    channel_process: str | scenarios.ScenarioSpec = "iid"
    arrival_process: str | scenarios.ScenarioSpec = "poisson"
    churn_process: str | scenarios.ScenarioSpec = "none"


def _default_net(cfg: SimConfig) -> network.NetworkConfig:
    return network.NetworkConfig(
        mean_clients=cfg.mean_clients, var_clients=cfg.var_clients,
        mean_pathloss_db=cfg.mean_channel_db, var_pathloss_db=cfg.var_channel_db,
    )


def _k_cap(cfg: SimConfig) -> int:
    """Seed-independent client-capacity pad: mean + 5 sigma (counts are
    clipped into it, so no silent truncation).  Deriving the pad from the
    config rather than the drawn counts keeps every engine -- run, run_scan,
    and any batch composition in run_batch -- on the same shapes, hence the
    same RNG draws and bitwise-identical per-seed results."""
    if cfg.k_max is not None:
        return cfg.k_max
    return int(np.ceil(cfg.mean_clients + 5.0 * np.sqrt(max(cfg.var_clients, 0.0))))


# Salt folded into the episode key to derive the episode-static draw stream
# (arrival periods + client counts).  Follows the scenarios.base salt
# convention: above every period number, distinct from the scenario-state
# salts, so the static draws never collide with per-period sampling.
_DRAW_SALT = (1 << 30) + 3

# Version tag of the episode-static draw stream, written into legacy-engine
# checkpoints: resuming re-derives arrivals/counts from cfg.seed, so a
# snapshot from a different stream (e.g. the pre-fleet host-NumPy draws)
# must be refused, not silently continued with different arrivals.
DRAW_STREAM = "device/v1"

_DRAW_STATICS = ("arrival", "n_total", "p_arrive", "mean_clients",
                 "var_clients", "k_min", "k_cap")


@functools.partial(jax.jit, static_argnames=_DRAW_STATICS)
def _draws(keys, *, arrival, n_total, p_arrive, mean_clients, var_clients,
           k_min, k_cap):
    """Episode-static randomness for a whole fleet in ONE compiled dispatch.

    Arrival periods come from the registered device-side ``arrival_process``
    sampler (default: cumulative exponential gaps, the paper's Poisson
    process); client counts are a clipped normal, fixed at arrival.  Both are
    drawn per episode key and vmapped over the fleet's seed axis, so setup
    cost is O(1) dispatches for any fleet size -- and because each row
    depends only on its own key, the batched draw is bitwise identical to
    per-seed draws (asserted in tests/test_fleet.py).
    """
    draw = scenarios.get_arrival(arrival)
    std = np.sqrt(max(var_clients, 1e-9))

    def one(key):
        k_arr, k_cnt = jax.random.split(jax.random.fold_in(key, _DRAW_SALT))
        arrivals = draw(k_arr, n_total, p_arrive).astype(jnp.int32)
        counts = jnp.clip(
            jnp.round(mean_clients
                      + std * jax.random.normal(k_cnt, (n_total,), jnp.float32)),
            k_min, k_cap).astype(jnp.int32)
        return arrivals, counts

    return jax.vmap(one)(keys)


def _episode_keys(seeds) -> jax.Array:
    """Per-episode PRNG keys -- the same stream run_scan/run_batch always fed
    the compiled episode; the static draws branch off it via ``_DRAW_SALT``."""
    return jax.vmap(jax.random.key)(jnp.asarray(seeds, jnp.uint32) + 7)


def _draw_statics(cfg: SimConfig, net: network.NetworkConfig) -> dict:
    return dict(arrival=scenarios.as_spec(cfg.arrival_process, "poisson"),
                n_total=cfg.n_services_total, p_arrive=cfg.p_arrive,
                mean_clients=cfg.mean_clients, var_clients=cfg.var_clients,
                k_min=net.k_min, k_cap=_k_cap(cfg))


def _static_draws_batch(
    cfg: SimConfig, net: network.NetworkConfig, seeds,
) -> tuple[np.ndarray, np.ndarray]:
    """Batched episode-static draws: (S, N) arrivals + client counts."""
    arrivals, counts = _draws(_episode_keys(seeds), **_draw_statics(cfg, net))
    return np.asarray(arrivals), np.asarray(counts)


def _static_draws(cfg: SimConfig, net: network.NetworkConfig) -> tuple[np.ndarray, np.ndarray]:
    """Single-episode view of ``_static_draws_batch`` (the looped reference:
    calling this per seed is bitwise identical to one batched call)."""
    arrivals, counts = _static_draws_batch(cfg, net, [cfg.seed])
    return arrivals[0].astype(np.int64), counts[0].astype(np.int64)


# ---------------------------------------------------------------------------
# The shared per-period step (one trace serves every period of every episode).
# ---------------------------------------------------------------------------

def _period_step(rounds_done, duration, chan_state, churn_state, pol_state,
                 period, arrivals, counts, key, extra_avail=None,
                 ul_comp=None, *,
                 policy_fn, chan_step, churn_step, chan_rebuilds: bool, net,
                 n_total: int, k_max: int, rounds_required: int):
    """One period: evolve channels and churn, flip activity masks, allocate.

    All shapes are fixed at (n_total, k_max); activity and churn are pure
    masking, and the scenario processes *and* the policy solver (``pol_state``,
    e.g. the warm-start dual price) carry fixed-shape state, so the scan
    engine traces this exactly once per (episode shape, scenario) combo.

    Besides the carry and scalar ``stats`` it returns ``extras`` -- the
    period's full allocation record (the churn-masked ServiceSet, per-service
    bandwidth/frequency, activity mask, and the round counts *before* the
    rounds_required clamp).  ``extras`` is assembled purely from values the
    step already computed, so consuming it (the ``fl.cotrain`` co-simulation)
    or discarding it (every duration-only engine; dead-code-eliminated under
    jit) cannot move a single RNG draw or allocation result.

    ``extra_avail`` is an optional externally-supplied (n_total, k_max) bool
    availability mask applied on top of the churn process (the control
    plane's heartbeat-timeout drops).  ``None`` -- what every offline engine
    passes -- leaves the traced graph unchanged; an all-True mask is a
    bitwise no-op (masking an already-masked set is the identity), which is
    exactly what makes the live daemon's healthy-path stream replayable by
    ``run_scan``.

    ``ul_comp`` is an optional (n_total,) per-service uplink-compression
    multiplier (each service's ``fl.compression.compression_ratio``) applied
    to the dynamic s^UT column via ``types.scale_uplink`` *before* the
    policy runs -- so the allocator prices the compressed upload, round
    frequency rises, and the bandwidth split shifts.  This is the
    compression→allocation feedback edge of the co-simulation
    (``fl.cotrain``).  Like ``extra_avail``, the ``None`` default leaves the
    traced graph untouched, which is what keeps every duration engine and
    the committed goldens bitwise-pinned.
    """
    _TRACE_COUNTS["allocation_step"] += 1
    key_p = jax.random.fold_in(key, period)
    if chan_rebuilds:
        # The channel process reconstructs the ServiceSet itself (on this
        # same key, so non-channel draws match the i.i.d. path); hand it a
        # shape/mask-only shell instead of tracing a discarded base sample.
        mask = jnp.arange(k_max)[None, :] < counts[:, None]
        svc_full = ServiceSet(alpha=jnp.zeros(mask.shape, jnp.float32),
                              t_comp=jnp.zeros(mask.shape, jnp.float32),
                              mask=mask)
    else:
        svc_full, _ = network.sample_services(
            key_p, n_total, net, k_max=k_max, client_counts=counts,
        )
    chan_state, svc_full = chan_step(key_p, chan_state, svc_full)
    churn_state, svc_full = churn_step(key_p, churn_state, svc_full)
    if extra_avail is not None:
        svc_full = mask_clients(svc_full, extra_avail)
    if ul_comp is not None:
        svc_full = scale_uplink(svc_full, ul_comp)
    active = jnp.logical_and(arrivals <= period, rounds_done < rounds_required)
    svc = mask_inactive(svc_full, active)
    b, f, pol_state = policy_fn(svc, net.total_bandwidth_mhz, pol_state)
    # Integrity guard: a non-finite frequency (poisoned channel state under
    # fault injection) must not corrupt the integer rounds_done carry --
    # floor(NaN).astype(int32) is undefined.  Bitwise no-op on finite f.
    f_rounds = jnp.where(jnp.isfinite(f), f, 0.0)
    rounds = jnp.maximum(
        jnp.floor(f_rounds * jnp.float32(net.period_s)), 0.0
    ).astype(jnp.int32)
    rounds_done = jnp.minimum(
        rounds_done + jnp.where(active, rounds, 0), rounds_required
    )
    duration = duration + active.astype(jnp.int32)
    stats = {
        "freq_sum": jnp.sum(f),
        "objective": jnp.sum(jnp.log1p(f)),
        "n_active": jnp.sum(active.astype(jnp.int32)),
        "n_clients": jnp.sum(svc.mask.astype(jnp.int32)),
        "all_done": jnp.all(rounds_done >= rounds_required),
    }
    extras = {"svc": svc, "b": b, "f": f, "active": active, "rounds": rounds}
    return (rounds_done, duration, chan_state, churn_state, pol_state, stats,
            extras)


_EPISODE_STATICS = ("policy", "net", "n_total", "k_max", "rounds_required",
                    "max_periods", "n_bids", "alpha_fair", "intra_backend",
                    "warm_start", "collect_history", "collect_alloc",
                    "channel", "churn")

_AGG_KEYS = ("freq_sum", "objective", "n_active", "n_clients")


def _episode_impl(arrivals, counts, key, avail=None, *, policy, net, n_total,
                  k_max, rounds_required, max_periods, n_bids, alpha_fair,
                  intra_backend, warm_start, collect_history, collect_alloc,
                  channel, churn):
    pol = policy_mod.get_stateful_policy(
        policy, warm_start=warm_start, n_bids=n_bids, alpha_fair=alpha_fair,
        intra_backend=intra_backend,
    )
    chan_proc = scenarios.get_channel(channel, net)
    churn_proc = scenarios.get_churn(churn, net)

    def step(carry, xs):
        # ``avail`` (a recorded per-period availability stream, e.g. the
        # control plane's heartbeat masks) rides the scan xs next to the
        # period index; None -- every offline engine -- leaves the traced
        # graph exactly as before.
        period, extra_avail = xs if avail is not None else (xs, None)
        rounds_done, duration, chan_state, churn_state, pol_state, agg = carry
        (rounds_done, duration, chan_state, churn_state, pol_state,
         stats, extras) = _period_step(
            rounds_done, duration, chan_state, churn_state, pol_state, period,
            arrivals, counts, key, extra_avail,
            policy_fn=pol.step, chan_step=chan_proc.step,
            churn_step=churn_proc.step, chan_rebuilds=chan_proc.rebuilds,
            net=net, n_total=n_total, k_max=k_max,
            rounds_required=rounds_required,
        )
        carry = (rounds_done, duration, chan_state, churn_state, pol_state)
        if collect_history:
            if collect_alloc:
                stats = dict(stats, b=extras["b"], f=extras["f"],
                             active=extras["active"], rounds=extras["rounds"])
            return carry + ((),), stats
        # Aggregate-only mode: fold the per-period stats into the carry over
        # the first ``periods`` periods (up to and including the one where
        # every service finishes -- the same window _summarize slices).
        live = jnp.logical_not(agg["done"])
        agg = {
            "done": jnp.logical_or(agg["done"], stats["all_done"]),
            "periods": agg["periods"] + live.astype(jnp.int32),
            **{k: agg[k] + jnp.where(live, stats[k], 0).astype(agg[k].dtype)
               for k in _AGG_KEYS},
        }
        return carry + (agg,), None

    agg0 = () if collect_history else {
        "done": jnp.bool_(False), "periods": jnp.int32(0),
        "freq_sum": jnp.float32(0), "objective": jnp.float32(0),
        "n_active": jnp.int32(0), "n_clients": jnp.int32(0),
    }
    init = (jnp.zeros((n_total,), jnp.int32), jnp.zeros((n_total,), jnp.int32),
            chan_proc.init(key, n_total, k_max),
            churn_proc.init(key, n_total, k_max),
            pol.init_state(n_total), agg0)
    periods = jnp.arange(max_periods, dtype=jnp.int32)
    xs = periods if avail is None else (periods, avail)
    (rounds_done, duration, _, _, _, agg), hist = jax.lax.scan(step, init, xs)
    return rounds_done, duration, (hist if collect_history else agg)


_episode = functools.partial(jax.jit, static_argnames=_EPISODE_STATICS)(_episode_impl)


@functools.partial(jax.jit, static_argnames=_EPISODE_STATICS)
def _episode_batch(arrivals, counts, keys, *, policy, net, n_total, k_max,
                   rounds_required, max_periods, n_bids, alpha_fair,
                   intra_backend, warm_start, collect_history, collect_alloc,
                   channel, churn):
    """vmap of the episode over a leading seeds axis -- one compiled call
    evaluates a whole scenario sweep."""

    def one(a, c, k):
        return _episode_impl(
            a, c, k, policy=policy, net=net, n_total=n_total, k_max=k_max,
            rounds_required=rounds_required, max_periods=max_periods,
            n_bids=n_bids, alpha_fair=alpha_fair, intra_backend=intra_backend,
            warm_start=warm_start, collect_history=collect_history,
            collect_alloc=collect_alloc, channel=channel, churn=churn,
        )

    return jax.vmap(one)(arrivals, counts, keys)


def _summarize(cfg: SimConfig, rounds_done, duration, hist) -> dict:
    duration = np.asarray(duration)
    if not cfg.collect_history:
        agg = hist
        return {
            "avg_duration": float(np.mean(duration)),
            "std_duration": float(np.std(duration)),
            "durations": [int(d) for d in duration],
            "periods": int(agg["periods"]),
            "history": None,
            "totals": {k: float(agg[k]) for k in _AGG_KEYS},
            "finished": bool(
                np.all(np.asarray(rounds_done) >= cfg.rounds_required)),
        }
    done = np.asarray(hist["all_done"])
    periods = int(np.argmax(done)) + 1 if done.any() else cfg.max_periods
    return {
        "avg_duration": float(np.mean(duration)),
        "std_duration": float(np.std(duration)),
        "durations": [int(d) for d in duration],
        "periods": periods,
        # Every stacked series except the completion flag (with
        # collect_alloc that includes the b/f/active/rounds stream itself).
        "history": {k: np.asarray(v)[:periods] for k, v in hist.items()
                    if k != "all_done"},
        "finished": bool(np.all(np.asarray(rounds_done) >= cfg.rounds_required)),
    }


def _episode_statics(cfg: SimConfig, net: network.NetworkConfig,
                     k_max: int) -> dict:
    if cfg.collect_alloc and not cfg.collect_history:
        raise ValueError(
            "collect_alloc stacks the per-period allocation stream into the "
            "history, so it requires collect_history=True")
    return dict(
        policy=cfg.policy, net=net, n_total=cfg.n_services_total, k_max=k_max,
        rounds_required=cfg.rounds_required, max_periods=cfg.max_periods,
        n_bids=cfg.n_bids, alpha_fair=cfg.alpha_fair,
        intra_backend=cfg.intra_backend, warm_start=cfg.warm_start,
        collect_history=cfg.collect_history, collect_alloc=cfg.collect_alloc,
        channel=scenarios.as_spec(cfg.channel_process, "iid"),
        churn=scenarios.as_spec(cfg.churn_process, "none"),
    )


def run_scan(cfg: SimConfig, net: network.NetworkConfig | None = None, *,
             arrivals=None, counts=None, avail=None) -> dict:
    """Simulate one episode as a single compiled ``lax.scan``.

    Returns the same summary keys as ``run`` (avg_duration, durations,
    periods, finished) with the per-period history as stacked arrays.

    ``arrivals``/``counts`` optionally replace the episode-static draws with
    an explicit (n_services_total,) admission trace -- per-slot arrival
    period and enrolled-client count.  This is how the control plane's
    differential check replays a *live* admission stream through the offline
    reference engine: everything else (channel/churn draws, policy state)
    still comes from ``cfg.seed``'s episode key, so a daemon run on the same
    seed must match bitwise (tests/test_control_plane.py).

    ``avail`` optionally adds a recorded per-period client-availability
    stream, a ``(max_periods, n_services_total, k_max)`` bool tensor applied
    on top of the churn process each period (``_period_step``'s
    ``extra_avail`` hook).  The control plane records its heartbeat-timeout
    masks and feeds them back here, so even a heartbeat-masked live episode
    replays bitwise.  All-True planes are a bitwise no-op.
    """
    net = net or _default_net(cfg)
    if (arrivals is None) != (counts is None):
        raise ValueError("pass arrivals and counts together (or neither)")
    if arrivals is None:
        arrivals, counts = _static_draws(cfg, net)
    k_max = _k_cap(cfg)
    if avail is not None:
        avail = jnp.asarray(avail, bool)
        want = (cfg.max_periods, cfg.n_services_total, k_max)
        if avail.shape != want:
            raise ValueError(
                f"avail must have shape (max_periods, n_services_total, "
                f"k_max) = {want}, got {avail.shape}")
    rounds_done, duration, hist = _episode(
        jnp.asarray(arrivals, jnp.int32), jnp.asarray(counts, jnp.int32),
        jax.random.key(cfg.seed + 7), avail,
        **_episode_statics(cfg, net, k_max),
    )
    return _summarize(cfg, rounds_done, duration, hist)


def _summarize_batch(cfg: SimConfig, seeds, rounds_done, duration, hist) -> dict:
    """Per-seed stacked summary shared by ``run_batch`` and ``run_fleet``."""
    duration = np.asarray(duration)
    finished = np.all(np.asarray(rounds_done) >= cfg.rounds_required, axis=1)
    out = {
        "seeds": list(seeds),
        "avg_duration": duration.mean(axis=1),
        "std_duration": duration.std(axis=1),
        "durations": duration,
        "finished": finished,
    }
    if cfg.collect_history:
        out["history"] = {k: np.asarray(v) for k, v in hist.items()}
    else:
        # hist is the per-seed aggregate carry: scalar reductions only, no
        # (S, T) stacked arrays ever leave the device.
        out["history"] = None
        out["periods"] = np.asarray(hist["periods"])
        out["totals"] = {k: np.asarray(hist[k]) for k in _AGG_KEYS}
    return out


def run_batch(cfg: SimConfig, seeds, net: network.NetworkConfig | None = None) -> dict:
    """Scenario sweep: the compiled episode vmapped over ``seeds``.

    Every engine pads clients to the same config-derived ``k_max``
    (``_k_cap``), so the sweep is a single compiled call AND each episode is
    bitwise identical to its own ``run_scan``/``run`` regardless of which
    other seeds share the batch.  Returns per-seed summaries stacked:
    avg_duration (S,), durations (S, N), ...
    """
    net = net or _default_net(cfg)
    seeds = list(seeds)
    if not seeds:
        raise ValueError("run_batch needs at least one seed")
    keys = _episode_keys(seeds)
    arrivals, counts = _draws(keys, **_draw_statics(cfg, net))
    rounds_done, duration, hist = _episode_batch(
        arrivals, counts, keys, **_episode_statics(cfg, net, _k_cap(cfg)),
    )
    return _summarize_batch(cfg, seeds, rounds_done, duration, hist)


# ---------------------------------------------------------------------------
# Fleet engine: device-sharded, memory-bounded episode sweeps.
# ---------------------------------------------------------------------------

def _fleet_shape(n_seeds: int, n_dev: int, chunk_size: int | None) -> tuple[int, int, int]:
    """(chunk, n_chunks, padded fleet size): seeds are padded up to
    n_dev * n_chunks * chunk so every device runs the same chunk grid (the
    pad rows are dropped before summarizing)."""
    per_dev = -(-n_seeds // n_dev)
    chunk = max(1, min(chunk_size or FLEET_CHUNK, per_dev))
    n_chunks = -(-per_dev // chunk)
    return chunk, n_chunks, n_dev * n_chunks * chunk


def sharded_chunked_fn(mesh, axis: str, n_chunks: int, chunk: int, episode):
    """Build the compiled fleet sweep for an arbitrary per-episode function:
    shard_map over the seed axis of an outer ``lax.map`` over chunks of the
    vmapped episode.  ``episode(arrivals, counts, key_data) -> pytree`` takes
    one seed's inputs (keys as raw uint32 key data -- typed PRNG key arrays
    predate stable shard_map support on the oldest JAX this repo carries).

    Shared by the duration engine's ``run_fleet`` and the co-training
    engine's ``fl.cotrain.run_cotrain_fleet``; callers lru_cache the result
    per (mesh, chunk grid, episode statics) so the period step still traces
    exactly once per combination no matter how many fleet calls run.  Input
    buffers (arrivals, counts) are donated -- together with XLA's in-place
    reuse of the scan carry this keeps peak memory at O(chunk) episode state
    plus the requested outputs.
    """

    def device_fn(arrivals, counts, key_data):
        def chunk_fn(args):
            return jax.vmap(episode)(*args)

        def to_chunks(x):
            return x.reshape((n_chunks, chunk) + x.shape[1:])

        out = jax.lax.map(
            chunk_fn, (to_chunks(arrivals), to_chunks(counts),
                       to_chunks(key_data)))
        return jax.tree_util.tree_map(
            lambda x: x.reshape((n_chunks * chunk,) + x.shape[2:]), out)

    spec = P(axis)
    fn = compat.shard_map_unchecked(
        device_fn, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec)
    # Keys are excluded from donation: no uint32 output ever reuses them, so
    # donating would only emit a "not usable" warning per call.
    return jax.jit(fn, donate_argnums=(0, 1))


@functools.lru_cache(maxsize=None)
def _fleet_fn(mesh, axis: str, n_chunks: int, chunk: int, statics_items):
    """Compiled duration-engine fleet sweep (see ``sharded_chunked_fn``);
    the lru_cache plays the role of jit's cache for the mesh/chunk-grid +
    episode statics."""
    statics = dict(statics_items)

    def episode(arrivals, counts, key_data):
        return _episode_impl(arrivals, counts,
                             jax.random.wrap_key_data(key_data), **statics)

    return sharded_chunked_fn(mesh, axis, n_chunks, chunk, episode)


def fleet_geometry(seeds, mesh, chunk_size: int | None):
    """Normalize a fleet request: validate the mesh (one axis), derive the
    chunk grid, and pad the seed list with repeats of its last element so
    every device runs the same grid.  Returns
    ``(mesh, axis, n_dev, chunk, n_chunks, padded_seeds)``; callers slice
    the pad rows off on device before summarizing."""
    seeds = [int(s) for s in seeds]
    if not seeds:
        raise ValueError("fleet sweeps need at least one seed")
    if mesh is None:
        mesh = mesh_lib.make_fleet_mesh()
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"fleet sweeps shard over a one-axis mesh, got axes "
            f"{mesh.axis_names}")
    axis = mesh.axis_names[0]
    n_dev = mesh.shape[axis]
    chunk, n_chunks, padded_to = _fleet_shape(len(seeds), n_dev, chunk_size)
    padded = seeds + [seeds[-1]] * (padded_to - len(seeds))
    return mesh, axis, n_dev, chunk, n_chunks, padded


def run_fleet(cfg: SimConfig, seeds, net: network.NetworkConfig | None = None,
              *, mesh=None, chunk_size: int | None = None) -> dict:
    """Device-sharded, memory-bounded Monte-Carlo sweep over ``seeds``.

    The fleet's seed axis is split across a one-axis device mesh (default:
    ``launch.mesh.make_fleet_mesh()`` over every visible device), and each
    device walks its local batch in chunks of ``chunk_size`` episodes
    (default ``FLEET_CHUNK``) via an outer ``lax.map``, so the episode
    *working set* (solver intermediates, scan carry) is O(chunk) regardless
    of fleet size -- 10k+ episodes per call.  What remains O(fleet) is only
    the requested output: with ``collect_history=True`` that includes the
    (S, T) history arrays themselves; ``collect_history=False`` sweeps
    return per-seed scalars only and never materialize any (S, T) array.

    Invariants (tests/test_fleet.py): per-seed outputs are bitwise identical
    to ``run_batch``/``run_scan`` under every mesh size, chunk size, and
    fleet-size remainder, and the per-period allocation step traces exactly
    once.  Returns the ``run_batch`` summary dict plus a ``"fleet"`` record
    of the sweep geometry.
    """
    net = net or _default_net(cfg)
    seeds = [int(s) for s in seeds]
    mesh, axis, n_dev, chunk, n_chunks, padded = fleet_geometry(
        seeds, mesh, chunk_size)
    n_seeds = len(seeds)
    # Padded with repeats of the last seed: identical shapes on every device;
    # the pad episodes' outputs are sliced off (on device) before transfer.
    keys = _episode_keys(padded)
    arrivals, counts = _draws(keys, **_draw_statics(cfg, net))
    statics = _episode_statics(cfg, net, _k_cap(cfg))
    fn = _fleet_fn(mesh, axis, n_chunks, chunk, tuple(statics.items()))
    rounds_done, duration, hist = jax.tree_util.tree_map(
        lambda x: x[:n_seeds],
        fn(arrivals, counts, jax.random.key_data(keys)),
    )
    out = _summarize_batch(cfg, seeds, rounds_done, duration, hist)
    out["fleet"] = {"n_devices": n_dev, "mesh_axis": axis, "chunk": chunk,
                    "n_chunks": n_chunks, "padded_to": len(padded)}
    return out


# ---------------------------------------------------------------------------
# Legacy checkpointable engine (reference semantics for the scan engine).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _legacy_step_jit(policy, n_bids, alpha_fair, intra_backend, warm_start,
                     net, n_total, k_max, rounds_required, channel, churn):
    """Jitted period step + scenario processes, cached across ``run`` calls
    (per static shape / scenario spec) so per-seed sweeps / resumes reuse one
    compilation."""
    pol = policy_mod.get_stateful_policy(
        policy, warm_start=warm_start, n_bids=n_bids, alpha_fair=alpha_fair,
        intra_backend=intra_backend,
    )
    chan_proc = scenarios.get_channel(channel, net)
    churn_proc = scenarios.get_churn(churn, net)
    bound = functools.partial(
        _period_step, policy_fn=pol.step, chan_step=chan_proc.step,
        churn_step=churn_proc.step, chan_rebuilds=chan_proc.rebuilds, net=net,
        n_total=n_total, k_max=k_max, rounds_required=rounds_required,
    )

    def _drop_extras(*args):
        # The legacy loop only consumes the carry + stats; dropping the
        # allocation extras inside the jit boundary lets XLA dead-code
        # eliminate them instead of transferring a ServiceSet every period.
        *out, _ = bound(*args)
        return tuple(out)

    return jax.jit(_drop_extras), chan_proc, churn_proc, pol


def _scenario_state_to_json(state) -> list:
    """Flatten a scenario-state pytree to JSON-serializable nested lists."""
    return [np.asarray(leaf).tolist() for leaf in jax.tree_util.tree_leaves(state)]


def _scenario_state_from_json(template, data: list):
    """Rebuild scenario state from ``_scenario_state_to_json`` output, using
    a freshly-initialized ``template`` for tree structure, dtypes, shapes."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(data) != len(leaves):
        raise ValueError(
            f"checkpointed scenario state has {len(data)} leaves, the "
            f"configured processes expect {len(leaves)} -- was the checkpoint "
            f"written under a different scenario?")
    restored = [
        jnp.asarray(np.asarray(d).reshape(np.asarray(leaf).shape),
                    dtype=leaf.dtype)
        for d, leaf in zip(data, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, restored)


def run(cfg: SimConfig, net: network.NetworkConfig | None = None,
        state: dict | None = None, checkpoint_path: str | None = None) -> dict:
    """Per-period Python loop until every service finishes.

    Runs the same fixed-capacity period step as ``run_scan`` (so durations
    match the compiled engine exactly on the same seed) but keeps plain-dict
    state: ``state`` resumes a previous partial run and ``checkpoint_path``
    writes a JSON snapshot each period, so long runs restart after a crash.
    """
    net = net or _default_net(cfg)
    arrivals, counts = _static_draws(cfg, net)
    k_max = _k_cap(cfg)

    if state is None:
        state = {
            "period": 0,
            "rounds_done": [0] * cfg.n_services_total,
            "duration": [0] * cfg.n_services_total,
            "history": [],
            "draw_stream": DRAW_STREAM,
        }
    elif state["period"] > 0 and state.get("draw_stream") != DRAW_STREAM:
        # Arrivals/counts are re-derived from cfg.seed on resume, so a
        # snapshot written under a different episode-static draw stream
        # (e.g. the pre-fleet host-NumPy stream) would silently continue
        # with different arrival periods than the ones that produced its
        # rounds_done/duration.  Refuse instead.
        raise ValueError(
            f"resume state was written under draw stream "
            f"{state.get('draw_stream')!r}, this engine draws "
            f"{DRAW_STREAM!r} -- the checkpoint's arrivals cannot be "
            f"reconstructed; restart the episode")

    period = state["period"]
    rounds_done = list(state["rounds_done"])
    duration = list(state["duration"])
    history = list(state["history"])

    step_jit, chan_proc, churn_proc, pol = _legacy_step_jit(
        cfg.policy, cfg.n_bids, cfg.alpha_fair, cfg.intra_backend,
        cfg.warm_start, net,
        cfg.n_services_total, k_max, cfg.rounds_required,
        scenarios.as_spec(cfg.channel_process, "iid"),
        scenarios.as_spec(cfg.churn_process, "none"),
    )
    key = jax.random.key(cfg.seed + 7)
    arrivals_j = jnp.asarray(arrivals, jnp.int32)
    counts_j = jnp.asarray(counts, jnp.int32)

    # Scenario state: same init draws as the scan engine (episode key), then
    # restored from the snapshot when resuming mid-episode.
    def _restore_scenario_state(name: str, template):
        if name in state:
            return _scenario_state_from_json(template, state[name])
        if period > 0 and jax.tree_util.tree_leaves(template):
            raise ValueError(
                f"resume state has no {name!r} but the configured scenario/"
                f"policy processes are stateful -- was the snapshot written "
                f"under a different configuration?")
        return template

    chan_state = _restore_scenario_state(
        "chan_state", chan_proc.init(key, cfg.n_services_total, k_max))
    churn_state = _restore_scenario_state(
        "churn_state", churn_proc.init(key, cfg.n_services_total, k_max))
    pol_state = _restore_scenario_state(
        "pol_state", pol.init_state(cfg.n_services_total))

    def _snapshot() -> dict:
        return {"period": period, "rounds_done": rounds_done,
                "duration": duration, "history": history,
                "draw_stream": DRAW_STREAM,
                "chan_state": _scenario_state_to_json(chan_state),
                "churn_state": _scenario_state_to_json(churn_state),
                "pol_state": _scenario_state_to_json(pol_state)}

    # With stateful scenario processes (or warm-started policy state) the
    # step must run every period -- even with no active service -- so the
    # state trajectory matches the scan engine's period-per-step carry
    # exactly.  Stateless processes (the defaults) keep the cheap skip of
    # inactive periods.
    stateless = not jax.tree_util.tree_leaves(
        (chan_state, churn_state, pol_state))

    while period < cfg.max_periods:
        if all(r >= cfg.rounds_required for r in rounds_done):
            break
        active = [
            i for i in range(cfg.n_services_total)
            if arrivals[i] <= period and rounds_done[i] < cfg.rounds_required
        ]
        if active or not stateless:
            rd, du, chan_state, churn_state, pol_state, stats = step_jit(
                jnp.asarray(rounds_done, jnp.int32),
                jnp.asarray(duration, jnp.int32),
                chan_state, churn_state, pol_state,
                jnp.int32(period), arrivals_j, counts_j, key,
            )
            rounds_done = [int(r) for r in np.asarray(rd)]
            duration = [int(d) for d in np.asarray(du)]
            if active:
                history.append({
                    "period": period,
                    "active": active,
                    "freq_sum": float(stats["freq_sum"]),
                    "objective": float(stats["objective"]),
                    "n_clients": int(stats["n_clients"]),
                })
        period += 1
        if checkpoint_path is not None:
            snap = _snapshot()
            tmp = checkpoint_path + ".tmp"
            with open(tmp, "w") as fp:
                json.dump(snap, fp)
            os.replace(tmp, checkpoint_path)

    out = {
        "avg_duration": float(np.mean(duration)),
        "std_duration": float(np.std(duration)),
        "durations": duration,
        "periods": period,
        "history": history,
        "finished": all(r >= cfg.rounds_required for r in rounds_done),
        "state": _snapshot(),
    }
    if not cfg.collect_history:
        # Same summary shape as run_scan's aggregate mode.  The snapshot
        # keeps the full per-period list (resumes need it); only the
        # returned summary collapses to totals.  Skipped inactive periods
        # contribute exactly zero to every total, matching the scan carry.
        out["history"] = None
        out["totals"] = {
            "freq_sum": float(sum(h["freq_sum"] for h in history)),
            "objective": float(sum(h["objective"] for h in history)),
            "n_active": float(sum(len(h["active"]) for h in history)),
            "n_clients": float(sum(h["n_clients"] for h in history)),
        }
    return out
