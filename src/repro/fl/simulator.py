"""Multi-period wireless-network simulator (paper §VI.D long-term setting).

Services arrive via a Poisson(p_arrive) process, live for a fixed number of
FL rounds (2000 in the paper), and exit on completion.  Each period the
active set is (re-)allocated bandwidth by the selected policy -- this periodic
re-solve is the paper's elasticity mechanism: arrivals/departures change the
allocation without disturbing the surviving services' state.

Engines
-------

``run_scan`` -- the production engine.  The episode state lives in a
*fixed-capacity* ServiceSet (capacity = ``n_services_total``); a service that
has not arrived yet or has already finished is an all-masked row
(``types.mask_inactive``), so arrivals/departures are mask flips, never shape
changes.  The entire multi-period loop is one ``jax.lax.scan`` whose body --
sample channels, flip activity masks, run the ``AllocationPolicy`` -- is
traced exactly once per (policy, shape) combination, no matter how many
periods or episodes run (see ``trace_count``).  ``run_batch`` vmaps the same
compiled episode over a batch of seeds for scenario sweeps: one compiled call
evaluates many network conditions.

``run`` -- the legacy per-period Python loop, kept as the checkpointable
reference engine (plain-dict state survives crashes; exercised by
tests/test_fl_runtime.py).  It consumes the *same* per-period step math as
the scan engine, so the two produce identical durations on the same seed
(asserted in tests/test_policy_simulator.py).

Policies: coop (DISBA), selfish (multi-bid auction), ec / es / pp benchmarks
-- all resolved through the string-keyed ``core.policy`` registry, including
the selectable intra-service backend (reference bisection or the Pallas
``bisect_alloc`` kernel).

Scenarios
---------

The stochastic environment is selected per axis through the
``repro.scenarios`` registries (see EXPERIMENTS.md "Scenario catalogue"):
``channel_process`` (i.i.d. redraw, Gauss-Markov shadowing, correlated
Rayleigh block fading), ``arrival_process`` (Poisson, periodic, batched,
bursty MMPP), and ``churn_process`` (none, Bernoulli, Gilbert client
dropout).  Channel and churn processes are stateful ``(key, state, svc) ->
(state, svc')`` transforms whose state rides in the scan carry, so every
scenario combination still compiles the period step exactly once; the
defaults reproduce the pre-scenario engine bitwise.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.core import network, policy as policy_mod
from repro.core.types import ServiceSet, mask_inactive

POLICIES = ("coop", "selfish", "ec", "es", "pp")

# Incremented each time the per-period allocation step is *traced* (not run).
# The scan engine's acceptance bar is exactly one trace per episode shape --
# mask flips must never retrigger compilation.
_TRACE_COUNTS = {"allocation_step": 0}


def trace_count() -> int:
    return _TRACE_COUNTS["allocation_step"]


def reset_trace_count() -> None:
    _TRACE_COUNTS["allocation_step"] = 0


@dataclasses.dataclass
class SimConfig:
    policy: str = "coop"
    n_services_total: int = 10
    rounds_required: int = 2000
    p_arrive: float = 5.0              # mean arrival interval in periods
    mean_clients: float = 25.0
    var_clients: float = 15.0
    mean_channel_db: float = 85.0
    var_channel_db: float = 15.0
    n_bids: int = 5
    alpha_fair: float = 0.5
    max_periods: int = 4000
    seed: int = 0
    intra_backend: str = "reference"   # "reference" | "pallas"
    k_max: int | None = None           # client-capacity pad; None -> derived
    # Warm-start the allocation across periods: policy solver state (e.g.
    # coop's dual price) rides in the scan carry and seeds the next period's
    # solve.  Off by default -- the cold path is pinned by the goldens.
    warm_start: bool = False
    # When False the scan emits no per-period stacked history -- only scalar
    # aggregates accumulated in the carry -- cutting HBM traffic and host
    # transfer for large run_batch sweeps.
    collect_history: bool = True
    # Scenario processes: registry keys or scenarios.spec(name, **params).
    channel_process: str | scenarios.ScenarioSpec = "iid"
    arrival_process: str | scenarios.ScenarioSpec = "poisson"
    churn_process: str | scenarios.ScenarioSpec = "none"


def _default_net(cfg: SimConfig) -> network.NetworkConfig:
    return network.NetworkConfig(
        mean_clients=cfg.mean_clients, var_clients=cfg.var_clients,
        mean_pathloss_db=cfg.mean_channel_db, var_pathloss_db=cfg.var_channel_db,
    )


def _k_cap(cfg: SimConfig) -> int:
    """Seed-independent client-capacity pad: mean + 5 sigma (counts are
    clipped into it, so no silent truncation).  Deriving the pad from the
    config rather than the drawn counts keeps every engine -- run, run_scan,
    and any batch composition in run_batch -- on the same shapes, hence the
    same RNG draws and bitwise-identical per-seed results."""
    if cfg.k_max is not None:
        return cfg.k_max
    return int(np.ceil(cfg.mean_clients + 5.0 * np.sqrt(max(cfg.var_clients, 0.0))))


def _static_draws(cfg: SimConfig, net: network.NetworkConfig) -> tuple[np.ndarray, np.ndarray]:
    """Episode-static randomness: arrival periods + per-service client counts.

    Arrival periods come from the registered ``arrival_process`` (default:
    cumulative exponential gaps, the paper's Poisson process -- same RNG
    stream as the pre-scenario engine).  Counts are fixed at arrival;
    channels are resampled per period by the channel process (inside the
    compiled step).
    """
    rng = np.random.default_rng(cfg.seed)
    draw = scenarios.get_arrival(cfg.arrival_process)
    arrivals = np.asarray(
        draw(rng, cfg.n_services_total, cfg.p_arrive), dtype=np.int64)
    counts = np.clip(
        np.round(rng.normal(cfg.mean_clients, np.sqrt(max(cfg.var_clients, 1e-9)),
                            size=cfg.n_services_total)), net.k_min, _k_cap(cfg)
    ).astype(np.int64)
    return arrivals, counts


# ---------------------------------------------------------------------------
# The shared per-period step (one trace serves every period of every episode).
# ---------------------------------------------------------------------------

def _period_step(rounds_done, duration, chan_state, churn_state, pol_state,
                 period, arrivals, counts, key, *, policy_fn, chan_step,
                 churn_step, chan_rebuilds: bool, net, n_total: int,
                 k_max: int, rounds_required: int):
    """One period: evolve channels and churn, flip activity masks, allocate.

    All shapes are fixed at (n_total, k_max); activity and churn are pure
    masking, and the scenario processes *and* the policy solver (``pol_state``,
    e.g. the warm-start dual price) carry fixed-shape state, so the scan
    engine traces this exactly once per (episode shape, scenario) combo.
    """
    _TRACE_COUNTS["allocation_step"] += 1
    key_p = jax.random.fold_in(key, period)
    if chan_rebuilds:
        # The channel process reconstructs the ServiceSet itself (on this
        # same key, so non-channel draws match the i.i.d. path); hand it a
        # shape/mask-only shell instead of tracing a discarded base sample.
        mask = jnp.arange(k_max)[None, :] < counts[:, None]
        svc_full = ServiceSet(alpha=jnp.zeros(mask.shape, jnp.float32),
                              t_comp=jnp.zeros(mask.shape, jnp.float32),
                              mask=mask)
    else:
        svc_full, _ = network.sample_services(
            key_p, n_total, net, k_max=k_max, client_counts=counts,
        )
    chan_state, svc_full = chan_step(key_p, chan_state, svc_full)
    churn_state, svc_full = churn_step(key_p, churn_state, svc_full)
    active = jnp.logical_and(arrivals <= period, rounds_done < rounds_required)
    svc = mask_inactive(svc_full, active)
    b, f, pol_state = policy_fn(svc, net.total_bandwidth_mhz, pol_state)
    rounds = jnp.maximum(
        jnp.floor(f * jnp.float32(net.period_s)), 0.0
    ).astype(jnp.int32)
    rounds_done = jnp.minimum(
        rounds_done + jnp.where(active, rounds, 0), rounds_required
    )
    duration = duration + active.astype(jnp.int32)
    stats = {
        "freq_sum": jnp.sum(f),
        "objective": jnp.sum(jnp.log1p(f)),
        "n_active": jnp.sum(active.astype(jnp.int32)),
        "n_clients": jnp.sum(svc.mask.astype(jnp.int32)),
        "all_done": jnp.all(rounds_done >= rounds_required),
    }
    return rounds_done, duration, chan_state, churn_state, pol_state, stats


_EPISODE_STATICS = ("policy", "net", "n_total", "k_max", "rounds_required",
                    "max_periods", "n_bids", "alpha_fair", "intra_backend",
                    "warm_start", "collect_history", "channel", "churn")

_AGG_KEYS = ("freq_sum", "objective", "n_active", "n_clients")


def _episode_impl(arrivals, counts, key, *, policy, net, n_total, k_max,
                  rounds_required, max_periods, n_bids, alpha_fair,
                  intra_backend, warm_start, collect_history, channel, churn):
    pol = policy_mod.get_stateful_policy(
        policy, warm_start=warm_start, n_bids=n_bids, alpha_fair=alpha_fair,
        intra_backend=intra_backend,
    )
    chan_proc = scenarios.get_channel(channel, net)
    churn_proc = scenarios.get_churn(churn, net)

    def step(carry, period):
        rounds_done, duration, chan_state, churn_state, pol_state, agg = carry
        (rounds_done, duration, chan_state, churn_state, pol_state,
         stats) = _period_step(
            rounds_done, duration, chan_state, churn_state, pol_state, period,
            arrivals, counts, key,
            policy_fn=pol.step, chan_step=chan_proc.step,
            churn_step=churn_proc.step, chan_rebuilds=chan_proc.rebuilds,
            net=net, n_total=n_total, k_max=k_max,
            rounds_required=rounds_required,
        )
        carry = (rounds_done, duration, chan_state, churn_state, pol_state)
        if collect_history:
            return carry + ((),), stats
        # Aggregate-only mode: fold the per-period stats into the carry over
        # the first ``periods`` periods (up to and including the one where
        # every service finishes -- the same window _summarize slices).
        live = jnp.logical_not(agg["done"])
        agg = {
            "done": jnp.logical_or(agg["done"], stats["all_done"]),
            "periods": agg["periods"] + live.astype(jnp.int32),
            **{k: agg[k] + jnp.where(live, stats[k], 0).astype(agg[k].dtype)
               for k in _AGG_KEYS},
        }
        return carry + (agg,), None

    agg0 = () if collect_history else {
        "done": jnp.bool_(False), "periods": jnp.int32(0),
        "freq_sum": jnp.float32(0), "objective": jnp.float32(0),
        "n_active": jnp.int32(0), "n_clients": jnp.int32(0),
    }
    init = (jnp.zeros((n_total,), jnp.int32), jnp.zeros((n_total,), jnp.int32),
            chan_proc.init(key, n_total, k_max),
            churn_proc.init(key, n_total, k_max),
            pol.init_state(n_total), agg0)
    (rounds_done, duration, _, _, _, agg), hist = jax.lax.scan(
        step, init, jnp.arange(max_periods, dtype=jnp.int32)
    )
    return rounds_done, duration, (hist if collect_history else agg)


_episode = functools.partial(jax.jit, static_argnames=_EPISODE_STATICS)(_episode_impl)


@functools.partial(jax.jit, static_argnames=_EPISODE_STATICS)
def _episode_batch(arrivals, counts, keys, *, policy, net, n_total, k_max,
                   rounds_required, max_periods, n_bids, alpha_fair,
                   intra_backend, warm_start, collect_history, channel, churn):
    """vmap of the episode over a leading seeds axis -- one compiled call
    evaluates a whole scenario sweep."""

    def one(a, c, k):
        return _episode_impl(
            a, c, k, policy=policy, net=net, n_total=n_total, k_max=k_max,
            rounds_required=rounds_required, max_periods=max_periods,
            n_bids=n_bids, alpha_fair=alpha_fair, intra_backend=intra_backend,
            warm_start=warm_start, collect_history=collect_history,
            channel=channel, churn=churn,
        )

    return jax.vmap(one)(arrivals, counts, keys)


def _summarize(cfg: SimConfig, rounds_done, duration, hist) -> dict:
    duration = np.asarray(duration)
    if not cfg.collect_history:
        agg = hist
        return {
            "avg_duration": float(np.mean(duration)),
            "std_duration": float(np.std(duration)),
            "durations": [int(d) for d in duration],
            "periods": int(agg["periods"]),
            "history": None,
            "totals": {k: float(agg[k]) for k in _AGG_KEYS},
            "finished": bool(
                np.all(np.asarray(rounds_done) >= cfg.rounds_required)),
        }
    done = np.asarray(hist["all_done"])
    periods = int(np.argmax(done)) + 1 if done.any() else cfg.max_periods
    return {
        "avg_duration": float(np.mean(duration)),
        "std_duration": float(np.std(duration)),
        "durations": [int(d) for d in duration],
        "periods": periods,
        "history": {
            "freq_sum": np.asarray(hist["freq_sum"])[:periods],
            "objective": np.asarray(hist["objective"])[:periods],
            "n_active": np.asarray(hist["n_active"])[:periods],
            "n_clients": np.asarray(hist["n_clients"])[:periods],
        },
        "finished": bool(np.all(np.asarray(rounds_done) >= cfg.rounds_required)),
    }


def _episode_statics(cfg: SimConfig, net: network.NetworkConfig,
                     k_max: int) -> dict:
    return dict(
        policy=cfg.policy, net=net, n_total=cfg.n_services_total, k_max=k_max,
        rounds_required=cfg.rounds_required, max_periods=cfg.max_periods,
        n_bids=cfg.n_bids, alpha_fair=cfg.alpha_fair,
        intra_backend=cfg.intra_backend, warm_start=cfg.warm_start,
        collect_history=cfg.collect_history,
        channel=scenarios.as_spec(cfg.channel_process, "iid"),
        churn=scenarios.as_spec(cfg.churn_process, "none"),
    )


def run_scan(cfg: SimConfig, net: network.NetworkConfig | None = None) -> dict:
    """Simulate one episode as a single compiled ``lax.scan``.

    Returns the same summary keys as ``run`` (avg_duration, durations,
    periods, finished) with the per-period history as stacked arrays.
    """
    net = net or _default_net(cfg)
    arrivals, counts = _static_draws(cfg, net)
    k_max = _k_cap(cfg)
    rounds_done, duration, hist = _episode(
        jnp.asarray(arrivals, jnp.int32), jnp.asarray(counts, jnp.int32),
        jax.random.key(cfg.seed + 7), **_episode_statics(cfg, net, k_max),
    )
    return _summarize(cfg, rounds_done, duration, hist)


def run_batch(cfg: SimConfig, seeds, net: network.NetworkConfig | None = None) -> dict:
    """Scenario sweep: the compiled episode vmapped over ``seeds``.

    Every engine pads clients to the same config-derived ``k_max``
    (``_k_cap``), so the sweep is a single compiled call AND each episode is
    bitwise identical to its own ``run_scan``/``run`` regardless of which
    other seeds share the batch.  Returns per-seed summaries stacked:
    avg_duration (S,), durations (S, N), ...
    """
    net = net or _default_net(cfg)
    seeds = list(seeds)
    if not seeds:
        raise ValueError("run_batch needs at least one seed")
    draws = [_static_draws(dataclasses.replace(cfg, seed=s), net) for s in seeds]
    arrivals = np.stack([a for a, _ in draws])
    counts = np.stack([c for _, c in draws])
    k_max = _k_cap(cfg)
    keys = jax.vmap(jax.random.key)(jnp.asarray(seeds, jnp.uint32) + 7)
    rounds_done, duration, hist = _episode_batch(
        jnp.asarray(arrivals, jnp.int32), jnp.asarray(counts, jnp.int32),
        keys, **_episode_statics(cfg, net, k_max),
    )
    duration = np.asarray(duration)
    finished = np.all(np.asarray(rounds_done) >= cfg.rounds_required, axis=1)
    out = {
        "seeds": seeds,
        "avg_duration": duration.mean(axis=1),
        "std_duration": duration.std(axis=1),
        "durations": duration,
        "finished": finished,
    }
    if cfg.collect_history:
        out["history"] = {k: np.asarray(v) for k, v in hist.items()}
    else:
        # hist is the per-seed aggregate carry: scalar reductions only, no
        # (S, T) stacked arrays ever leave the device.
        out["history"] = None
        out["periods"] = np.asarray(hist["periods"])
        out["totals"] = {k: np.asarray(hist[k]) for k in _AGG_KEYS}
    return out


# ---------------------------------------------------------------------------
# Legacy checkpointable engine (reference semantics for the scan engine).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _legacy_step_jit(policy, n_bids, alpha_fair, intra_backend, warm_start,
                     net, n_total, k_max, rounds_required, channel, churn):
    """Jitted period step + scenario processes, cached across ``run`` calls
    (per static shape / scenario spec) so per-seed sweeps / resumes reuse one
    compilation."""
    pol = policy_mod.get_stateful_policy(
        policy, warm_start=warm_start, n_bids=n_bids, alpha_fair=alpha_fair,
        intra_backend=intra_backend,
    )
    chan_proc = scenarios.get_channel(channel, net)
    churn_proc = scenarios.get_churn(churn, net)
    step = jax.jit(functools.partial(
        _period_step, policy_fn=pol.step, chan_step=chan_proc.step,
        churn_step=churn_proc.step, chan_rebuilds=chan_proc.rebuilds, net=net,
        n_total=n_total, k_max=k_max, rounds_required=rounds_required,
    ))
    return step, chan_proc, churn_proc, pol


def _scenario_state_to_json(state) -> list:
    """Flatten a scenario-state pytree to JSON-serializable nested lists."""
    return [np.asarray(leaf).tolist() for leaf in jax.tree_util.tree_leaves(state)]


def _scenario_state_from_json(template, data: list):
    """Rebuild scenario state from ``_scenario_state_to_json`` output, using
    a freshly-initialized ``template`` for tree structure, dtypes, shapes."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(data) != len(leaves):
        raise ValueError(
            f"checkpointed scenario state has {len(data)} leaves, the "
            f"configured processes expect {len(leaves)} -- was the checkpoint "
            f"written under a different scenario?")
    restored = [
        jnp.asarray(np.asarray(d).reshape(np.asarray(leaf).shape),
                    dtype=leaf.dtype)
        for d, leaf in zip(data, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, restored)


def run(cfg: SimConfig, net: network.NetworkConfig | None = None,
        state: dict | None = None, checkpoint_path: str | None = None) -> dict:
    """Per-period Python loop until every service finishes.

    Runs the same fixed-capacity period step as ``run_scan`` (so durations
    match the compiled engine exactly on the same seed) but keeps plain-dict
    state: ``state`` resumes a previous partial run and ``checkpoint_path``
    writes a JSON snapshot each period, so long runs restart after a crash.
    """
    net = net or _default_net(cfg)
    arrivals, counts = _static_draws(cfg, net)
    k_max = _k_cap(cfg)

    if state is None:
        state = {
            "period": 0,
            "rounds_done": [0] * cfg.n_services_total,
            "duration": [0] * cfg.n_services_total,
            "history": [],
        }

    period = state["period"]
    rounds_done = list(state["rounds_done"])
    duration = list(state["duration"])
    history = list(state["history"])

    step_jit, chan_proc, churn_proc, pol = _legacy_step_jit(
        cfg.policy, cfg.n_bids, cfg.alpha_fair, cfg.intra_backend,
        cfg.warm_start, net,
        cfg.n_services_total, k_max, cfg.rounds_required,
        scenarios.as_spec(cfg.channel_process, "iid"),
        scenarios.as_spec(cfg.churn_process, "none"),
    )
    key = jax.random.key(cfg.seed + 7)
    arrivals_j = jnp.asarray(arrivals, jnp.int32)
    counts_j = jnp.asarray(counts, jnp.int32)

    # Scenario state: same init draws as the scan engine (episode key), then
    # restored from the snapshot when resuming mid-episode.
    def _restore_scenario_state(name: str, template):
        if name in state:
            return _scenario_state_from_json(template, state[name])
        if period > 0 and jax.tree_util.tree_leaves(template):
            raise ValueError(
                f"resume state has no {name!r} but the configured scenario/"
                f"policy processes are stateful -- was the snapshot written "
                f"under a different configuration?")
        return template

    chan_state = _restore_scenario_state(
        "chan_state", chan_proc.init(key, cfg.n_services_total, k_max))
    churn_state = _restore_scenario_state(
        "churn_state", churn_proc.init(key, cfg.n_services_total, k_max))
    pol_state = _restore_scenario_state(
        "pol_state", pol.init_state(cfg.n_services_total))

    def _snapshot() -> dict:
        return {"period": period, "rounds_done": rounds_done,
                "duration": duration, "history": history,
                "chan_state": _scenario_state_to_json(chan_state),
                "churn_state": _scenario_state_to_json(churn_state),
                "pol_state": _scenario_state_to_json(pol_state)}

    # With stateful scenario processes (or warm-started policy state) the
    # step must run every period -- even with no active service -- so the
    # state trajectory matches the scan engine's period-per-step carry
    # exactly.  Stateless processes (the defaults) keep the cheap skip of
    # inactive periods.
    stateless = not jax.tree_util.tree_leaves(
        (chan_state, churn_state, pol_state))

    while period < cfg.max_periods:
        if all(r >= cfg.rounds_required for r in rounds_done):
            break
        active = [
            i for i in range(cfg.n_services_total)
            if arrivals[i] <= period and rounds_done[i] < cfg.rounds_required
        ]
        if active or not stateless:
            rd, du, chan_state, churn_state, pol_state, stats = step_jit(
                jnp.asarray(rounds_done, jnp.int32),
                jnp.asarray(duration, jnp.int32),
                chan_state, churn_state, pol_state,
                jnp.int32(period), arrivals_j, counts_j, key,
            )
            rounds_done = [int(r) for r in np.asarray(rd)]
            duration = [int(d) for d in np.asarray(du)]
            if active:
                history.append({
                    "period": period,
                    "active": active,
                    "freq_sum": float(stats["freq_sum"]),
                    "objective": float(stats["objective"]),
                    "n_clients": int(stats["n_clients"]),
                })
        period += 1
        if checkpoint_path is not None:
            snap = _snapshot()
            tmp = checkpoint_path + ".tmp"
            with open(tmp, "w") as fp:
                json.dump(snap, fp)
            os.replace(tmp, checkpoint_path)

    out = {
        "avg_duration": float(np.mean(duration)),
        "std_duration": float(np.std(duration)),
        "durations": duration,
        "periods": period,
        "history": history,
        "finished": all(r >= cfg.rounds_required for r in rounds_done),
        "state": _snapshot(),
    }
    if not cfg.collect_history:
        # Same summary shape as run_scan's aggregate mode.  The snapshot
        # keeps the full per-period list (resumes need it); only the
        # returned summary collapses to totals.  Skipped inactive periods
        # contribute exactly zero to every total, matching the scan carry.
        out["history"] = None
        out["totals"] = {
            "freq_sum": float(sum(h["freq_sum"] for h in history)),
            "objective": float(sum(h["objective"] for h in history)),
            "n_active": float(sum(len(h["active"]) for h in history)),
            "n_clients": float(sum(h["n_clients"] for h in history)),
        }
    return out
