"""Multi-period wireless-network simulator (paper §VI.D long-term setting).

Services arrive via a Poisson(p_arrive) process, live for a fixed number of
FL rounds (2000 in the paper), and exit on completion.  Each period the
active set is (re-)allocated bandwidth by the selected policy -- this periodic
re-solve is the paper's elasticity mechanism: arrivals/departures change the
allocation without disturbing the surviving services' state.

Policies: coop (DISBA), selfish (multi-bid auction), ec / es / pp benchmarks.
The simulator is checkpointable (plain dict state) so long runs restart after
a crash -- exercised by tests/test_fl_runtime.py.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import auction, baselines, disba, network
from repro.core.types import ServiceSet
from repro.fl.service import FLService

POLICIES = ("coop", "selfish", "ec", "es", "pp")


@dataclasses.dataclass
class SimConfig:
    policy: str = "coop"
    n_services_total: int = 10
    rounds_required: int = 2000
    p_arrive: float = 5.0              # mean arrival interval in periods
    mean_clients: float = 25.0
    var_clients: float = 15.0
    mean_channel_db: float = 85.0
    var_channel_db: float = 15.0
    n_bids: int = 5
    alpha_fair: float = 0.5
    max_periods: int = 4000
    seed: int = 0


def _allocate(policy: str, svc: ServiceSet, b_total: float, cfg: SimConfig):
    if policy == "coop":
        res = disba.solve_lambda_bisect(svc, b_total)
        return res.b, res.f
    if policy == "selfish":
        bid = auction.uniform_truthful_bids(svc, cfg.n_bids, cfg.alpha_fair)
        b, _ = auction.allocate(bid, b_total)
        from repro.core import intra
        return b, intra.freq(svc, b)
    if policy == "ec":
        return baselines.equal_client(svc, b_total)
    if policy == "es":
        return baselines.equal_service(svc, b_total)
    if policy == "pp":
        return baselines.proportional(svc, b_total)
    raise ValueError(policy)


def _sample_arrivals(rng: np.random.Generator, cfg: SimConfig) -> np.ndarray:
    """Arrival period of each service: cumulative exponential gaps."""
    gaps = rng.exponential(cfg.p_arrive, size=cfg.n_services_total)
    return np.floor(np.cumsum(gaps)).astype(np.int64)


def run(cfg: SimConfig, net: network.NetworkConfig | None = None,
        state: dict | None = None, checkpoint_path: str | None = None) -> dict:
    """Simulate until every service finishes.  Returns summary + history.

    ``state`` resumes a previous partial run (see ``run_resumable`` in tests);
    ``checkpoint_path`` writes a JSON snapshot each period.
    """
    net = net or network.NetworkConfig(
        mean_clients=cfg.mean_clients, var_clients=cfg.var_clients,
        mean_pathloss_db=cfg.mean_channel_db, var_pathloss_db=cfg.var_channel_db,
    )
    rng = np.random.default_rng(cfg.seed)
    arrivals = _sample_arrivals(rng, cfg)
    # per-service static draws (channels are resampled per period around the
    # service's mean; counts are fixed at arrival)
    counts = np.clip(
        np.round(rng.normal(cfg.mean_clients, np.sqrt(max(cfg.var_clients, 1e-9)),
                            size=cfg.n_services_total)), net.k_min, None
    ).astype(np.int64)

    if state is None:
        state = {
            "period": 0,
            "rounds_done": [0] * cfg.n_services_total,
            "duration": [0] * cfg.n_services_total,
            "history": [],
        }

    period = state["period"]
    rounds_done = list(state["rounds_done"])
    duration = list(state["duration"])
    history = list(state["history"])
    k_max = int(counts.max())

    while period < cfg.max_periods:
        active = [
            i for i in range(cfg.n_services_total)
            if arrivals[i] <= period and rounds_done[i] < cfg.rounds_required
        ]
        if not active and all(
            rounds_done[i] >= cfg.rounds_required for i in range(cfg.n_services_total)
        ):
            break
        if active:
            key = jax.random.fold_in(jax.random.key(cfg.seed + 7), period)
            svc, _ = network.sample_services(
                key, len(active), net, k_max=k_max,
                client_counts=jnp.asarray(counts[active]),
            )
            b, f = _allocate(cfg.policy, svc, net.total_bandwidth_mhz, cfg)
            rounds = np.floor(np.asarray(f) * net.period_s).astype(np.int64)
            for j, i in enumerate(active):
                rounds_done[i] = min(
                    rounds_done[i] + int(rounds[j]), cfg.rounds_required
                )
                duration[i] += 1
            history.append({
                "period": period,
                "active": active,
                "freq_sum": float(jnp.sum(f)),
                "objective": float(jnp.sum(jnp.log1p(f))),
            })
        period += 1
        if checkpoint_path is not None:
            snap = {"period": period, "rounds_done": rounds_done,
                    "duration": duration, "history": history}
            tmp = checkpoint_path + ".tmp"
            with open(tmp, "w") as fp:
                json.dump(snap, fp)
            import os
            os.replace(tmp, checkpoint_path)

    return {
        "avg_duration": float(np.mean(duration)),
        "std_duration": float(np.std(duration)),
        "durations": duration,
        "periods": period,
        "history": history,
        "finished": all(r >= cfg.rounds_required for r in rounds_done),
        "state": {"period": period, "rounds_done": rounds_done,
                  "duration": duration, "history": history},
    }
