"""Federated-learning runtime: services (the paper's tuple abstraction over
real architectures), client local training, FedAvg/FedProx servers with
straggler mitigation, uplink gradient compression (feeds the allocator's
s^UT), and the multi-period wall-clock simulator behind Figs. 11-15."""
from repro.fl.service import (FLService, arch_service_tuple,  # noqa: F401
                              episode_services)
from repro.fl.client import local_update  # noqa: F401
from repro.fl.server import fedavg_round, make_fl_round_step  # noqa: F401
from repro.fl import aggregation, compression, cotrain, simulator  # noqa: F401
