"""Federated-learning runtime: services (the paper's tuple abstraction over
real architectures), client local training, FedAvg/FedProx servers with
straggler mitigation, and the multi-period wall-clock simulator behind
Figs. 11-15.

Uplink gradient compression is a closed loop, not a bolt-on: each service's
level prices its ``compression_ratio`` into the allocator's s^UT (statically
via ``arch_service_tuple``, per period via the ServiceSet's dynamic uplink
column and ``cotrain``'s compression controller), while the round step
applies the same level's lossy operator to the uploaded deltas -- with real
client-held error-feedback residuals (``make_fl_round_step``'s
``error_feedback`` mode; ``init_residuals`` builds the zero state) carried
across rounds so the withheld mass is re-injected, never dropped.
"""
from repro.fl.service import (FLService, arch_service_tuple,  # noqa: F401
                              episode_services)
from repro.fl.client import local_update  # noqa: F401
from repro.fl.server import (fedavg_round, init_residuals,  # noqa: F401
                             make_fl_round_step)
from repro.fl import aggregation, compression, cotrain, simulator  # noqa: F401
