"""Training-in-the-loop co-simulation: allocation-paced FedAvg.

The paper's evaluation compares allocation policies by what they do to
*learning* -- FL accuracy against wall-clock time (Figs. 16-17) -- not just
by round counts.  This module couples the repo's two halves end-to-end: the
fixed-capacity multi-period simulator (``fl.simulator``) paces REAL FedAvg
training (``fl.server.make_fl_round_step``), so every simulated period

  1. runs the *identical* allocation step as the duration engines
     (``simulator._period_step`` -- same RNG stream, same scenario carries,
     same ``AllocationPolicy`` registry incl. warm starts), then
  2. converts the period's allocation into training pace: the allocated
     per-client water-filling split gives each client a DT+LC+UT latency,
     clients past ``deadline_x`` times the optimal round time are dropped as
     stragglers (on top of scenario churn, which already masked them out of
     the ServiceSet; note the *optimal* split equalizes admitted latencies
     at exactly the round time, so under it the deadline is all-or-nothing
     per service -- ``deadline_x >= 1`` is a guard band admitting every
     churn survivor, ``deadline_x < 1`` models a hard budget below the
     optimum and freezes the service; partial participation loss enters
     through churn, which removes clients *before* the split), and each
     active service advances exactly the simulated number of FedAvg rounds
     (bounded by the static ``rounds_cap``; the shortfall is *counted*,
     never silent), and
  3. evaluates every service's model, accumulating per-service loss/accuracy
     curves against the cumulative allocated wall-clock.

With compression off the coupling is strictly one-way by construction:
training reads the allocation extras that ``_period_step`` already computed
and writes nothing back, so the duration stream of a co-trained episode is
**bitwise identical** to ``run_scan`` on the same config (pinned per policy
in tests/test_cotrain.py).  Turning compression on (``TrainSpec.compression``
/ ``comp_levels`` / ``comp_policy="adaptive"``) closes the loop the other
way too: each service's level prices a smaller s^UT into the allocator via
the ServiceSet's dynamic uplink column (``_period_step``'s ``ul_comp`` hook),
so compressing harder shortens rounds, shifts the bandwidth split, and moves
the accuracy-vs-allocated-wallclock frontier -- while the round step applies
the *same* level's lossy operator (with optional error-feedback residuals
riding the scan carry) to what the clients upload.  Like the duration engines, the whole episode is one
``jax.lax.scan`` (the allocation step traces exactly once per
policy x scenario combo -- ``simulator.trace_count()``), ``run_cotrain_batch``
vmaps it over seeds, and ``run_cotrain_fleet`` shards it over a one-axis
device mesh in memory-bounded chunks for Monte-Carlo accuracy bands.

Train tasks
-----------

What trains is selected by a hashable ``TrainSpec`` (a jit static):

* ``task="bigram"`` -- a (V, V) bigram-logit table fit to ``data.SyntheticLM``
  sequences by cross-entropy.  One embedding lookup per step: cheap enough
  that thousands of simulated rounds run in one compiled episode, while
  still having real signal (the chain is learnable) and a real accuracy
  (next-token argmax).  The default for tests, goldens, and paper figures.
* ``task="zoo"`` -- a smoke-scaled architecture from ``repro.configs``
  (``arch=`` zoo key, decoder-only or xLSTM families), trained on
  ``SyntheticLM`` at its own vocab size.  The CI smoke path.

Every service carries its own stacked copy of the model parameters through
the scan; per-service data streams are disjoint slices of the client-id
space, and model inits fold ``scenarios.base.COTRAIN_SALT`` into the episode
key -- a stream no other consumer reads, so co-training cannot perturb the
simulator's draws.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.core import network, policy as policy_mod
from repro.data import SyntheticLM
from repro.fl import compression as fl_comp
from repro.fl import server as fl_server
from repro.fl import service as fl_service
from repro.fl import simulator
from repro.models import registry as model_registry
from repro.scenarios.base import COTRAIN_SALT

# Disjoint client-id stripes per service slot inside one SyntheticLM stream;
# the eval stream uses the top id of each stripe (training uses 0..k_max-1,
# k_max is always far below the stripe width).
_SVC_STRIDE = 1 << 20
_EVAL_CLIENT = _SVC_STRIDE - 1
# Eval batches sit at a step index no training round ever reaches
# (training steps are round * local_steps + e, rounds < rounds_required).
_EVAL_STEP = (1 << 30) + 7


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Hashable (jit-static) description of what trains during an episode.

    ``rounds_cap`` is the static per-period bound on *executed* training
    rounds; the simulated round count is never altered by it -- periods whose
    allocation grants more rounds than the cap train ``rounds_cap`` rounds
    and the shortfall is accumulated in the summary's ``clipped_rounds`` (a
    sweep meant to be read as accuracy-vs-time should keep it at 0, e.g. by
    shortening ``NetworkConfig.period_s``).  ``deadline_x`` scales the
    straggler deadline off the optimal round time for the allocated
    bandwidth; ``float("inf")`` disables straggler drop entirely.  Because
    the optimal water-filling split equalizes admitted latencies at exactly
    the round time, the deadline is all-or-nothing per service (see the
    module docstring): values >= 1 admit everyone the churn process left,
    values < 1 drop everyone.

    Compression is a *first-class allocation control*, not just a training
    perturbation: the selected level's ``compression_ratio`` rescales the
    ServiceSet's dynamic s^UT column (``types.scale_uplink``) before the
    allocator prices the period, so compressing harder shortens rounds and
    shifts the bandwidth split.  ``compression`` sets one level for every
    service; ``comp_levels`` (a tuple cycled over the service slots)
    overrides it per service.  ``comp_policy="adaptive"`` turns the level
    into a per-period control: a service starts uncompressed and switches to
    its target level whenever its allocated share drops below
    ``comp_threshold`` times the fair share B/n_active (and back when
    bandwidth loosens).  ``error_feedback`` carries client-held compression
    residuals through the episode scan (``server.make_fl_round_step``'s EF
    mode); ``index_bits`` is the per-kept-entry index width priced into the
    top-k ratios.  All of it defaults off: ``compression="none"`` episodes
    stay bitwise identical to the duration engines and the goldens.
    """

    task: str = "bigram"              # "bigram" | "zoo"
    arch: str = "gemma3-1b"           # zoo entry (smoke-scaled) for task="zoo"
    vocab: int = 32                   # bigram table / data vocab (task="bigram")
    seq_len: int = 8
    batch_size: int = 4
    local_steps: int = 1
    eval_batch: int = 16
    client_lr: float = 0.5
    server_lr: float = 1.0
    prox_mu: float = 0.0
    compression: str = "none"         # fl.compression key, feeds the round step
    topk_frac: float = 0.01
    index_bits: int = 32              # index width priced into topk ratios
    comp_levels: tuple | None = None  # per-service levels, cycled over slots
    comp_policy: str = "static"       # "static" | "adaptive"
    comp_threshold: float = 0.5       # adaptive: compress when b < thr*fair
    error_feedback: bool = False      # client-held EF residuals in the carry
    deadline_x: float = 3.0
    rounds_cap: int = 4
    data_seed: int = 0
    data_temperature: float = 0.3
    aggregator: str = "fedavg"        # fl.aggregation registry key
    trim_frac: float = 0.1            # trimmed_mean tail fraction per side
    clip_norm: float | None = None    # norm_clip radius (None = median norm)
    byz_f: int = 1                    # krum/multi_krum assumed Byzantine count
    weight_cap: float | None = None   # server.sanitize_weights clip

    def __post_init__(self):
        if self.rounds_cap < 1:
            raise ValueError(f"rounds_cap must be >= 1, got {self.rounds_cap}")
        if self.local_steps < 1:
            raise ValueError(
                f"local_steps must be >= 1, got {self.local_steps}")
        if not self.deadline_x > 0:
            raise ValueError(
                f"deadline_x must be positive, got {self.deadline_x}")
        from repro.fl import aggregation
        if self.aggregator not in aggregation.available():
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; "
                f"available: {list(aggregation.available())}")
        if self.comp_levels is not None and (
                not isinstance(self.comp_levels, tuple)
                or not self.comp_levels):
            raise ValueError(
                f"comp_levels must be a non-empty tuple of method names "
                f"(hashable: TrainSpec is a jit static), got "
                f"{self.comp_levels!r}")
        from repro.fl import compression as fl_comp
        for level in (self.compression,) + (self.comp_levels or ()):
            if level not in fl_comp.METHODS:
                raise ValueError(
                    f"unknown compression level {level!r}; "
                    f"available: {fl_comp.METHODS}")
        if self.comp_policy not in ("static", "adaptive"):
            raise ValueError(
                f"comp_policy must be 'static' or 'adaptive', got "
                f"{self.comp_policy!r}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(
                f"topk_frac must be in (0, 1], got {self.topk_frac}")
        if not self.comp_threshold > 0:
            raise ValueError(
                f"comp_threshold must be positive, got {self.comp_threshold}")


class _Task:
    """Bundle the episode needs from a TrainSpec: per-service ``init(key)``,
    the jitted-together FedAvg ``round_step``, a ``batch_fn(svc_id, round)``
    producing the (C, E, ...) client batches, and ``eval_fn(params, svc_id)
    -> (loss, accuracy)`` on the service's held-out stream.

    ``steps`` (when the episode's compression plan needs it) is a tuple of
    round steps -- one per plan branch method, identical kwargs apart from
    ``compression`` -- dispatched per service via ``lax.switch`` (or called
    directly when the plan is uniform).  ``round_step`` stays the plain
    ``spec.compression`` step for callers outside the episode (tests, the
    launch driver's replay helpers)."""

    def __init__(self, init, round_step, batch_fn, eval_fn, steps=None):
        self.init = init
        self.round_step = round_step
        self.batch_fn = batch_fn
        self.eval_fn = eval_fn
        self.steps = steps


class _CompPlan:
    """Static (trace-time) per-service compression plan for one episode.

    ``methods``: the distinct branch methods, ``methods[0] == "none"``.
    ``level_ids``: (N,) int -- each service's *target* branch index.
    ``ratios``: per-branch s^UT multipliers (``compression_ratio``, clamped).
    ``adaptive``: whether the applied level is the per-period carry (switching
    between 0 and the target id) rather than the static target itself.
    """

    def __init__(self, methods, level_ids, ratios, adaptive):
        self.methods = methods
        self.level_ids = level_ids
        self.ratios = ratios
        self.adaptive = adaptive
        # One distinct non-none static level needs no per-service dispatch.
        self.multi = adaptive or len(set(level_ids.tolist())) > 1
        self.branch_methods = (
            methods if self.multi else (methods[int(level_ids[0])],))


def _comp_plan(spec: TrainSpec, n_total: int) -> _CompPlan | None:
    """Resolve the spec's compression knobs for an episode of ``n_total``
    service slots.  Returns None when compression is fully off -- the
    episode then runs the exact historical (bitwise-pinned) graph."""
    levels = (spec.comp_levels if spec.comp_levels is not None
              else (spec.compression,))
    levels = tuple(levels[i % len(levels)] for i in range(n_total))
    if all(m == "none" for m in levels):
        return None
    methods = ("none",) + tuple(
        dict.fromkeys(m for m in levels if m != "none"))
    level_ids = np.array([methods.index(m) for m in levels], np.int32)
    ratios = np.array(
        [fl_comp.compression_ratio(m, spec.topk_frac,
                                   index_bits=spec.index_bits)
         for m in methods], np.float32)
    return _CompPlan(methods, level_ids, ratios,
                     spec.comp_policy == "adaptive")


def _eval_metrics(logits, labels):
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                   .astype(jnp.float32))
    return -jnp.mean(ll), acc


def _stacked_batches(data: SyntheticLM, spec: TrainSpec, svc_id, round_idx,
                     k_max: int):
    """(C, E, B, S) client batches for one service's round: every client
    slot gets its own deterministic stream (masked slots are still computed
    -- their weight is 0 -- so shapes stay fixed)."""

    def one_client(c):
        per_step = [
            data.batch(round_idx * spec.local_steps + e, spec.batch_size,
                       client_id=svc_id * _SVC_STRIDE + c)
            for e in range(spec.local_steps)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)

    return jax.vmap(one_client)(jnp.arange(k_max, dtype=jnp.int32))


def _round_step_kwargs(spec: TrainSpec, attack) -> dict:
    return dict(
        local_steps=spec.local_steps, client_lr=spec.client_lr,
        server_lr=spec.server_lr, prox_mu=spec.prox_mu,
        compression=spec.compression, topk_frac=spec.topk_frac,
        error_feedback=spec.error_feedback,
        aggregator=spec.aggregator, trim_frac=spec.trim_frac,
        clip_norm=spec.clip_norm, byz_f=spec.byz_f,
        weight_cap=spec.weight_cap, attack=attack)


def _make_steps(loss_fn, spec: TrainSpec, attack, methods):
    """The default (``spec.compression``) round step plus, when the episode's
    compression plan asks for ``methods``, one step per branch method --
    identical kwargs apart from ``compression`` so every ``lax.switch``
    branch shares signature and output structure."""
    kwargs = _round_step_kwargs(spec, attack)
    round_step = fl_server.make_fl_round_step(loss_fn, **kwargs)
    steps = None
    if methods is not None:
        steps = tuple(
            round_step if m == spec.compression
            else fl_server.make_fl_round_step(
                loss_fn, **{**kwargs, "compression": m})
            for m in methods)
    return round_step, steps


def _bigram_task(spec: TrainSpec, k_max: int, attack=None,
                 methods=None) -> _Task:
    data = SyntheticLM(vocab_size=spec.vocab, seq_len=spec.seq_len,
                       seed=spec.data_seed, temperature=spec.data_temperature)

    def loss_fn(table, batch):
        logits = table[batch["tokens"]]
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                                 axis=-1)[..., 0]
        return -jnp.mean(ll)

    def init(key):
        return 0.01 * jax.random.normal(
            key, (spec.vocab, spec.vocab), jnp.float32)

    round_step, steps = _make_steps(loss_fn, spec, attack, methods)

    def batch_fn(svc_id, round_idx):
        return _stacked_batches(data, spec, svc_id, round_idx, k_max)

    def eval_fn(table, svc_id):
        batch = data.batch(_EVAL_STEP, spec.eval_batch,
                           client_id=svc_id * _SVC_STRIDE + _EVAL_CLIENT)
        return _eval_metrics(table[batch["tokens"]], batch["labels"])

    return _Task(init, round_step, batch_fn, eval_fn, steps)


def _zoo_task(spec: TrainSpec, k_max: int, attack=None,
              methods=None) -> _Task:
    from repro import configs

    cfg = configs.get_smoke_config(spec.arch)
    if cfg.family == "encdec":
        raise ValueError(
            f"zoo co-training supports decoder-only/ssm families; "
            f"{spec.arch!r} is encoder-decoder (needs modality frontends)")
    model = model_registry.build_model(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=spec.seq_len,
                       seed=spec.data_seed, temperature=spec.data_temperature)

    round_step, steps = _make_steps(model.loss, spec, attack, methods)

    def batch_fn(svc_id, round_idx):
        return _stacked_batches(data, spec, svc_id, round_idx, k_max)

    def eval_fn(params, svc_id):
        batch = data.batch(_EVAL_STEP, spec.eval_batch,
                           client_id=svc_id * _SVC_STRIDE + _EVAL_CLIENT)
        logits = model.forward(params, batch["tokens"])[0]
        return _eval_metrics(logits, batch["labels"])

    return _Task(model.init, round_step, batch_fn, eval_fn, steps)


def _build_task(spec: TrainSpec, k_max: int, attack=None,
                methods=None) -> _Task:
    if spec.task == "bigram":
        return _bigram_task(spec, k_max, attack, methods)
    if spec.task == "zoo":
        return _zoo_task(spec, k_max, attack, methods)
    raise ValueError(
        f"unknown train task {spec.task!r}; expected 'bigram' or 'zoo'")


# ---------------------------------------------------------------------------
# The co-trained episode: one lax.scan, allocation step traced once.
# ---------------------------------------------------------------------------

_COTRAIN_STATICS = simulator._EPISODE_STATICS + ("train", "attack")


def _cotrain_episode_impl(arrivals, counts, key, *, train, attack, policy,
                          net, n_total, k_max, rounds_required, max_periods,
                          n_bids, alpha_fair, intra_backend, warm_start,
                          collect_history, collect_alloc, channel, churn):
    # -- identical construction to simulator._episode_impl: the allocation
    # side of the scan must be indistinguishable from the duration engine.
    pol = policy_mod.get_stateful_policy(
        policy, warm_start=warm_start, n_bids=n_bids, alpha_fair=alpha_fair,
        intra_backend=intra_backend,
    )
    chan_proc = scenarios.get_channel(channel, net)
    churn_proc = scenarios.get_churn(churn, net)

    # -- the training side: task closures + the allocated-latency model.
    # The compression plan decides which round-step branches exist and what
    # s^UT multiplier the allocator prices each period; None (compression
    # fully off) runs the exact historical graph.
    plan = _comp_plan(train, n_total)
    ef = train.error_feedback
    task = _build_task(
        train, k_max, attack,
        methods=(plan.branch_methods if plan is not None else ("none",)))
    split_fn = policy_mod.client_split_fn(intra_backend)
    time_fn = policy_mod.round_time_fn(intra_backend)
    svc_ids = jnp.arange(n_total, dtype=jnp.int32)
    k_init = jax.random.fold_in(key, COTRAIN_SALT)
    params0 = jax.vmap(lambda i: task.init(jax.random.fold_in(k_init, i)))(
        svc_ids)
    # Client-held EF residual state: params-shaped with (N, k_max) leading
    # axes, zero-init; () when EF is off so the default carry is unchanged.
    resid0 = () if not ef else jax.tree.map(
        lambda p: jnp.zeros((n_total, k_max) + p.shape[1:], p.dtype), params0)
    if plan is not None:
        level_ids = jnp.asarray(plan.level_ids)
        ratios = jnp.asarray(plan.ratios)
    # Adaptive plans carry the applied per-service branch id across periods
    # (a service starts uncompressed); static plans close over the constant.
    comp0 = (jnp.zeros((n_total,), jnp.int32)
             if plan is not None and plan.adaptive else ())
    if attack is not None:
        # Host-side (trace-time) Byzantine plan on the chaos channels: a
        # deterministic function of the static AttackSpec, so the compiled
        # episode replays the attack bitwise and the fleet cache stays
        # consistent.  Shared across seeds by design (the attacker does not
        # re-roll per episode).
        from repro.chaos import clients as chaos_clients
        byz_plan = jnp.asarray(chaos_clients.ClientChaos(attack).plan(
            max_periods, n_total, k_max))

    def train_service(svc_id, params, resid, comp_id, first_round, n_rounds,
                      weights, byz=None):
        """Advance one service ``n_rounds`` FedAvg rounds (static bound
        ``rounds_cap``; skipped rounds are identity on params -- and, under
        EF, on the clients' residuals).  ``comp_id`` indexes the plan's
        round-step branches when the plan is per-service/adaptive; with a
        single branch it is unused and the step is called directly."""

        def body(carry, r):
            p, rs = carry
            do = r < n_rounds
            batches = task.batch_fn(svc_id, first_round + r)
            args = ((p, batches, weights) + ((rs,) if ef else ())
                    + (() if attack is None else (byz,)))
            if plan is not None and plan.multi:
                out = jax.lax.switch(comp_id, task.steps, *args)
            else:
                out = task.steps[0](*args)
            if ef:
                new_p, metrics, new_rs = out
                rs = jax.tree.map(
                    lambda a, b: jnp.where(do, a, b), new_rs, rs)
            else:
                new_p, metrics = out
            p = jax.tree.map(
                lambda a, b: jnp.where(do, a, b), new_p, p)
            return (p, rs), jnp.where(do, metrics["loss"], 0.0)

        (params, resid), losses = jax.lax.scan(
            body, (params, resid),
            jnp.arange(train.rounds_cap, dtype=jnp.int32))
        mean_loss = jnp.sum(losses) / jnp.maximum(n_rounds, 1)
        return params, resid, mean_loss

    def step(carry, period):
        if attack is not None:
            period, byz_p = period
        (rounds_done, duration, chan_state, churn_state, pol_state,
         params, resid, comp_ids, trained, clipped) = carry
        prev_rounds = rounds_done
        # The branch ids applied THIS period (allocation and training must
        # agree on what each service transmits): the carried control for
        # adaptive plans, the static targets otherwise.
        if plan is None:
            applied_ids = jnp.zeros((n_total,), jnp.int32)
            ul_comp = None
        else:
            applied_ids = comp_ids if plan.adaptive else level_ids
            ul_comp = ratios[applied_ids]
        (rounds_done, duration, chan_state, churn_state, pol_state, stats,
         ex) = simulator._period_step(
            rounds_done, duration, chan_state, churn_state, pol_state,
            period, arrivals, counts, key, None, ul_comp,
            policy_fn=pol.step, chan_step=chan_proc.step,
            churn_step=churn_proc.step, chan_rebuilds=chan_proc.rebuilds,
            net=net, n_total=n_total, k_max=k_max,
            rounds_required=rounds_required,
        )
        svc, b, f, active = ex["svc"], ex["b"], ex["f"], ex["active"]
        # Rounds that actually count toward the episode (the same clamp the
        # duration engine applies to rounds_done), then the executed subset.
        eff = jnp.where(
            active, jnp.minimum(ex["rounds"], rounds_required - prev_rounds),
            0)
        n_train = jnp.minimum(eff, train.rounds_cap)
        clipped = clipped + jnp.sum(eff - n_train)
        # Allocated per-client DT+LC+UT latency -> straggler weights.  The
        # deadline anchors at the optimal round time for the allocated
        # bandwidth; churned clients are already outside svc.mask.
        t_round = time_fn(svc, b)
        b_clients = split_fn(svc, b)
        lat = svc.t_comp + svc.alpha / jnp.maximum(b_clients, 1e-30)
        admitted = jnp.logical_and(
            svc.mask, jnp.where(svc.mask, lat, jnp.inf)
            <= train.deadline_x * t_round[:, None])
        weights = admitted.astype(jnp.float32)
        if attack is None:
            params, resid, train_loss = jax.vmap(train_service)(
                svc_ids, params, resid, applied_ids, trained, n_train,
                weights)
        else:
            params, resid, train_loss = jax.vmap(train_service)(
                svc_ids, params, resid, applied_ids, trained, n_train,
                weights, byz_p)
        trained = trained + n_train
        ev_loss, ev_acc = jax.vmap(task.eval_fn)(params, svc_ids)
        # Adaptive control for the NEXT period, from this period's split: a
        # service whose share fell below comp_threshold x the fair share
        # B/n_active switches to its target level; one that recovered
        # switches back to dense (reactive, one-period lag by construction
        # -- the allocator must price what the clients actually transmit).
        if plan is not None and plan.adaptive:
            n_active = jnp.sum(active.astype(jnp.float32))
            fair = net.total_bandwidth_mhz / jnp.maximum(n_active, 1.0)
            tight = jnp.logical_and(
                active, b < train.comp_threshold * fair)
            comp_ids = jnp.where(tight, level_ids, 0).astype(jnp.int32)
        out = {
            "loss": ev_loss, "acc": ev_acc, "train_loss": train_loss,
            "b": b, "f": f, "active": active, "rounds": eff,
            "trained": n_train,
            # clients that actually trained this period: 0 when no round
            # executed, else the admitted (deadline + churn survivors) count
            "participants": jnp.where(
                n_train > 0,
                jnp.sum(weights, axis=-1).astype(jnp.int32), 0),
            # the applied compression record: branch id + s^UT multiplier
            "comp_id": applied_ids,
            "ul_mult": (ul_comp if ul_comp is not None
                        else jnp.ones((n_total,), jnp.float32)),
            "freq_sum": stats["freq_sum"], "objective": stats["objective"],
            "all_done": stats["all_done"],
        }
        carry = (rounds_done, duration, chan_state, churn_state, pol_state,
                 params, resid, comp_ids, trained, clipped)
        return carry, out

    init = (jnp.zeros((n_total,), jnp.int32), jnp.zeros((n_total,), jnp.int32),
            chan_proc.init(key, n_total, k_max),
            churn_proc.init(key, n_total, k_max),
            pol.init_state(n_total), params0, resid0, comp0,
            jnp.zeros((n_total,), jnp.int32), jnp.int32(0))
    periods = jnp.arange(max_periods, dtype=jnp.int32)
    xs = periods if attack is None else (periods, byz_plan)
    (rounds_done, duration, _, _, _, params, _, _, trained, clipped), hist = (
        jax.lax.scan(step, init, xs))
    return rounds_done, duration, trained, clipped, params, hist


_cotrain_episode = functools.partial(
    jax.jit, static_argnames=_COTRAIN_STATICS)(_cotrain_episode_impl)


@functools.partial(jax.jit, static_argnames=_COTRAIN_STATICS)
def _cotrain_episode_batch(arrivals, counts, keys, *, train, **statics):
    def one(a, c, k):
        return _cotrain_episode_impl(a, c, k, train=train, **statics)

    return jax.vmap(one)(arrivals, counts, keys)


@functools.lru_cache(maxsize=None)
def _cotrain_fleet_fn(mesh, axis: str, n_chunks: int, chunk: int,
                      statics_items):
    """Compiled co-training fleet sweep over ``simulator.sharded_chunked_fn``
    (same mesh/chunk geometry and donation rules as ``run_fleet``)."""
    statics = dict(statics_items)

    def episode(arrivals, counts, key_data):
        return _cotrain_episode_impl(
            arrivals, counts, jax.random.wrap_key_data(key_data), **statics)

    return simulator.sharded_chunked_fn(mesh, axis, n_chunks, chunk, episode)


# ---------------------------------------------------------------------------
# Entry points + summaries.
# ---------------------------------------------------------------------------

_CURVE_KEYS = ("loss", "acc", "train_loss", "b", "f", "active", "rounds",
               "trained", "participants", "comp_id", "ul_mult",
               "freq_sum", "objective")


def _statics(cfg: simulator.SimConfig, train: TrainSpec,
             net: network.NetworkConfig, attack=None) -> dict:
    return dict(train=train, attack=attack,
                **simulator._episode_statics(cfg, net, simulator._k_cap(cfg)))


def _summarize_episode(cfg: simulator.SimConfig,
                       net: network.NetworkConfig, arrivals, counts,
                       rounds_done, duration, trained, clipped, params,
                       hist) -> dict:
    duration = np.asarray(duration)
    done = np.asarray(hist["all_done"])
    periods = int(np.argmax(done)) + 1 if done.any() else cfg.max_periods
    return {
        "avg_duration": float(np.mean(duration)),
        "std_duration": float(np.std(duration)),
        "durations": [int(d) for d in duration],
        "periods": periods,
        "finished": bool(np.all(np.asarray(rounds_done)
                                >= cfg.rounds_required)),
        "trained_rounds": [int(t) for t in np.asarray(trained)],
        "clipped_rounds": int(clipped),
        "time_s": np.arange(1, periods + 1) * net.period_s,
        "history": {k: np.asarray(hist[k])[:periods] for k in _CURVE_KEYS},
        "services": fl_service.episode_services(
            np.asarray(arrivals), np.asarray(counts),
            np.asarray(rounds_done), duration, cfg.rounds_required),
        "params": params,
    }


def run_cotrain_scan(cfg: simulator.SimConfig, train: TrainSpec | None = None,
                     net: network.NetworkConfig | None = None, *,
                     attack=None) -> dict:
    """Co-train one episode (one compiled ``lax.scan``).

    Returns the ``run_scan`` summary keys (durations bitwise identical to
    ``run_scan(cfg)``) plus the learning record: per-period ``history``
    curves (eval ``loss``/``acc``, executed/simulated rounds, per-service
    bandwidth), the ``time_s`` wall-clock axis, per-service
    ``trained_rounds`` / ``clipped_rounds`` totals, the final stacked model
    ``params``, and ``services`` -- the episode's ``FLService`` bookkeeping.

    ``attack`` (a ``chaos.clients.AttackSpec``) turns a seeded fraction of
    client slots Byzantine; the allocation stream is untouched (the attack
    only perturbs uploaded deltas/weights), so durations stay bitwise equal
    to ``run_scan`` even under attack.
    """
    train = train or TrainSpec()
    net = net or simulator._default_net(cfg)
    arrivals, counts = simulator._static_draws(cfg, net)
    rounds_done, duration, trained, clipped, params, hist = _cotrain_episode(
        jnp.asarray(arrivals, jnp.int32), jnp.asarray(counts, jnp.int32),
        jax.random.key(cfg.seed + 7), **_statics(cfg, train, net, attack),
    )
    return _summarize_episode(cfg, net, arrivals, counts, rounds_done,
                              duration, trained, clipped, params, hist)


def _summarize_batch(cfg: simulator.SimConfig, net: network.NetworkConfig,
                     seeds, arrivals, counts, rounds_done, duration, trained,
                     clipped, params, hist) -> dict:
    duration = np.asarray(duration)
    done = np.asarray(hist["all_done"])                      # (S, T)
    periods = np.where(done.any(axis=1), np.argmax(done, axis=1) + 1,
                       cfg.max_periods)
    rounds_done = np.asarray(rounds_done)
    return {
        "seeds": list(seeds),
        "avg_duration": duration.mean(axis=1),
        "std_duration": duration.std(axis=1),
        "durations": duration,
        "periods": periods,
        "finished": np.all(rounds_done >= cfg.rounds_required, axis=1),
        "trained_rounds": np.asarray(trained),
        "clipped_rounds": np.asarray(clipped),
        "time_s": np.arange(1, cfg.max_periods + 1) * net.period_s,
        "history": {k: np.asarray(hist[k]) for k in _CURVE_KEYS},
        "services": [
            fl_service.episode_services(
                np.asarray(arrivals)[i], np.asarray(counts)[i],
                rounds_done[i], duration[i], cfg.rounds_required)
            for i in range(len(seeds))
        ],
        "params": params,
    }


def run_cotrain_batch(cfg: simulator.SimConfig,
                      train: TrainSpec | None = None, seeds=(0,),
                      net: network.NetworkConfig | None = None, *,
                      attack=None) -> dict:
    """Co-trained scenario sweep: the compiled episode vmapped over seeds.

    Same batching contract as ``simulator.run_batch``: every episode is
    bitwise identical to its own ``run_cotrain_scan`` regardless of which
    other seeds share the batch.  History curves come back stacked
    (S, max_periods, N) with the per-seed episode length in ``periods``.
    """
    train = train or TrainSpec()
    net = net or simulator._default_net(cfg)
    seeds = list(seeds)
    if not seeds:
        raise ValueError("run_cotrain_batch needs at least one seed")
    keys = simulator._episode_keys(seeds)
    arrivals, counts = simulator._draws(
        keys, **simulator._draw_statics(cfg, net))
    out = _cotrain_episode_batch(arrivals, counts, keys,
                                 **_statics(cfg, train, net, attack))
    return _summarize_batch(cfg, net, seeds, arrivals, counts, *out)


def run_cotrain_fleet(cfg: simulator.SimConfig,
                      train: TrainSpec | None = None, seeds=(0,),
                      net: network.NetworkConfig | None = None, *,
                      mesh=None, chunk_size: int | None = None,
                      attack=None) -> dict:
    """Device-sharded, memory-bounded co-training sweep (Monte-Carlo
    accuracy bands): ``simulator.run_fleet`` geometry -- one-axis mesh over
    the seed axis, fixed-size chunks per device -- around the co-trained
    episode.  Per-seed outputs are bitwise identical to
    ``run_cotrain_batch`` under every mesh/chunk/remainder combination."""
    train = train or TrainSpec()
    net = net or simulator._default_net(cfg)
    seeds = [int(s) for s in seeds]
    mesh, axis, n_dev, chunk, n_chunks, padded = simulator.fleet_geometry(
        seeds, mesh, chunk_size)
    keys = simulator._episode_keys(padded)
    arrivals, counts = simulator._draws(
        keys, **simulator._draw_statics(cfg, net))
    # Host copies before the call: the compiled sweep donates these buffers.
    arrivals_host = np.asarray(arrivals)[:len(seeds)]
    counts_host = np.asarray(counts)[:len(seeds)]
    statics = _statics(cfg, train, net, attack)
    fn = _cotrain_fleet_fn(mesh, axis, n_chunks, chunk,
                           tuple(statics.items()))
    out = jax.tree_util.tree_map(
        lambda x: x[:len(seeds)],
        fn(arrivals, counts, jax.random.key_data(keys)))
    summary = _summarize_batch(cfg, net, seeds, arrivals_host, counts_host,
                               *out)
    summary["fleet"] = {"n_devices": n_dev, "mesh_axis": axis, "chunk": chunk,
                        "n_chunks": n_chunks, "padded_to": len(padded)}
    return summary
