"""Uplink gradient compression with error feedback.

Compression shrinks the UT payload s^UT, which feeds straight back into the
allocator's alpha_{n,k} = s^DT/r^DT + s^UT/r^UT -- the paper's tuple
abstraction makes communication-efficiency methods and bandwidth allocation
compose cleanly (DESIGN.md §3.5).  The loop is closed end-to-end by the
co-simulation: ``cotrain.TrainSpec`` selects a per-service level (static or
adaptive), ``compression_ratio`` prices it into the ServiceSet's dynamic
s^UT column (``types.scale_uplink``) *before* the allocator runs, and the
round step applies the matching lossy operator to the uploaded deltas -- so
compressing harder buys shorter rounds at the price of noisier updates.

Implemented: top-k magnitude sparsification (per-leaf) and symmetric int8
quantization, both with client-held error-feedback residuals so the lossy
round-trip error is re-injected next round (Karimireddy et al. style).  The
residuals are live in training, not just available here:
``server.make_fl_round_step(error_feedback=True)`` threads per-client
residual state through every round (and ``fl.cotrain`` carries it through
the episode scan), gated on participation so a straggler's withheld mass is
neither dropped nor double-counted.  ``compression_ratio`` reports the s^UT
multiplier the service plugs into ``arch_service_tuple`` -- clamped at 1.0,
since "compressing" must never price an upload above dense.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

# Registry of uplink compression methods, in the order the co-simulation's
# per-service branch ids use ("none" is always id 0).
METHODS = ("none", "topk", "int8", "topk_int8")


def topk_sparsify(delta, k_frac: float, residual=None):
    """Keep the top k_frac fraction (by magnitude) of each leaf.
    Returns (sparse_delta, new_residual).

    Selection is by ``top_k`` *indices* + scatter, so exactly k entries are
    transmitted per leaf: a threshold compare (``|x| >= thresh``) would keep
    every tied entry -- and, on an all-zero leaf (thresh 0), the whole leaf
    -- making ``compression_ratio`` under-report the actual upload.  Ties
    resolve to ``top_k``'s deterministic lowest-index winners.
    """
    if residual is not None:
        delta = jax.tree.map(lambda d, r: d + r.astype(d.dtype), delta, residual)

    def one(x):
        n = x.size
        k = max(1, int(n * k_frac))
        flat = x.reshape(-1)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return kept.reshape(x.shape)

    sparse = jax.tree.map(one, delta)
    new_residual = jax.tree.map(lambda d, s: d - s, delta, sparse)
    return sparse, new_residual


def int8_quantize(delta, residual=None):
    """Symmetric per-leaf int8 quantization.  Returns (dequantized, residual)."""
    if residual is not None:
        delta = jax.tree.map(lambda d, r: d + r.astype(d.dtype), delta, residual)

    def one(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127)
        return q * scale

    deq = jax.tree.map(one, delta)
    new_residual = jax.tree.map(lambda d, s: d - s, delta, deq)
    return deq, new_residual


def compress(method: str, delta, k_frac: float = 0.01, residual=None):
    """Apply ``method`` to a delta pytree.  Returns (compressed, residual').

    One dispatch for every registry entry so the server and tests cannot
    drift from the ratio pricing: ``"none"`` is the identity compressor --
    under error feedback it *flushes* the carried residual (the dense upload
    has room for the backlog a lossy period withheld, exactly what an
    adaptive controller switching back to uncompressed should do) and on a
    zero residual it is a bitwise no-op; ``"topk_int8"`` composes the two
    lossy stages under ONE residual (the error-feedback state absorbs the
    *total* round-trip error of the composition, not just the first
    stage's).
    """
    if method == "none":
        if residual is None:
            return delta, None
        flushed = jax.tree.map(lambda d, r: d + r.astype(d.dtype),
                               delta, residual)
        return flushed, jax.tree.map(jnp.zeros_like, residual)
    if method == "topk":
        return topk_sparsify(delta, k_frac, residual)
    if method == "int8":
        return int8_quantize(delta, residual)
    if method == "topk_int8":
        if residual is not None:
            delta = jax.tree.map(lambda d, r: d + r.astype(d.dtype),
                                 delta, residual)
        sparse, _ = topk_sparsify(delta, k_frac)
        deq, _ = int8_quantize(sparse)
        new_residual = jax.tree.map(lambda d, s: d - s, delta, deq)
        return deq, new_residual
    raise ValueError(
        f"unknown compression method {method!r}; available: {METHODS}")


def compression_ratio(method: str, k_frac: float = 0.01,
                      weight_bits: int = 32, index_bits: int = 32) -> float:
    """s^UT multiplier vs dense fp32 upload, clamped to <= 1.0.

    Top-k transmits values + indices, so at large ``k_frac`` (or wide
    ``index_bits``) the naive ratio exceeds 1.0 -- a "compressed" upload
    priced *above* dense.  That can never be what the allocator should see
    (a client would just send the dense tensor), so ratios are clamped at
    1.0 with a warning instead of silently inflating s^UT.
    """
    if method not in METHODS:
        raise ValueError(
            f"unknown compression method {method!r}; available: {METHODS}")
    if method != "none" and "topk" in method and not 0.0 < k_frac <= 1.0:
        raise ValueError(f"k_frac must be in (0, 1], got {k_frac}")
    if method == "none":
        return 1.0
    if method == "int8":
        ratio = 8.0 / weight_bits
    elif method == "topk":
        # values + indices for the kept entries
        ratio = k_frac * (weight_bits + index_bits) / weight_bits
    else:  # topk_int8
        ratio = k_frac * (8.0 + index_bits) / weight_bits
    if ratio > 1.0:
        warnings.warn(
            f"compression_ratio({method!r}, k_frac={k_frac}, "
            f"index_bits={index_bits}) = {ratio:.3f} exceeds dense; "
            f"clamping s^UT multiplier to 1.0 (send dense instead)",
            stacklevel=2)
        return 1.0
    return float(ratio)
