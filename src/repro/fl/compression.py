"""Uplink gradient compression with error feedback.

Compression shrinks the UT payload s^UT, which feeds straight back into the
allocator's alpha_{n,k} = s^DT/r^DT + s^UT/r^UT -- the paper's tuple
abstraction makes communication-efficiency methods and bandwidth allocation
compose cleanly (DESIGN.md §3.5).

Implemented: top-k magnitude sparsification (per-leaf) and symmetric int8
quantization, both with client-held error-feedback residuals so the lossy
round-trip error is re-injected next round (Karimireddy et al. style).
``compression_ratio`` reports the s^UT multiplier the service plugs into
``arch_service_tuple``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_sparsify(delta, k_frac: float, residual=None):
    """Keep the top k_frac fraction (by magnitude) of each leaf.
    Returns (sparse_delta, new_residual).

    Selection is by ``top_k`` *indices* + scatter, so exactly k entries are
    transmitted per leaf: a threshold compare (``|x| >= thresh``) would keep
    every tied entry -- and, on an all-zero leaf (thresh 0), the whole leaf
    -- making ``compression_ratio`` under-report the actual upload.  Ties
    resolve to ``top_k``'s deterministic lowest-index winners.
    """
    if residual is not None:
        delta = jax.tree.map(lambda d, r: d + r.astype(d.dtype), delta, residual)

    def one(x):
        n = x.size
        k = max(1, int(n * k_frac))
        flat = x.reshape(-1)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return kept.reshape(x.shape)

    sparse = jax.tree.map(one, delta)
    new_residual = jax.tree.map(lambda d, s: d - s, delta, sparse)
    return sparse, new_residual


def int8_quantize(delta, residual=None):
    """Symmetric per-leaf int8 quantization.  Returns (dequantized, residual)."""
    if residual is not None:
        delta = jax.tree.map(lambda d, r: d + r.astype(d.dtype), delta, residual)

    def one(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127)
        return q * scale

    deq = jax.tree.map(one, delta)
    new_residual = jax.tree.map(lambda d, s: d - s, delta, deq)
    return deq, new_residual


def compression_ratio(method: str, k_frac: float = 0.01,
                      weight_bits: int = 32, index_bits: int = 32) -> float:
    """s^UT multiplier vs dense fp32 upload."""
    if method == "none":
        return 1.0
    if method == "int8":
        return 8.0 / weight_bits
    if method == "topk":
        # values + indices for the kept entries
        return k_frac * (weight_bits + index_bits) / weight_bits
    if method == "topk_int8":
        return k_frac * (8.0 + index_bits) / weight_bits
    raise ValueError(method)
