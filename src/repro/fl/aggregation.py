"""Byzantine-robust aggregation: a string-keyed registry over the FedAvg
server, mirroring ``core.policy``'s AllocationPolicy registry.

Every aggregator has the same pure signature

    agg(deltas, weights) -> aggregated_delta

with ``deltas`` a pytree whose leaves carry a leading client axis (C, ...)
and ``weights`` (C,) where zero marks a dropped straggler.  All of them are

* **mask-aware** -- a dropped client (weight 0) never contributes, not even
  a non-finite delta (``where`` masks, never bare multiplies; the all-dropped
  round returns an exactly-zero delta);
* **jit-compatible and vmap/fleet-safe** -- static shapes only, the
  participant count enters through comparisons and dynamic gathers, never
  through shapes, so one trace serves every episode in a fleet sweep;
* **attack-hardened** -- a *participating* client whose delta contains
  NaN/Inf is treated as Byzantine and excluded before any reduction (the
  robust aggregators; plain ``fedavg`` keeps the seed semantics where only
  the weight mask protects you, which is exactly the breakage the robust
  registry exists to fix).

Registry entries:

* ``fedavg``       -- ``fl.server.fedavg_round`` itself (the bitwise-pinned
                      default path; cotrain goldens ride on it).
* ``trimmed_mean`` -- coordinate-wise trimmed mean: per coordinate sort the
                      participating values, drop the ``trim_frac`` tails,
                      average the middle (Yin et al. 2018).
* ``median``       -- coordinate-wise median over participants.
* ``norm_clip``    -- FedAvg over per-client global-L2-clipped deltas; the
                      clip radius is ``clip_norm`` or, when None, the median
                      participant norm (parameter-free, scale-adaptive).
* ``krum`` / ``multi_krum`` -- select the client(s) with the smallest sum of
                      squared distances to their n-f-2 nearest neighbours
                      (Blanchard et al. 2017); ``multi_krum`` averages the
                      best n-f.

Robust aggregators deliberately ignore weight *magnitudes* and use only the
participation mask (w > 0): trusting client-reported weights is itself an
attack surface (see ``ClientChaos``'s inflate_weight attack and the capped
``fedavg_round``).
"""
from __future__ import annotations

import functools
import inspect
from typing import Callable

import jax
import jax.numpy as jnp

# Finite-but-huge pairwise distance for invalid pairs: keeps Krum scores
# finite for every participant (an all-inf score row would let argmin land
# on a non-participating slot).
_FAR = 1e30

_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def available() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_aggregator(
    name: str,
    *,
    trim_frac: float = 0.1,
    clip_norm: float | None = None,
    byz_f: int = 1,
    **unknown,
) -> Callable:
    """Build ``agg(deltas, weights)`` by registry name.

    Options are per-family (unused ones are ignored by the factory, unknown
    ones are rejected here, mirroring ``core.policy.get_policy``):
    ``trim_frac`` (trimmed_mean), ``clip_norm`` (norm_clip; None = adaptive
    median-norm), ``byz_f`` (krum/multi_krum's assumed Byzantine count).
    """
    if unknown:
        raise ValueError(
            f"unknown aggregator options {sorted(unknown)}; "
            f"known: {sorted(KNOWN_OPTIONS)}")
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown aggregator {name!r}; available: {list(available())}")
    return _REGISTRY[name](trim_frac=trim_frac, clip_norm=clip_norm,
                           byz_f=byz_f)


KNOWN_OPTIONS = frozenset(
    p for p in inspect.signature(get_aggregator).parameters
    if p not in ("name", "unknown"))


# ---------------------------------------------------------------------------
# Shared mask plumbing.
# ---------------------------------------------------------------------------

def participation(deltas, weights) -> jax.Array:
    """(C,) bool: clients that both met the deadline (w > 0) and sent an
    entirely finite delta.  The robust aggregators reduce only over this
    set, so a NaN/Inf update is equivalent to the client having dropped."""
    part = weights > 0
    for leaf in jax.tree.leaves(deltas):
        axes = tuple(range(1, leaf.ndim))
        part = jnp.logical_and(part, jnp.all(jnp.isfinite(leaf), axis=axes))
    return part


def _bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


def _masked_sorted(leaf: jax.Array, part: jax.Array) -> jax.Array:
    """Sort along the client axis with non-participants pushed to the top
    (+inf), so positions 0..m-1 hold exactly the participating values."""
    vals = jnp.where(_bcast(part, leaf), leaf, jnp.inf)
    return jnp.sort(vals, axis=0)


def _flatten_clients(deltas) -> jax.Array:
    """(C, D) float32 matrix of per-client flattened deltas."""
    leaves = jax.tree.leaves(deltas)
    c = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.reshape(c, -1).astype(jnp.float32) for leaf in leaves], axis=1)


# ---------------------------------------------------------------------------
# Implementations.
# ---------------------------------------------------------------------------

@register("fedavg")
def _fedavg(**_opts):
    from repro.fl import server as fl_server  # circular at module load
    return fl_server.fedavg_round


@register("trimmed_mean")
def _trimmed_mean(*, trim_frac: float, **_opts):
    if not 0.0 <= trim_frac < 0.5:
        raise ValueError(f"trim_frac must be in [0, 0.5), got {trim_frac}")

    def agg(deltas, weights):
        part = participation(deltas, weights)
        m = jnp.sum(part.astype(jnp.int32))
        t = jnp.floor(trim_frac * m).astype(jnp.int32)

        def one(leaf):
            srt = _masked_sorted(leaf, part)
            pos = _bcast(jnp.arange(leaf.shape[0], dtype=jnp.int32), leaf)
            keep = jnp.logical_and(pos >= t, pos < m - t)
            num = jnp.sum(jnp.where(keep, srt, jnp.zeros_like(srt)), axis=0)
            cnt = jnp.maximum(m - 2 * t, 1).astype(leaf.dtype)
            return jnp.where(m > 0, num / cnt, jnp.zeros_like(num))

        return jax.tree.map(one, deltas)

    return agg


@register("median")
def _median(**_opts):
    def agg(deltas, weights):
        part = participation(deltas, weights)
        m = jnp.sum(part.astype(jnp.int32))
        lo_i = jnp.maximum((m - 1) // 2, 0)
        hi_i = jnp.maximum(m // 2, 0)

        def one(leaf):
            srt = _masked_sorted(leaf, part)
            med = 0.5 * (jnp.take(srt, lo_i, axis=0)
                         + jnp.take(srt, hi_i, axis=0))
            return jnp.where(m > 0, med.astype(leaf.dtype),
                             jnp.zeros_like(med, leaf.dtype))

        return jax.tree.map(one, deltas)

    return agg


@register("norm_clip")
def _norm_clip(*, clip_norm: float | None, **_opts):
    if clip_norm is not None and not clip_norm > 0:
        raise ValueError(f"clip_norm must be positive or None, got {clip_norm}")
    from repro.fl import server as fl_server

    def agg(deltas, weights):
        part = participation(deltas, weights)
        flat = _flatten_clients(deltas)
        sq = jnp.sum(jnp.where(part[:, None], flat, 0.0) ** 2, axis=1)
        norms = jnp.sqrt(sq)                                       # (C,)
        if clip_norm is None:
            # Adaptive radius: median participant norm (itself robust).
            m = jnp.sum(part.astype(jnp.int32))
            srt = jnp.sort(jnp.where(part, norms, jnp.inf))
            radius = 0.5 * (srt[jnp.maximum((m - 1) // 2, 0)]
                            + srt[jnp.maximum(m // 2, 0)])
            radius = jnp.where(m > 0, radius, 0.0)
        else:
            radius = jnp.asarray(clip_norm, norms.dtype)
        factor = jnp.minimum(1.0, radius / jnp.maximum(norms, 1e-30))
        clipped = jax.tree.map(
            lambda leaf: leaf * _bcast(factor, leaf).astype(leaf.dtype),
            deltas)
        return fl_server.fedavg_round(
            clipped, jnp.where(part, weights, jnp.zeros_like(weights)))

    return agg


def _krum_scores(deltas, weights):
    """(part, scores): Krum score per client = sum of squared distances to
    its m - byz_f - 2 nearest participating neighbours.  Non-participants
    score +inf; invalid pairs contribute the finite ``_FAR`` so a lone
    participant still wins the argmin."""
    part = participation(deltas, weights)
    flat = jnp.where(part[:, None], _flatten_clients(deltas), 0.0)
    c = flat.shape[0]
    sq = jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    valid = jnp.logical_and(part[:, None], part[None, :])
    valid = jnp.logical_and(valid, ~jnp.eye(c, dtype=bool))
    d2 = jnp.where(valid, sq, _FAR)
    return part, d2


def _make_krum(byz_f: int, multi: bool):
    if byz_f < 0:
        raise ValueError(f"byz_f must be >= 0, got {byz_f}")

    def agg(deltas, weights):
        part, d2 = _krum_scores(deltas, weights)
        c = d2.shape[0]
        m = jnp.sum(part.astype(jnp.int32))
        k = jnp.clip(m - byz_f - 2, 1, jnp.maximum(m - 1, 1))
        srt = jnp.sort(d2, axis=1)
        pos = jnp.arange(c, dtype=jnp.int32)[None, :]
        scores = jnp.sum(jnp.where(pos < k, srt, 0.0), axis=1)
        scores = jnp.where(part, scores, jnp.inf)
        if multi:
            n_sel = jnp.clip(m - byz_f, 1, c)
            rank = jnp.argsort(jnp.argsort(scores))
            sel = jnp.logical_and(rank < n_sel, part)
            n_sel = jnp.maximum(jnp.sum(sel.astype(jnp.int32)), 1)

            def one(leaf):
                num = jnp.sum(
                    jnp.where(_bcast(sel, leaf), leaf, jnp.zeros_like(leaf)),
                    axis=0)
                out = num / n_sel.astype(leaf.dtype)
                return jnp.where(m > 0, out, jnp.zeros_like(out))
        else:
            winner = jnp.argmin(scores)

            def one(leaf):
                out = jnp.take(leaf, winner, axis=0)
                # the winner is a participant, hence finite, but keep the
                # empty-round identity exact
                return jnp.where(m > 0, out, jnp.zeros_like(out))

        return jax.tree.map(one, deltas)

    return agg


@register("krum")
def _krum(*, byz_f: int, **_opts):
    return _make_krum(byz_f, multi=False)


@register("multi_krum")
def _multi_krum(*, byz_f: int, **_opts):
    return _make_krum(byz_f, multi=True)
