"""FL services: the paper abstracts a service to the tuple
<s^DT, {w^LC_k}, s^UT, w^GC> (§III.A).  ``arch_service_tuple`` derives that
tuple from any architecture config in the zoo -- download/upload payloads from
the parameter footprint (optionally compressed), local work from the
training-step FLOPs, aggregation work from the averaging cost -- making every
assigned architecture a first-class FL service (DESIGN.md §3a).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import RawServiceParams
from repro.models.config import ModelConfig

MBIT = 1e6


@dataclasses.dataclass
class FLService:
    """One live FL service in the network simulator.

    The bookkeeping fields (``rounds_done``, ``periods_active``,
    ``arrived_period``) are driven by the co-simulation: ``episode_services``
    materializes one record per fixed-capacity slot from an episode's
    outputs, so ``finished`` reflects the simulated termination criterion --
    a finished service's slot is an all-masked row from the next period on
    and its bandwidth share is re-cleared across the survivors (asserted in
    tests/test_cotrain.py).
    """

    service_id: int
    n_clients: int
    rounds_required: int          # termination criterion (rounds to converge)
    rounds_done: int = 0
    periods_active: int = 0
    arrived_period: int = 0

    @property
    def finished(self) -> bool:
        return self.rounds_done >= self.rounds_required


def episode_services(arrivals, counts, rounds_done, durations,
                     rounds_required: int) -> list[FLService]:
    """Materialize an episode's per-slot bookkeeping as ``FLService`` records.

    ``arrivals``/``counts`` are the episode-static draws ((N,) arrival period
    and enrolled-client count per slot); ``rounds_done``/``durations`` are
    the simulator's final counters.  Used by ``fl.cotrain`` (and valid on any
    duration-engine summary) so the dataclass fields track the simulation
    instead of staying at their defaults.
    """
    return [
        FLService(
            service_id=i,
            n_clients=int(counts[i]),
            rounds_required=int(rounds_required),
            rounds_done=int(rounds_done[i]),
            periods_active=int(durations[i]),
            arrived_period=int(arrivals[i]),
        )
        for i in range(len(arrivals))
    ]


def model_bits(cfg: ModelConfig, weight_bits: int = 32, active_only: bool = False) -> float:
    n = cfg.active_param_count() if active_only else cfg.param_count()
    return float(n) * weight_bits


def train_flops_per_token(cfg: ModelConfig) -> float:
    """6*N_active*token approximation (fwd+bwd) -- the MODEL_FLOPS convention."""
    return 6.0 * float(cfg.active_param_count())


def arch_service_tuple(
    cfg: ModelConfig,
    *,
    r_dl: jax.Array,
    r_ul: jax.Array,
    client_flops: jax.Array,
    server_flops: float = 1e12,
    tokens_per_round: int = 8192,
    local_epochs: int = 1,
    weight_bits: int = 32,
    uplink_compression: float = 1.0,   # s^UT multiplier from repro.fl.compression
) -> RawServiceParams:
    """Instantiate the paper's service tuple for an architecture.

    r_dl/r_ul: per-client base rates (bit/s/Hz); client_flops: per-client
    compute speeds phi_k (FLOP/s).  Payloads are in Mbit to match the
    allocator's canonical units.

    ``uplink_compression`` is the *static* s^UT multiplier baked into the
    tuple (``compression_ratio`` of the service's transmit level); ratios
    above 1.0 are rejected -- ``compression_ratio`` clamps them, so a bigger
    value here means the caller bypassed the pricing.  ServiceSets built
    from this tuple (``types.stack_services``) also carry the dynamic-s^UT
    column, so per-*period* recompression (``types.scale_uplink``, driven by
    the co-simulation's compression controller) composes on top of this
    static baseline.
    """
    if not 0.0 < uplink_compression <= 1.0:
        raise ValueError(
            f"uplink_compression must be in (0, 1] (compressing can never "
            f"grow s^UT past dense -- use fl.compression.compression_ratio, "
            f"which clamps), got {uplink_compression}")
    bits = model_bits(cfg, weight_bits)
    s_dl = bits / MBIT
    s_ul = bits * uplink_compression / MBIT
    w_lc = train_flops_per_token(cfg) * tokens_per_round * local_epochs
    t_local = w_lc / jnp.asarray(client_flops)
    k = r_dl.shape[0]
    w_gc = float(cfg.param_count()) * k  # averaging adds
    return RawServiceParams(
        s_dl_mbit=float(s_dl),
        s_ul_mbit=float(s_ul),
        r_dl=r_dl,
        r_ul=r_ul,
        t_local=t_local,
        t_global=w_gc / server_flops,
    )
