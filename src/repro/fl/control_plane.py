"""Live allocation control plane: online service admission over the
warm-started market-clearing step.

The offline engines (``fl.simulator``) evaluate a *recorded* episode; this
module is the serving side of the same period step: FL services arrive and
depart while the network provider keeps clearing the market (paper §III/§V),
so the allocator runs as a long-lived daemon that

* **admits / retires services online** into free slots of one fixed-capacity
  mask-padded ServiceSet -- an admission is a mask flip plus two array
  writes, never a shape change, so the compiled step traces once for the
  daemon's whole lifetime;
* **holds warm policy state** (``StatefulPolicy`` carry, e.g. coop's dual
  price) across requests, so steady-state decisions reuse the <= 6-trip
  safeguarded-Newton clear instead of the 48-trip cold bisection;
* **drives per-client churn from heartbeats**: a client whose last heartbeat
  is older than ``heartbeat_timeout_periods`` is dropped from the next
  period's clear (CFLMEC-style liveness, mapped onto the
  ``scenarios.churn`` mask conventions via ``types.mask_clients``);
* **checkpoints and auto-resumes**: the full serving state is a fixed-shape
  pytree written through ``CheckpointManager``'s COMMIT protocol;
  ``run_resumable`` drives scripted serving through
  ``distributed.fault.resumable_loop`` so a crashed daemon replays nothing
  and loses at most ``save_every - 1`` periods.

Differential contract (tests/test_control_plane.py): a daemon that never
serves a stale decision produces an allocation stream **bitwise equal** to
``simulator.run_scan(collect_alloc=True)`` fed the same admission trace
(explicit ``arrivals``/``counts``) on the same seed.  Three facts make that
hold: the per-period math IS ``simulator._period_step`` (one shared
implementation), the all-healthy heartbeat mask is a bitwise no-op
(re-masking an already-masked set is the identity), and inactive slots are
invisible to every mask-aware solver -- so the placeholder client counts of
not-yet-admitted slots cannot perturb a single bit of the active rows.

The asyncio front end (request queue, solver-timeout degradation, the
``stale_decisions`` metric) lives in ``repro.launch.allocd``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.checkpoint import CheckpointManager
from repro.core import network, policy as policy_mod
from repro.distributed import fault
from repro.fl import simulator

# Arrival sentinel for a slot no service has been admitted into: period
# numbers stay far below int32 max, so ``arrivals <= period`` is False
# forever.  The replay feeds run_scan the very same sentinel.
NEVER = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass(frozen=True)
class ControlPlaneConfig:
    """Static configuration of one allocation daemon.

    Mirrors the ``SimConfig`` fields that select the compiled period step --
    ``replay_sim_config`` maps one onto the other for the differential
    check.  ``capacity`` is the fixed slot count (admissions beyond it are
    rejected, never silently queued into a retrace); ``k_max`` the per-slot
    client pad."""

    capacity: int = 16
    k_max: int = 32
    policy: str = "coop"
    warm_start: bool = True
    rounds_required: int = 2000
    seed: int = 0
    n_bids: int = 5
    alpha_fair: float = 0.5
    intra_backend: str = "reference"
    channel_process: str | scenarios.ScenarioSpec = "iid"
    churn_process: str | scenarios.ScenarioSpec = "none"
    # A client whose last heartbeat is more than this many periods old is
    # dropped from the next clear.  None disables liveness tracking (every
    # enrolled client stays up) -- the replayable configuration.
    heartbeat_timeout_periods: int | None = None


class Decision(NamedTuple):
    """One served per-period allocation over the fixed-capacity slots."""

    period: int
    b: np.ndarray          # (capacity,) MHz
    f: np.ndarray          # (capacity,) rounds/s
    active: np.ndarray     # (capacity,) bool
    stale: bool            # True: previous clear rescaled, not a fresh solve
    # True: the O(1) equal-share emergency policy (stale-streak overflow or
    # a non-finite solver output), flagged distinctly from plain staleness.
    degraded: bool = False


@functools.lru_cache(maxsize=None)
def _serve_step_jit(policy, n_bids, alpha_fair, intra_backend, warm_start,
                    net, n_total, k_max, rounds_required, channel, churn):
    """The daemon's compiled period step: ``simulator._period_step`` bound to
    the same statics the offline engines use, keeping the allocation record
    (b/f/active/rounds) and dropping only the per-period ServiceSet.  Cached
    per configuration so restarts and tests reuse one compilation."""
    pol = policy_mod.get_stateful_policy(
        policy, warm_start=warm_start, n_bids=n_bids, alpha_fair=alpha_fair,
        intra_backend=intra_backend,
    )
    chan_proc = scenarios.get_channel(channel, net)
    churn_proc = scenarios.get_churn(churn, net)
    bound = functools.partial(
        simulator._period_step, policy_fn=pol.step, chan_step=chan_proc.step,
        churn_step=churn_proc.step, chan_rebuilds=chan_proc.rebuilds, net=net,
        n_total=n_total, k_max=k_max, rounds_required=rounds_required,
    )

    def step(rounds_done, duration, chan_state, churn_state, pol_state,
             period, arrivals, counts, key, hb_avail):
        (rounds_done, duration, chan_state, churn_state, pol_state, stats,
         extras) = bound(rounds_done, duration, chan_state, churn_state,
                         pol_state, period, arrivals, counts, key, hb_avail)
        return (rounds_done, duration, chan_state, churn_state, pol_state,
                stats, extras["b"], extras["f"], extras["active"])

    return jax.jit(step), chan_proc, churn_proc, pol


@dataclasses.dataclass
class _SlotRecord:
    service_id: Any
    slot: int
    n_clients: int
    admitted_period: int
    retired_period: int | None = None


class ControlPlane:
    """Synchronous serving core: slot registry + compiled step + state.

    All state transitions happen in ``tick`` (one period each); the asyncio
    daemon in ``launch.allocd`` layers batched request draining, heartbeat
    wall-clock mapping, and solver-timeout degradation on top.
    """

    def __init__(self, cfg: ControlPlaneConfig,
                 net: network.NetworkConfig | None = None):
        if cfg.capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.cfg = cfg
        self.net = net or network.NetworkConfig()
        self._step, chan_proc, churn_proc, pol = _serve_step_jit(
            cfg.policy, cfg.n_bids, cfg.alpha_fair, cfg.intra_backend,
            cfg.warm_start, self.net, cfg.capacity, cfg.k_max,
            cfg.rounds_required,
            scenarios.as_spec(cfg.channel_process, "iid"),
            scenarios.as_spec(cfg.churn_process, "none"),
        )
        # The episode key run_scan would use on the same seed -- re-derived,
        # never checkpointed (typed keys don't round-trip through npz).
        self._key = jax.random.key(cfg.seed + 7)
        n, k = cfg.capacity, cfg.k_max
        self._arrivals = np.full((n,), NEVER, np.int32)
        self._counts = np.zeros((n,), np.int32)
        self._last_seen = np.zeros((n, k), np.int32)
        self._period = 0
        self._carry = (jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
                       chan_proc.init(self._key, n, k),
                       churn_proc.init(self._key, n, k),
                       pol.init_state(n))
        self._rounds_done = np.zeros((n,), np.int32)
        self._last_alloc: tuple[np.ndarray, np.ndarray] | None = None
        self.services: dict[Any, _SlotRecord] = {}
        self.retired: list[_SlotRecord] = []
        self._free = list(range(n))
        self.replayable = True      # falsified by slot reuse / forced retire
        self.unreplayable_reasons: list[str] = []
        # period -> [[slot, client], ...] heartbeat-timeout drops, recorded
        # so a masked episode still replays bitwise (run_scan's ``avail``).
        self._hb_drops: dict[int, list[list[int]]] = {}
        self.metrics = {
            "decisions": 0, "stale_decisions": 0, "admitted": 0,
            "retired": 0, "rejected": 0, "heartbeat_drops": 0,
            # Robustness counters (PR 8): none of these ever move on a
            # healthy run -- each marks a counted, never-silent degradation.
            "solver_fallbacks": 0, "nonfinite_decisions": 0,
            "degraded_decisions": 0, "carry_repairs": 0,
            "checkpoint_skips": 0, "admit_retries": 0,
        }
        self.decisions: list[Decision] = []

    def _mark_unreplayable(self, reason: str) -> None:
        self.replayable = False
        if reason not in self.unreplayable_reasons:
            self.unreplayable_reasons.append(reason)

    # -- admission / retirement -------------------------------------------

    @property
    def period(self) -> int:
        """Periods cleared so far (the next tick's period index)."""
        return self._period

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def admit(self, service_id, n_clients: int) -> int:
        """Admit a service into the lowest free slot, active from the period
        of the *next* ``tick``.  Raises when full or on a duplicate id --
        the daemon maps that onto an explicit rejection, never a silent
        drop."""
        if service_id in self.services:
            raise ValueError(f"service {service_id!r} already admitted")
        if not 1 <= n_clients <= self.cfg.k_max:
            raise ValueError(
                f"n_clients must be in [1, {self.cfg.k_max}], got {n_clients}")
        if not self._free:
            self.metrics["rejected"] += 1
            raise RuntimeError(
                f"all {self.cfg.capacity} slots occupied; retire a service "
                f"or grow capacity")
        # Prefer a never-used slot: reusing a freed one makes the episode
        # inexpressible as a single run_scan (arrival, count) trace.
        virgin = [s for s in self._free if self._arrivals[s] == NEVER]
        slot = min(virgin) if virgin else min(self._free)
        self._free.remove(slot)
        if self._arrivals[slot] != NEVER:
            self._mark_unreplayable("slot reuse")
        self._arrivals[slot] = self._period
        self._counts[slot] = n_clients
        self._last_seen[slot, :] = self._period
        self.services[service_id] = _SlotRecord(
            service_id, slot, n_clients, self._period)
        self.metrics["admitted"] += 1
        return slot

    def retire(self, service_id) -> None:
        """Forced (client-requested) retirement: the slot goes inactive from
        the next period and returns to the free list.  Completion-based
        departures need no request -- ``tick`` detects them."""
        rec = self.services.pop(service_id, None)
        if rec is None:
            raise KeyError(f"unknown service {service_id!r}")
        self._arrivals[rec.slot] = NEVER
        self._free.append(rec.slot)
        rec.retired_period = self._period
        self.retired.append(rec)
        self.metrics["retired"] += 1
        self._mark_unreplayable("forced retire")
        self._counts[rec.slot] = 0

    # -- heartbeats --------------------------------------------------------

    def heartbeat(self, service_id, client: int | None = None) -> None:
        """Record liveness for one client (or the whole cohort) of a
        service, stamped at the current period."""
        rec = self.services.get(service_id)
        if rec is None:
            raise KeyError(f"unknown service {service_id!r}")
        if client is None:
            self._last_seen[rec.slot, :] = self._period
        else:
            if not 0 <= client < rec.n_clients:
                raise ValueError(
                    f"client {client} out of range for service "
                    f"{service_id!r} ({rec.n_clients} clients)")
            self._last_seen[rec.slot, client] = self._period
        return None

    def _heartbeat_mask(self) -> np.ndarray:
        """(capacity, k_max) availability from heartbeat ages.  All-True when
        liveness tracking is off -- a bitwise no-op inside the step.

        Only drops of *live, enrolled* clients can perturb the clear:
        inactive rows are zeroed whole by the activity rule and columns
        ``k >= counts`` by the base client mask, so everything else is forced
        True.  That keeps the mask's non-identity entries sparse, and they
        are recorded per period in ``_hb_drops`` -- ``replay_reference``
        feeds them back through ``run_scan(avail=...)``, so a
        heartbeat-masked episode still replays bitwise."""
        timeout = self.cfg.heartbeat_timeout_periods
        if timeout is None:
            return np.ones((self.cfg.capacity, self.cfg.k_max), bool)
        avail = (self._period - self._last_seen) <= timeout
        live = np.zeros((self.cfg.capacity, 1), bool)
        for rec in list(self.services.values()):
            live[rec.slot, 0] = True
        enrolled = np.arange(self.cfg.k_max)[None, :] < self._counts[:, None]
        eff = np.where(live & enrolled, avail, True)
        dropped = int(np.sum(~eff))
        self.metrics["heartbeat_drops"] += dropped
        if dropped:
            slots, clients = np.nonzero(~eff)
            self._hb_drops[self._period] = [
                [int(s), int(c)] for s, c in zip(slots, clients)]
        return eff

    # -- the period step ---------------------------------------------------

    def tick(self) -> Decision:
        """Run one period: heartbeat-derived churn, the compiled clear,
        completion-based retirement, trace bookkeeping.

        Hardened (chaos-tested): a non-finite solver output is never served
        -- the period degrades to the O(1) equal-share decision, counted in
        ``nonfinite_decisions``; any non-finite values left in the carry are
        healed afterwards (``carry_repairs``) so one poisoned period cannot
        cascade; warm-solver cold-bisection rescues are mirrored from the
        policy carry into ``solver_fallbacks``.  Each of those also falsifies
        ``replayable`` -- an injected fault is not part of the recorded
        trace, so the offline replay could no longer match.
        """
        period = self._period
        hb = self._heartbeat_mask()
        out = self._step(
            *self._carry, jnp.int32(period),
            jnp.asarray(self._arrivals), jnp.asarray(self._counts),
            self._key, jnp.asarray(hb),
        )
        self._carry = out[:5]
        b, f, active = (np.asarray(out[6]), np.asarray(out[7]),
                        np.asarray(out[8]))
        self._rounds_done = np.asarray(out[0])
        self._period = period + 1
        self._retire_finished()
        fallbacks = policy_mod.fallback_count(self._carry[4])
        if fallbacks > self.metrics["solver_fallbacks"]:
            self.metrics["solver_fallbacks"] = fallbacks
            self._mark_unreplayable("solver fallback (non-finite inputs)")
        if self._repair_carry():
            self._mark_unreplayable("carry repaired after non-finite values")
        if not (np.all(np.isfinite(b)) and np.all(np.isfinite(f))):
            self.metrics["nonfinite_decisions"] += 1
            self.metrics["degraded_decisions"] += 1
            self._mark_unreplayable("non-finite solver output")
            decision = self._equal_share(period, stale=False)
        else:
            decision = Decision(period=period, b=b, f=f, active=active,
                                stale=False)
        self.metrics["decisions"] += 1
        self.decisions.append(decision)
        return decision

    def _repair_carry(self) -> int:
        """Replace non-finite float entries anywhere in the serving carry
        with 0 (for the warm dual price, 0 means "cold seed next period").
        Returns the number of entries healed, mirrored into
        ``metrics['carry_repairs']`` -- 0 on every healthy tick."""
        leaves, treedef = jax.tree.flatten(self._carry)
        healed = 0
        out = []
        for leaf in leaves:
            arr = np.asarray(leaf)
            if np.issubdtype(arr.dtype, np.floating):
                bad = ~np.isfinite(arr)
                n_bad = int(bad.sum())
                if n_bad:
                    healed += n_bad
                    arr = np.where(bad, np.zeros_like(arr), arr)
                    leaf = jnp.asarray(arr)
            out.append(leaf)
        if healed:
            self._carry = jax.tree.unflatten(treedef, out)
            self.metrics["carry_repairs"] += healed
        return healed

    def _retire_finished(self) -> None:
        """Completion-based departure (the simulator's own rule): a service
        whose rounds_done reached rounds_required frees its slot.  The
        arrival/count arrays are left untouched -- the step's activity rule
        already excludes the row, and the replay needs the history."""
        done = self._rounds_done >= self.cfg.rounds_required
        for sid in [s for s, r in self.services.items() if done[r.slot]]:
            rec = self.services.pop(sid)
            rec.retired_period = self._period
            self.retired.append(rec)
            self._free.append(rec.slot)
            self.metrics["retired"] += 1

    def _occupied(self) -> np.ndarray:
        """(capacity,) live-slot mask from the registry.  Snapshots the
        registry values first: the daemon may call this from the event loop
        while a tick commits in an executor thread."""
        occupied = np.zeros((self.cfg.capacity,), bool)
        for rec in list(self.services.values()):
            occupied[rec.slot] = True
        return occupied

    def stale_decision(self) -> Decision:
        """Degraded decision for the current period: the previous clear
        rescaled to the live admission mask (budget-preserving), used by the
        daemon when the solver misses its deadline.  Counted in
        ``metrics['stale_decisions']`` -- never served silently -- and NOT
        appended to ``decisions``: that list is the fresh-solve stream the
        differential replay checks; the daemon records what it served."""
        period = self._period
        occupied = self._occupied()
        if self.decisions:
            prev = self.decisions[-1]
            b = np.where(occupied, prev.b, 0.0)
            total = float(b.sum())
            if total > 0.0:
                b = b * (self.net.total_bandwidth_mhz / total)
            f = np.where(occupied, prev.f, 0.0)
            self.metrics["stale_decisions"] += 1
            return Decision(period=period, b=b.astype(np.float32),
                            f=f.astype(np.float32), active=occupied,
                            stale=True)
        # Nothing cleared yet: equal split over live slots.
        self.metrics["stale_decisions"] += 1
        return self._equal_share(period, stale=True, count=False)

    def _equal_share(self, period: int, *, stale: bool,
                     count: bool = False) -> Decision:
        """The O(1) emergency allocation: B split equally over live slots,
        f = 0 (no solve ran, so no frequency claim is honest)."""
        occupied = self._occupied()
        n_live = max(int(occupied.sum()), 1)
        b = np.where(occupied, self.net.total_bandwidth_mhz / n_live, 0.0)
        f = np.zeros((self.cfg.capacity,), np.float32)
        if count:
            self.metrics["degraded_decisions"] += 1
        return Decision(period=period, b=b.astype(np.float32), f=f,
                        active=occupied, stale=stale, degraded=True)

    def degraded_decision(self) -> Decision:
        """Emergency decision for the current period: equal share over the
        live mask, used by the daemon once a stale streak exceeds its bound
        (the previous clear is too old to keep rescaling).  Counted in
        ``metrics['degraded_decisions']`` and flagged ``degraded`` --
        distinct from plain staleness -- and, like ``stale_decision``, NOT
        appended to the fresh-solve stream."""
        return self._equal_share(self._period, stale=True, count=True)

    def allocation_of(self, service_id) -> dict:
        """Latest served (b, f) for one admitted service."""
        rec = self.services.get(service_id)
        if rec is None:
            raise KeyError(f"unknown service {service_id!r}")
        if not self.decisions:
            raise RuntimeError("no decision served yet")
        last = self.decisions[-1]
        return {"period": last.period, "b_mhz": float(last.b[rec.slot]),
                "f_rounds_per_s": float(last.f[rec.slot]),
                "stale": last.stale}

    # -- differential replay ----------------------------------------------

    def trace(self) -> tuple[np.ndarray, np.ndarray]:
        """The admission trace as run_scan inputs: per-slot (arrivals,
        counts), with ``NEVER`` marking slots no service ever occupied."""
        return self._arrivals.copy(), self._counts.copy()

    def replay_sim_config(self) -> simulator.SimConfig:
        """The SimConfig whose ``run_scan(arrivals=..., counts=...,
        collect_alloc=True)`` replays this daemon's stream bitwise (healthy
        heartbeats, no forced retires -- ``replayable`` guards that)."""
        return simulator.SimConfig(
            policy=self.cfg.policy, n_services_total=self.cfg.capacity,
            rounds_required=self.cfg.rounds_required, seed=self.cfg.seed,
            k_max=self.cfg.k_max, max_periods=max(self._period, 1),
            n_bids=self.cfg.n_bids, alpha_fair=self.cfg.alpha_fair,
            intra_backend=self.cfg.intra_backend,
            warm_start=self.cfg.warm_start,
            channel_process=self.cfg.channel_process,
            churn_process=self.cfg.churn_process,
            collect_history=True, collect_alloc=True,
        )

    def recorded_avail(self) -> np.ndarray | None:
        """The recorded heartbeat-drop stream as run_scan's ``avail`` tensor
        ((period, capacity, k_max) bool), or None when no drop was ever
        recorded (an all-True plane would be a bitwise no-op anyway)."""
        if not self._hb_drops:
            return None
        avail = np.ones((max(self._period, 1), self.cfg.capacity,
                         self.cfg.k_max), bool)
        for p, drops in self._hb_drops.items():
            if p < avail.shape[0]:
                for slot, client in drops:
                    avail[p, slot, client] = False
        return avail

    def replay_reference(self) -> dict:
        """Run the offline reference on this daemon's recorded trace
        (admissions + heartbeat-drop masks)."""
        if not self.replayable:
            raise RuntimeError(
                "trace is not replayable as one run_scan episode (slot "
                "reuse, forced retire, or an injected fault: "
                f"{self.unreplayable_reasons or 'unknown'})")
        arrivals, counts = self.trace()
        return simulator.run_scan(self.replay_sim_config(), self.net,
                                  arrivals=arrivals, counts=counts,
                                  avail=self.recorded_avail())

    # -- checkpointable state ---------------------------------------------

    def state_pytree(self) -> dict:
        """The full serving state as one fixed-shape pytree (COMMIT-protocol
        checkpointable; shapes depend only on the config)."""
        return {
            "period": jnp.int32(self._period),
            "arrivals": jnp.asarray(self._arrivals),
            "counts": jnp.asarray(self._counts),
            "last_seen": jnp.asarray(self._last_seen),
            "carry": self._carry,
        }

    def registry_meta(self) -> dict:
        """JSON side-channel for ``CheckpointManager.save(extra=...)``: the
        service-id -> slot map the pytree cannot carry."""
        return {
            "services": {
                str(s): dataclasses.asdict(r)
                for s, r in self.services.items()
            },
            "metrics": dict(self.metrics),
            "replayable": self.replayable,
            "unreplayable_reasons": list(self.unreplayable_reasons),
            "hb_drops": {str(p): d for p, d in self._hb_drops.items()},
        }

    def snapshot(self, manager: CheckpointManager) -> None:
        """COMMIT-protocol checkpoint of serving state + registry meta."""
        manager.save(self._period, self.state_pytree(),
                     extra=self.registry_meta())

    def restore(self, manager: CheckpointManager) -> bool:
        """Adopt the newest VERIFIABLE checkpoint; False when none survives.
        Corrupted-but-committed steps the manager had to skip are surfaced
        in ``metrics['checkpoint_skips']`` -- a skipped checkpoint costs
        recovery time and is never silent."""
        step, tree, extra = manager.restore_latest(self.state_pytree())
        skipped = len(getattr(manager, "last_skipped", ()))
        if step is None:
            self.metrics["checkpoint_skips"] += skipped
            return False
        self.load_state(tree, extra)
        self.metrics["checkpoint_skips"] += skipped
        return True

    def load_state(self, state: dict, meta: dict | None = None) -> None:
        """Adopt a checkpointed pytree (and optionally the registry meta).

        Without ``meta`` the registry is rebuilt from the arrays alone --
        slot indices become the service ids -- which is exactly what the
        scripted ``run_resumable`` path needs after a crash."""
        self._period = int(state["period"])
        self._arrivals = np.asarray(state["arrivals"], np.int32).copy()
        self._counts = np.asarray(state["counts"], np.int32).copy()
        self._last_seen = np.asarray(state["last_seen"], np.int32).copy()
        self._carry = tuple(state["carry"])
        self._rounds_done = np.asarray(self._carry[0], np.int32)
        self.services.clear()
        self._free = []
        self._hb_drops = {}
        if meta and "services" in meta:
            for rec in meta["services"].values():
                rec = _SlotRecord(**rec)
                self.services[rec.service_id] = rec
            if "metrics" in meta:
                self.metrics.update(meta["metrics"])
            self.replayable = bool(meta.get("replayable", True))
            self.unreplayable_reasons = list(
                meta.get("unreplayable_reasons", []))
            self._hb_drops = {int(p): [[int(s), int(c)] for s, c in drops]
                              for p, drops in meta.get("hb_drops",
                                                       {}).items()}
            occupied = {r.slot for r in self.services.values()}
        else:
            occupied = set()
            live = np.logical_and(self._arrivals != NEVER,
                                  self._rounds_done < self.cfg.rounds_required)
            for slot in np.flatnonzero(live):
                slot = int(slot)
                self.services[slot] = _SlotRecord(
                    service_id=slot, slot=slot,
                    n_clients=int(self._counts[slot]),
                    admitted_period=int(self._arrivals[slot]))
                occupied.add(slot)
            if (self.cfg.heartbeat_timeout_periods is not None
                    and self._period > 0):
                # The array-only restore path has no heartbeat-drop record,
                # so a liveness-tracked episode cannot be replayed soundly.
                self._mark_unreplayable(
                    "restored without a heartbeat-drop record")
        self._free = [s for s in range(self.cfg.capacity)
                      if s not in occupied]


# ---------------------------------------------------------------------------
# Scripted serving through the fault-tolerance layer.
# ---------------------------------------------------------------------------

def run_resumable(
    cfg: ControlPlaneConfig,
    schedule: dict[int, tuple[int, ...]],
    n_periods: int,
    manager: CheckpointManager,
    policy: fault.RestartPolicy | None = None,
    fail_at: int | None = None,
    net: network.NetworkConfig | None = None,
) -> tuple[dict, ControlPlane]:
    """Drive a scripted admission schedule through
    ``fault.resumable_loop``: one resumable step per period, the serving
    state checkpointed via the COMMIT protocol every ``policy.save_every``
    periods.  ``schedule`` maps period -> client counts of the services to
    admit that period (ids are assigned ``p{period}s{i}``).  Deterministic:
    a crashed-and-restarted run reaches a bit-identical final state and
    loses at most ``save_every - 1`` periods of work
    (tests/test_control_plane.py / tests/test_fault.py).

    Returns ``(final state pytree, the replayed ControlPlane)`` -- the
    returned plane has ``load_state``-reconstructed bookkeeping, so its
    ``trace()`` still feeds the differential replay.
    """
    plane = ControlPlane(cfg, net)

    def step(state, t):
        plane.load_state(state)
        for i, n_clients in enumerate(schedule.get(t, ())):
            if plane.free_slots:
                plane.admit(f"p{t}s{i}", n_clients)
        plane.tick()
        return plane.state_pytree()

    final = fault.resumable_loop(step, plane.state_pytree(), n_periods,
                                 manager, policy, fail_at=fail_at)
    plane.load_state(final)
    return final, plane
