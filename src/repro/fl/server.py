"""Parameter-server side: synchronous FedAvg rounds with straggler
mitigation, as one jit-able function.

``make_fl_round_step`` builds the full round:
    per-client local training (vmap over the client axis -- the axis that
    shards over the mesh's ``data`` dimension at scale) ->
    optional uplink compression ->
    deadline-based straggler drop (clients whose simulated DT+LC+UT latency
    exceeds the deadline are masked out of the aggregate; the paper's
    synchronous model gates on the slowest *admitted* client) ->
    weighted FedAvg aggregation -> server optimizer step.

At mesh scale the client vmap axis is sharded over ``data`` and the
aggregation's masked mean lowers to the psum the FL literature calls "the
server" -- see DESIGN.md §3.5.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.fl import client as fl_client
from repro.fl import compression as fl_comp


def sanitize_weights(weights, weight_cap: float | None = None):
    """Defend the masked mean against weight manipulation.  Returns
    ``(clean_weights, n_nonfinite)``: non-finite client weights are zeroed
    (a NaN weight would poison the denominator for *everyone*) and counted
    -- never absorbed silently -- and, when ``weight_cap`` is set, each
    weight is clipped to it so no single client can dominate the average by
    inflating its report.  Finite, in-cap weights pass through bitwise."""
    finite = jnp.isfinite(weights)
    n_bad = jnp.sum((~finite).astype(jnp.int32))
    clean = jnp.where(finite, weights, jnp.zeros_like(weights))
    if weight_cap is not None:
        clean = jnp.minimum(clean, jnp.asarray(weight_cap, clean.dtype))
    return clean, n_bad


def fedavg_round(deltas, weights, weight_cap: float | None = None):
    """Weighted average of per-client deltas.  deltas: pytree with leading
    client axis (C, ...); weights: (C,) (zero = dropped straggler).

    Dropped clients are masked out of the numerator (``where`` on w > 0, not
    a bare multiply), so a straggler's delta never contributes -- even a
    non-finite one from a diverged run.  The all-straggler round returns an
    exactly-zero delta (params unchanged) instead of leaning on the 1e-12
    denominator clamp; when any weight is positive the arithmetic is
    unchanged from the plain weighted mean.  Weights themselves pass through
    ``sanitize_weights`` (non-finite -> dropped, optional ``weight_cap``
    clip), so a manipulated weight vector degrades to a masked mean instead
    of a poisoned one.
    """
    weights, _ = sanitize_weights(weights, weight_cap)
    wsum = jnp.sum(weights)
    denom = jnp.maximum(wsum, 1e-12)

    def agg(d):
        w = weights.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
        num = jnp.sum(jnp.where(w > 0, d * w, jnp.zeros_like(d)), axis=0)
        return jnp.where(wsum > 0, num / denom.astype(d.dtype),
                         jnp.zeros_like(num))

    return jax.tree.map(agg, deltas)


def make_fl_round_step(
    loss_fn: Callable,
    *,
    local_steps: int = 1,
    client_lr: float = 0.1,
    server_lr: float = 1.0,
    prox_mu: float = 0.0,
    compression: str = "none",
    topk_frac: float = 0.01,
    error_feedback: bool = False,
    aggregator: str = "fedavg",
    trim_frac: float = 0.1,
    clip_norm: float | None = None,
    byz_f: int = 1,
    weight_cap: float | None = None,
    attack=None,
):
    """Returns round(params, client_batches, client_weights) ->
    (params, metrics).  client_batches leaves: (C, E, ...) -- C clients, E
    local steps each.

    ``aggregator`` selects the reduction from ``fl.aggregation``'s registry
    (``"fedavg"`` keeps the exact seed path; the robust entries take
    ``trim_frac`` / ``clip_norm`` / ``byz_f``).  ``weight_cap`` bounds
    client-reported weights (``sanitize_weights``; applies to the loss
    average and the fedavg denominator alike).  ``attack`` is an optional
    ``chaos.clients.AttackSpec``: when set, the returned step takes an extra
    trailing argument ``byz`` -- a (C,) bool mask of Byzantine clients --
    and applies the attack to their deltas/weights *before* aggregation,
    modelling adversarial participants the server never observes directly.

    ``error_feedback=True`` turns on client-held compression residuals: the
    step's signature becomes ``round(params, client_batches, client_weights,
    residuals[, byz]) -> (params, metrics, residuals')`` where ``residuals``
    is a params-shaped pytree with a leading (C,) client axis.  Each client
    adds its carried residual to the fresh delta before compressing and
    keeps the part the compressor cut (Karimireddy-style EF), so the
    telescoping identity  sum(transmitted) + residual_T = sum(raw deltas)
    holds over any window of full-participation rounds.  A straggler
    (weight 0) transmits nothing, so its residual is left untouched rather
    than advanced -- the withheld mass is neither dropped nor
    double-counted.  The default ``False`` keeps the historical signature
    and the bitwise-pinned seed path.
    """
    from repro.fl import aggregation as fl_agg

    if compression not in fl_comp.METHODS:
        raise ValueError(
            f"unknown compression method {compression!r}; "
            f"available: {fl_comp.METHODS}")

    if aggregator == "fedavg":
        # The pinned default path: identical call to the seed fedavg_round.
        def agg_fn(deltas, weights):
            return fedavg_round(deltas, weights, weight_cap)
    else:
        agg_fn = fl_agg.get_aggregator(
            aggregator, trim_frac=trim_frac, clip_norm=clip_norm, byz_f=byz_f)

    if attack is not None:
        from repro.chaos import clients as chaos_clients
        attack_fn = chaos_clients.attack_fn(attack)

    def one_client(params, batches):
        delta, loss = fl_client.local_update(
            loss_fn, params, batches, lr=client_lr, prox_mu=prox_mu
        )
        if compression != "none":
            delta, _ = fl_comp.compress(compression, delta, topk_frac)
        return delta, loss

    def one_client_ef(params, batches, residual):
        delta, loss = fl_client.local_update(
            loss_fn, params, batches, lr=client_lr, prox_mu=prox_mu
        )
        delta, residual = fl_comp.compress(
            compression, delta, topk_frac, residual)
        return delta, loss, residual

    def _finish(params, deltas, losses, client_weights, byz):
        if attack is not None:
            deltas, client_weights = attack_fn(deltas, client_weights, byz)
        if weight_cap is not None or attack is not None:
            client_weights, n_bad_w = sanitize_weights(
                client_weights, weight_cap)
        else:
            n_bad_w = jnp.int32(0)
        agg = agg_fn(deltas, client_weights)
        new_params = jax.tree.map(
            lambda p, d: (p + server_lr * d.astype(p.dtype)), params, agg
        )
        wsum = jnp.sum(client_weights)
        num = jnp.sum(jnp.where(client_weights > 0,
                                losses * client_weights, 0.0))
        # all-straggler round: no participants -> report loss 0, not 0/clamp
        mean_loss = jnp.where(wsum > 0, num / jnp.maximum(wsum, 1e-12), 0.0)
        return new_params, {"loss": mean_loss,
                            "participating": jnp.sum(client_weights > 0),
                            "nonfinite_weights": n_bad_w}

    def round_step(params, client_batches, client_weights, byz=None):
        deltas, losses = jax.vmap(one_client, in_axes=(None, 0))(params, client_batches)
        return _finish(params, deltas, losses, client_weights, byz)

    def round_step_ef(params, client_batches, client_weights, residuals,
                      byz=None):
        deltas, losses, new_resid = jax.vmap(
            one_client_ef, in_axes=(None, 0, 0))(
                params, client_batches, residuals)
        # Stragglers transmit nothing this round: their residual must not
        # advance (the mass they withheld stays carried, once).  Gate on the
        # *reported* weights -- an attack may later rescale a participant's
        # weight, but participation itself is the deadline's verdict.
        part = client_weights > 0
        resid_out = jax.tree.map(
            lambda new, old: jnp.where(
                part.reshape((-1,) + (1,) * (new.ndim - 1)), new,
                old.astype(new.dtype)),
            new_resid, residuals)
        new_params, metrics = _finish(
            params, deltas, losses, client_weights, byz)
        return new_params, metrics, resid_out

    return round_step_ef if error_feedback else round_step


def init_residuals(params, n_clients: int):
    """Zero error-feedback residual state for ``n_clients`` clients: a
    params-shaped pytree with a leading (C,) axis, as consumed/returned by
    ``make_fl_round_step(error_feedback=True)``'s round step."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_clients,) + jnp.shape(p), jnp.asarray(p).dtype),
        params)


def straggler_weights(round_latencies: jax.Array, deadline: float) -> jax.Array:
    """1.0 for clients meeting the deadline, 0.0 for stragglers.
    round_latencies: (C,) simulated DT+LC+UT+GC times from the timing model."""
    return (round_latencies <= deadline).astype(jnp.float32)
