"""Parameter-server side: synchronous FedAvg rounds with straggler
mitigation, as one jit-able function.

``make_fl_round_step`` builds the full round:
    per-client local training (vmap over the client axis -- the axis that
    shards over the mesh's ``data`` dimension at scale) ->
    optional uplink compression ->
    deadline-based straggler drop (clients whose simulated DT+LC+UT latency
    exceeds the deadline are masked out of the aggregate; the paper's
    synchronous model gates on the slowest *admitted* client) ->
    weighted FedAvg aggregation -> server optimizer step.

At mesh scale the client vmap axis is sharded over ``data`` and the
aggregation's masked mean lowers to the psum the FL literature calls "the
server" -- see DESIGN.md §3.5.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.fl import client as fl_client
from repro.fl import compression as fl_comp


def fedavg_round(deltas, weights):
    """Weighted average of per-client deltas.  deltas: pytree with leading
    client axis (C, ...); weights: (C,) (zero = dropped straggler).

    Dropped clients are masked out of the numerator (``where`` on w > 0, not
    a bare multiply), so a straggler's delta never contributes -- even a
    non-finite one from a diverged run.  The all-straggler round returns an
    exactly-zero delta (params unchanged) instead of leaning on the 1e-12
    denominator clamp; when any weight is positive the arithmetic is
    unchanged from the plain weighted mean.
    """
    wsum = jnp.sum(weights)
    denom = jnp.maximum(wsum, 1e-12)

    def agg(d):
        w = weights.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
        num = jnp.sum(jnp.where(w > 0, d * w, jnp.zeros_like(d)), axis=0)
        return jnp.where(wsum > 0, num / denom.astype(d.dtype),
                         jnp.zeros_like(num))

    return jax.tree.map(agg, deltas)


def make_fl_round_step(
    loss_fn: Callable,
    *,
    local_steps: int = 1,
    client_lr: float = 0.1,
    server_lr: float = 1.0,
    prox_mu: float = 0.0,
    compression: str = "none",
    topk_frac: float = 0.01,
):
    """Returns round(params, client_batches, client_weights) ->
    (params, metrics).  client_batches leaves: (C, E, ...) -- C clients, E
    local steps each."""

    def one_client(params, batches):
        delta, loss = fl_client.local_update(
            loss_fn, params, batches, lr=client_lr, prox_mu=prox_mu
        )
        if compression == "topk":
            delta, _ = fl_comp.topk_sparsify(delta, topk_frac)
        elif compression == "int8":
            delta, _ = fl_comp.int8_quantize(delta)
        elif compression == "topk_int8":
            delta, _ = fl_comp.topk_sparsify(delta, topk_frac)
            delta, _ = fl_comp.int8_quantize(delta)
        return delta, loss

    def round_step(params, client_batches, client_weights):
        deltas, losses = jax.vmap(one_client, in_axes=(None, 0))(params, client_batches)
        agg = fedavg_round(deltas, client_weights)
        new_params = jax.tree.map(
            lambda p, d: (p + server_lr * d.astype(p.dtype)), params, agg
        )
        wsum = jnp.sum(client_weights)
        num = jnp.sum(jnp.where(client_weights > 0,
                                losses * client_weights, 0.0))
        # all-straggler round: no participants -> report loss 0, not 0/clamp
        mean_loss = jnp.where(wsum > 0, num / jnp.maximum(wsum, 1e-12), 0.0)
        return new_params, {"loss": mean_loss,
                            "participating": jnp.sum(client_weights > 0)}

    return round_step


def straggler_weights(round_latencies: jax.Array, deadline: float) -> jax.Array:
    """1.0 for clients meeting the deadline, 0.0 for stragglers.
    round_latencies: (C,) simulated DT+LC+UT+GC times from the timing model."""
    return (round_latencies <= deadline).astype(jnp.float32)
