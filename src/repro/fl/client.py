"""Client-side local training (the LC stage of the paper's round model).

``local_update`` runs E local SGD steps on one client's data via lax.scan and
returns the model delta -- the payload of the UT stage.  FedProx's proximal
term (mu/2 ||w - w_global||^2) is supported for non-IID robustness; mu=0
recovers FedAvg's plain local SGD.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def local_update(
    loss_fn: Callable,
    params,
    batches,                 # pytree with leading (E, ...) axis: one batch/step
    lr: float = 0.1,
    prox_mu: float = 0.0,
):
    """Returns (delta, mean_loss).  delta = w_local_final - w_global."""
    w_global = params

    def grad_loss(p, batch):
        def total(p_):
            l = loss_fn(p_, batch)
            if prox_mu > 0.0:
                sq = sum(
                    jnp.sum(jnp.square((a - b).astype(jnp.float32)))
                    for a, b in zip(jax.tree.leaves(p_), jax.tree.leaves(w_global))
                )
                l = l + 0.5 * prox_mu * sq
            return l
        return jax.value_and_grad(total)(p)

    def step(p, batch):
        loss, g = grad_loss(p, batch)
        p = jax.tree.map(lambda w, gr: (w - lr * gr).astype(w.dtype), p, g)
        return p, loss

    p_final, losses = jax.lax.scan(step, params, batches)
    delta = jax.tree.map(lambda a, b: a - b, p_final, w_global)
    return delta, jnp.mean(losses)
