"""Composable stochastic scenario processes for the multi-period simulator.

Turns the single-scenario §VI reproduction into a scenario-parameterized
evaluation engine: channel evolution (i.i.d., Gauss-Markov shadowing,
correlated Rayleigh block fading), arrival processes (Poisson, periodic,
batched, bursty MMPP), and client churn (Bernoulli, Gilbert) are all
registered under string keys -- mirroring ``core.policy`` -- and selected
from ``fl.simulator.SimConfig`` by name or parameterized ``spec``:

    from repro import scenarios
    from repro.fl import simulator

    cfg = simulator.SimConfig(
        policy="coop",
        channel_process=scenarios.spec("gauss_markov", rho=0.95),
        arrival_process=scenarios.spec("mmpp", burst=8.0),
        churn_process=scenarios.spec("gilbert", p_drop=0.2, p_return=0.3),
    )
    out = simulator.run_scan(cfg)       # still ONE compiled lax.scan

Channel and churn processes share the pure signature
``step(key, state, svc) -> (state', svc')`` with their state threaded
through the scan carry; arrival processes are episode-static device-side
samplers ``draw(key, n, mean_interval)``, vmapped over seeds by the
simulator so fleet setup is one compiled dispatch.  See ``base`` for the
registry contract and EXPERIMENTS.md for the catalogue.
"""
from __future__ import annotations

from repro.scenarios import arrival, channel, churn  # noqa: F401  (register)
from repro.scenarios.base import (KINDS, Process, ScenarioSpec, as_spec,
                                  available, get_process, register, spec)

__all__ = [
    "KINDS", "Process", "ScenarioSpec", "as_spec", "available",
    "get_process", "register", "spec",
    "get_channel", "get_arrival", "get_churn",
]


def get_channel(sp, net) -> Process:
    """Build a channel Process from a registry key / ScenarioSpec."""
    return get_process("channel", as_spec(sp, default="iid"), net=net)


def get_churn(sp, net) -> Process:
    """Build a churn Process from a registry key / ScenarioSpec."""
    return get_process("churn", as_spec(sp, default="none"), net=net)


def get_arrival(sp):
    """Build an arrival sampler ``draw(key, n, mean_interval)``."""
    return get_process("arrival", as_spec(sp, default="poisson"))
