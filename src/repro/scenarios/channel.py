"""Channel scenario processes: how per-period wireless state evolves.

The paper's §VI setup redraws every channel i.i.d. each period; these
processes add the temporally-correlated alternatives that stress exactly the
claims the paper makes about robustness to channel heterogeneity (Figs.
13-14).  All of them rebuild the period's ``ServiceSet`` through
``network.sample_services`` on the *same* per-period key the i.i.d. path
uses, so non-channel draws (model sizes, powers, compute times) are
untouched and a correlation-free configuration degenerates to the i.i.d.
engine bitwise:

* ``iid`` -- the identity process (state ``()``): keeps the period's base
  sample, i.e. today's behavior.
* ``gauss_markov`` -- AR(1) Gauss-Markov shadowing on the path-loss standard
  normals: z' = rho * z + sqrt(1 - rho^2) * eps with eps the very normals
  the i.i.d. draw would have consumed (``network.channel_innovations``).
  rho = 0 therefore reproduces the i.i.d. redraw exactly; rho -> 1 freezes
  the shadowing for the whole episode.
* ``rayleigh_block`` -- block-correlated Rayleigh fast fading: a complex
  Gaussian per-client tap h with AR(1) coherence, fading margin
  -10 log10 |h|^2 dB added on top of the (optionally also correlated)
  shadowing.  E|h|^2 = 1, so the long-run average channel matches §VI.A.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import network
from repro.scenarios.base import FADING_SALT, INIT_SALT, Process, register


def _validate_rho(rho: float, name: str) -> float:
    rho = float(rho)
    if not 0.0 <= rho < 1.0:
        raise ValueError(f"{name} must be in [0, 1), got {rho}")
    return rho


@register("channel", "iid")
def iid():
    """Identity: keep the period's i.i.d. base sample (paper default)."""

    def init(key, n, k):
        return ()

    def step(key, state, svc):
        return state, svc

    return Process(init, step)


def _ar1(z, eps, rho):
    return rho * z + jnp.sqrt(1.0 - rho * rho) * eps


def fading_margin_db(h_re, h_im, gain_floor: float) -> jax.Array:
    """Rayleigh fading margin -10 log10 |h|^2 in dB, with deep fades clamped
    at -10 log10(gain_floor) so an outage can never be infinitely deep."""
    power = jnp.maximum(h_re * h_re + h_im * h_im, gain_floor)
    return -10.0 * jnp.log10(power)


@register("channel", "gauss_markov")
def gauss_markov(net, rho: float = 0.95, rho_service: float | None = None):
    """Gauss-Markov shadowing: AR(1) on the path-loss innovations.

    ``rho`` correlates the per-client spread; ``rho_service`` the across-
    service mean path loss (defaults to ``rho``).  Stationary N(0, 1) in
    both, so every marginal period is distributed exactly like §VI.A.
    """
    rho_c = _validate_rho(rho, "rho")
    rho_s = _validate_rho(rho if rho_service is None else rho_service,
                          "rho_service")

    def init(key, n, k):
        ks, kc = jax.random.split(jax.random.fold_in(key, INIT_SALT))
        return (jax.random.normal(ks, (n, 1)), jax.random.normal(kc, (n, k)))

    def step(key, state, svc):
        z_s, z_c = state
        eps_s, eps_c = network.channel_innovations(key, svc.n_services, svc.k_max)
        z_s, z_c = _ar1(z_s, eps_s, rho_s), _ar1(z_c, eps_c, rho_c)
        svc2, _ = network.sample_services(
            key, svc.n_services, net, k_max=svc.k_max,
            client_counts=svc.client_counts(), channel_normals=(z_s, z_c),
        )
        return (z_s, z_c), svc2

    return Process(init, step, rebuilds=True)


@register("channel", "rayleigh_block")
def rayleigh_block(net, rho: float = 0.9, shadowing_rho: float | None = None,
                   floor_db: float = -40.0):
    """Correlated Rayleigh fast fading on top of (optionally AR(1)) shadowing.

    Per-client complex tap h with AR(1) coherence ``rho`` (h' = rho h +
    sqrt(1-rho^2) w, w ~ CN(0, 1)); the period's path loss gains the fading
    margin -10 log10 |h|^2 dB, clamped at ``floor_db`` so a deep fade cannot
    produce an infinite-dB outage.  ``shadowing_rho`` additionally threads
    the Gauss-Markov shadowing state; None keeps shadowing i.i.d.
    """
    rho_h = _validate_rho(rho, "rho")
    rho_sh = None if shadowing_rho is None else _validate_rho(
        shadowing_rho, "shadowing_rho")
    gain_floor = 10.0 ** (float(floor_db) / 10.0)

    def init(key, n, k):
        kr, ki, ks, kc = jax.random.split(jax.random.fold_in(key, INIT_SALT), 4)
        inv = jnp.sqrt(0.5)
        h = (inv * jax.random.normal(kr, (n, k)),
             inv * jax.random.normal(ki, (n, k)))
        if rho_sh is None:
            return h
        return h + (jax.random.normal(ks, (n, 1)),
                    jax.random.normal(kc, (n, k)))

    def step(key, state, svc):
        h_re, h_im = state[0], state[1]
        kr, ki = jax.random.split(jax.random.fold_in(key, FADING_SALT))
        inv = jnp.sqrt(0.5)
        h_re = _ar1(h_re, inv * jax.random.normal(kr, h_re.shape), rho_h)
        h_im = _ar1(h_im, inv * jax.random.normal(ki, h_im.shape), rho_h)
        fade_db = fading_margin_db(h_re, h_im, gain_floor)
        normals = None
        state2 = (h_re, h_im)
        if rho_sh is not None:
            eps_s, eps_c = network.channel_innovations(
                key, svc.n_services, svc.k_max)
            z_s, z_c = _ar1(state[2], eps_s, rho_sh), _ar1(state[3], eps_c, rho_sh)
            normals = (z_s, z_c)
            state2 = state2 + (z_s, z_c)
        svc2, _ = network.sample_services(
            key, svc.n_services, net, k_max=svc.k_max,
            client_counts=svc.client_counts(), channel_normals=normals,
            extra_pathloss_db=fade_db,
        )
        return state2, svc2

    return Process(init, step, rebuilds=True)
