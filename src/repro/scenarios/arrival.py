"""Arrival scenario processes: when each FL service enters the network.

Episode-static NumPy samplers ``draw(rng, n, mean_interval) -> int64 (n,)``
of non-decreasing arrival periods, consumed by the simulator's
``_static_draws`` before compilation (arrival times are data to the compiled
episode, so these never touch the jit cache).

* ``poisson``  -- exponential inter-arrival gaps (the paper's §VI.D process
  and the default; identical RNG stream to the pre-scenario engine).
* ``periodic`` -- deterministic arrivals every ``mean_interval`` periods
  (the zero-variance baseline of an arrival sweep).
* ``batched``  -- services arrive in simultaneous groups of ``group`` with
  exponential gaps between groups (flash-crowd onboarding).
* ``mmpp``     -- 2-state Markov-modulated Poisson process: a *burst* state
  draws gaps ``burst`` times shorter than the mean, a *calm* state
  compensates so the long-run rate stays ~1/mean_interval; ``stay`` is the
  per-arrival probability of remaining in the current state.  This is the
  bursty-demand stressor (cf. arXiv:2011.12469's time-varying loads).
"""
from __future__ import annotations

import numpy as np

from repro.scenarios.base import register


@register("arrival", "poisson")
def poisson():
    def draw(rng, n, mean_interval):
        gaps = rng.exponential(mean_interval, size=n)
        return np.floor(np.cumsum(gaps)).astype(np.int64)

    return draw


@register("arrival", "periodic")
def periodic():
    def draw(rng, n, mean_interval):
        return np.floor(np.arange(n, dtype=np.float64) * mean_interval).astype(np.int64)

    return draw


@register("arrival", "batched")
def batched(group: int = 3):
    group = int(group)
    if group < 1:
        raise ValueError(f"group must be >= 1, got {group}")

    def draw(rng, n, mean_interval):
        n_groups = -(-n // group)
        gaps = rng.exponential(mean_interval * group, size=n_groups)
        starts = np.floor(np.cumsum(gaps)).astype(np.int64)
        return np.repeat(starts, group)[:n]

    return draw


@register("arrival", "mmpp")
def mmpp(burst: float = 6.0, stay: float = 0.7):
    burst = float(burst)
    stay = float(stay)
    if burst < 1.0:
        raise ValueError(f"burst must be >= 1, got {burst}")
    if not 0.0 <= stay < 1.0:
        raise ValueError(f"stay must be in [0, 1), got {stay}")

    def draw(rng, n, mean_interval):
        # Equal-occupancy two-state chain; state means average to mean_interval.
        means = (mean_interval / burst, mean_interval * (2.0 - 1.0 / burst))
        state = int(rng.integers(2))
        gaps = np.empty(n, dtype=np.float64)
        for i in range(n):
            gaps[i] = rng.exponential(means[state])
            if rng.random() >= stay:
                state = 1 - state
        return np.floor(np.cumsum(gaps)).astype(np.int64)

    return draw
