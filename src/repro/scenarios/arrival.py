"""Arrival scenario processes: when each FL service enters the network.

Episode-static *device-side* samplers ``draw(key, n, mean_interval) ->
int32 (n,)`` of non-decreasing arrival periods.  Each sampler is a pure,
traceable jax function of a PRNG key, so the simulator's ``_static_draws``
can vmap one compiled draw over a whole fleet of seeds (O(1) dispatches for
any fleet size) instead of looping a host RNG per seed; ``n`` is static.
Arrival times are still *data* to the compiled episode -- the draw happens
once per episode, outside the period scan.

* ``poisson``  -- exponential inter-arrival gaps (the paper's §VI.D process
  and the default).
* ``periodic`` -- deterministic arrivals every ``mean_interval`` periods
  (the zero-variance baseline of an arrival sweep; consumes no randomness).
* ``batched``  -- services arrive in simultaneous groups of ``group`` with
  exponential gaps between groups (flash-crowd onboarding).
* ``mmpp``     -- 2-state Markov-modulated Poisson process: a *burst* state
  draws gaps ``burst`` times shorter than the mean, a *calm* state
  compensates so the long-run rate stays ~1/mean_interval; ``stay`` is the
  per-arrival probability of remaining in the current state.  This is the
  bursty-demand stressor (cf. arXiv:2011.12469's time-varying loads).  The
  per-arrival state chain is a ``lax.scan`` over per-step subkeys, so the
  sampler stays a single traceable draw.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.scenarios.base import register


@register("arrival", "poisson")
def poisson():
    def draw(key, n, mean_interval):
        gaps = jax.random.exponential(key, (n,), jnp.float32) * mean_interval
        return jnp.floor(jnp.cumsum(gaps)).astype(jnp.int32)

    return draw


@register("arrival", "periodic")
def periodic():
    def draw(key, n, mean_interval):
        del key  # deterministic
        return jnp.floor(
            jnp.arange(n, dtype=jnp.float32) * mean_interval).astype(jnp.int32)

    return draw


@register("arrival", "batched")
def batched(group: int = 3):
    group = int(group)
    if group < 1:
        raise ValueError(f"group must be >= 1, got {group}")

    def draw(key, n, mean_interval):
        n_groups = -(-n // group)
        gaps = jax.random.exponential(
            key, (n_groups,), jnp.float32) * (mean_interval * group)
        starts = jnp.floor(jnp.cumsum(gaps)).astype(jnp.int32)
        return jnp.repeat(starts, group)[:n]

    return draw


@register("arrival", "mmpp")
def mmpp(burst: float = 6.0, stay: float = 0.7):
    burst = float(burst)
    stay = float(stay)
    if burst < 1.0:
        raise ValueError(f"burst must be >= 1, got {burst}")
    if not 0.0 <= stay < 1.0:
        raise ValueError(f"stay must be in [0, 1), got {stay}")

    def draw(key, n, mean_interval):
        # Equal-occupancy two-state chain; state means average to mean_interval.
        means = jnp.array(
            [mean_interval / burst, mean_interval * (2.0 - 1.0 / burst)],
            jnp.float32)
        key_s0, key_steps = jax.random.split(key)
        state0 = jax.random.bernoulli(key_s0).astype(jnp.int32)

        def step(state, k):
            k_gap, k_flip = jax.random.split(k)
            gap = jax.random.exponential(k_gap, dtype=jnp.float32) * means[state]
            flip = jax.random.uniform(k_flip) >= stay
            return jnp.where(flip, 1 - state, state), gap

        _, gaps = jax.lax.scan(step, state0, jax.random.split(key_steps, n))
        return jnp.floor(jnp.cumsum(gaps)).astype(jnp.int32)

    return draw
