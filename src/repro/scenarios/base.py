"""Scenario-process registry: the machinery behind ``repro.scenarios``.

A *scenario process* is a stateful stochastic transform of the simulated
workload.  Two kinds exist:

* **Jax processes** (kinds ``"channel"`` and ``"churn"``) follow one pure
  signature

      step(key, state, svc) -> (state', svc')

  where ``state`` is an arbitrary pytree of arrays that the scan simulator
  threads through its ``lax.scan`` carry, and ``svc`` is the period's
  fixed-capacity ``ServiceSet``.  A companion ``init(key, n, k) -> state``
  builds the initial (stationary) state.  Mask/shape discipline: ``svc'``
  must keep the (N, K) shapes of ``svc`` so activity stays a mask flip and
  the compiled period step never retraces.

* **Arrival processes** (kind ``"arrival"``) are episode-static device-side
  samplers ``draw(key, n, mean_interval) -> int32 (n,)`` of non-decreasing
  arrival periods (``n`` static, ``key`` a jax PRNG key).  They are pure and
  vmappable, so the simulator's ``_static_draws`` batches one compiled draw
  over a whole fleet of seeds; arrival times remain data to the compiled
  episode.

Processes are registered under string keys per kind (mirroring
``core.policy``) and selected by a hashable ``ScenarioSpec`` so specs can be
jit statics: ``spec("gauss_markov", rho=0.95)`` or just the bare name for
default parameters.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, NamedTuple

KINDS = ("channel", "arrival", "churn")

# Salt offsets folded into the per-period key so scenario draws never collide
# with the 8-way split ``network.sample_services`` consumes (periods are far
# below 2**30, so these also never collide with a period number).  This block
# is the single registry of episode-key salts: the simulator's static-draw
# stream sits at +3 (``fl.simulator._DRAW_SALT``) and the co-simulation's
# model-init stream at +4 (``COTRAIN_SALT``), so adding a consumer here is
# how you prove it cannot disturb any existing stream.
INIT_SALT = 1 << 30
FADING_SALT = (1 << 30) + 1
CHURN_SALT = (1 << 30) + 2
# (1 << 30) + 3 == fl.simulator._DRAW_SALT (episode-static arrivals/counts)
COTRAIN_SALT = (1 << 30) + 4


class Process(NamedTuple):
    """A stateful jax scenario process (channel or churn kind).

    ``rebuilds=True`` declares that ``step`` reconstructs the period's
    ServiceSet from scratch (reading only shapes and ``client_counts()``
    from its ``svc`` input); the simulator then skips the base i.i.d. draw
    and hands such a process a shape/mask-only shell instead of a sampled
    set.  Perturbing processes (churn, the identity) keep the default
    ``False`` and receive the real sampled ServiceSet.
    """

    init: Callable[..., Any]    # (key, n, k) -> state pytree
    step: Callable[..., Any]    # (key, state, svc) -> (state', svc')
    rebuilds: bool = False


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Hashable (name, params) pair selecting a registered process.

    ``params`` is a sorted tuple of (key, value) pairs so the spec can sit in
    a jit ``static_argnames`` slot; build via ``spec(name, **params)``.
    """

    name: str
    params: tuple[tuple[str, Any], ...] = ()

    def kwargs(self) -> dict:
        return dict(self.params)


def spec(name: str, **params) -> ScenarioSpec:
    return ScenarioSpec(name, tuple(sorted(params.items())))


def as_spec(value: str | ScenarioSpec | None, default: str) -> ScenarioSpec:
    """Normalize a SimConfig field (name, spec, or None) to a ScenarioSpec."""
    if value is None:
        return ScenarioSpec(default)
    if isinstance(value, ScenarioSpec):
        return value
    if isinstance(value, str):
        return ScenarioSpec(value)
    raise TypeError(
        f"scenario selector must be a registry key or ScenarioSpec, got "
        f"{type(value).__name__}: {value!r}")


_REGISTRIES: dict[str, dict[str, Callable[..., Any]]] = {k: {} for k in KINDS}


def register(kind: str, name: str):
    """Register a factory for ``name`` under ``kind``.

    Channel/churn factories take keyword parameters (plus the context kwarg
    ``net`` if they need the NetworkConfig) and return a ``Process``; arrival
    factories return the ``draw(key, n, mean_interval)`` callable.
    """
    if kind not in _REGISTRIES:
        raise ValueError(f"unknown scenario kind {kind!r}; expected one of {KINDS}")

    def deco(factory):
        _REGISTRIES[kind][name] = factory
        return factory

    return deco


def available(kind: str) -> tuple[str, ...]:
    if kind not in _REGISTRIES:
        raise ValueError(f"unknown scenario kind {kind!r}; expected one of {KINDS}")
    return tuple(sorted(_REGISTRIES[kind]))


def get_process(kind: str, sp: str | ScenarioSpec, **context):
    """Build the selected process, validating the spec's parameter names.

    ``context`` carries simulator-provided objects (e.g. ``net``) that are
    forwarded only to factories whose signature asks for them.  Unknown
    process names and unknown parameters both raise a clear ValueError —
    a typo must never be silently swallowed (same contract as
    ``core.policy.get_policy``).
    """
    sp = as_spec(sp, default="")
    reg = _REGISTRIES[kind]
    if sp.name not in reg:
        raise ValueError(
            f"unknown {kind} process {sp.name!r}; available: {available(kind)}")
    factory = reg[sp.name]
    sig = inspect.signature(factory)
    accepted = {
        p.name for p in sig.parameters.values()
        if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                      inspect.Parameter.KEYWORD_ONLY)
    }
    unknown = sorted(set(sp.kwargs()) - accepted)
    if unknown:
        known = sorted(accepted - set(context))
        raise ValueError(
            f"unknown parameter(s) {unknown} for {kind} process "
            f"{sp.name!r}; known parameters: {known}")
    reserved = sorted(set(sp.kwargs()) & set(context))
    if reserved:
        raise ValueError(
            f"parameter(s) {reserved} of {kind} process {sp.name!r} are "
            f"supplied by the simulator and cannot be set in a spec")
    kwargs = sp.kwargs()
    for key, value in context.items():
        if key in accepted:
            kwargs[key] = value
    return factory(**kwargs)
