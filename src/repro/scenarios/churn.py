"""Client-churn scenario processes: per-period availability of FL clients.

The paper assumes every enrolled client participates in every round; these
processes model device churn (battery, mobility, user activity) as pure mask
perturbations on the fixed-capacity ``ServiceSet`` (``types.mask_clients``),
so the compiled period step never retraces.  A service whose clients all
drop for a period simply makes no FL progress that period (b = f = 0) while
its duration keeps counting -- the realistic stall the allocation policies
must absorb.

* ``none``      -- identity (paper default).
* ``bernoulli`` -- memoryless dropout: each client independently unavailable
  with probability ``p_drop`` each period.
* ``gilbert``   -- two-state Gilbert availability chain per client: an
  available client drops with ``p_drop``, a dropped one returns with
  ``p_return``; small ``p_return`` gives long, bursty outages at the same
  average availability.  Steady-state availability is
  p_return / (p_drop + p_return).

Both stochastic processes accept ``always_keep``: the first that many client
slots of every service are churn-immune (e.g. anchor devices on wall power),
bounding worst-case stalls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import mask_clients
from repro.scenarios.base import CHURN_SALT, Process, register


def _validate_prob(p: float, name: str) -> float:
    p = float(p)
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"{name} must be in [0, 1], got {p}")
    return p


def _keep_mask(k: int, always_keep: int):
    return jnp.arange(k) < always_keep


@register("churn", "none")
def none():
    def init(key, n, k):
        return ()

    def step(key, state, svc):
        return state, svc

    return Process(init, step)


@register("churn", "bernoulli")
def bernoulli(p_drop: float = 0.2, always_keep: int = 0):
    p = _validate_prob(p_drop, "p_drop")
    always_keep = int(always_keep)

    def init(key, n, k):
        return ()

    def step(key, state, svc):
        u = jax.random.uniform(jax.random.fold_in(key, CHURN_SALT),
                               svc.mask.shape)
        avail = jnp.logical_or(u >= p, _keep_mask(svc.k_max, always_keep))
        return state, mask_clients(svc, avail)

    return Process(init, step)


@register("churn", "gilbert")
def gilbert(p_drop: float = 0.1, p_return: float = 0.4, always_keep: int = 0):
    p_d = _validate_prob(p_drop, "p_drop")
    p_r = _validate_prob(p_return, "p_return")
    always_keep = int(always_keep)
    # Steady-state availability; the degenerate frozen chain (both probs 0)
    # never transitions, so everyone simply stays available.
    steady = p_r / (p_d + p_r) if (p_d + p_r) > 0.0 else 1.0

    def init(key, n, k):
        # Start at the chain's steady state so churn statistics are
        # stationary from period 0.
        u = jax.random.uniform(jax.random.fold_in(key, CHURN_SALT), (n, k))
        return jnp.logical_or(u < steady, _keep_mask(k, always_keep))

    def step(key, state, svc):
        u = jax.random.uniform(jax.random.fold_in(key, CHURN_SALT),
                               svc.mask.shape)
        avail = jnp.where(state, u >= p_d, u < p_r)
        avail = jnp.logical_or(avail, _keep_mask(svc.k_max, always_keep))
        return avail, mask_clients(svc, avail)

    return Process(init, step)
