"""Decoder-only transformer covering the dense, MoE, MLA-MoE and hybrid
(attention ∥ SSM) families.

Layer stacks run under ``jax.lax.scan`` with stacked parameters, so the
lowered HLO contains ONE block body regardless of depth -- essential for fast
SPMD compiles at 512 devices and for real TPU compile times.  Heterogeneous
patterns are expressed without breaking scan homogeneity:

  * local/global attention (gemma3, hymba): a traced per-layer ``is_global``
    flag toggles the sliding-window mask term inside one scan;
  * alternating dense/MoE (llama4): the scan iterates over (dense, MoE)
    super-blocks with both parameter sets stacked;
  * leading dense layers (deepseek-v2): applied outside the main MoE scan.

The same ``_forward`` drives training (no cache), prefill (writes a cache)
and decode (appends one token), selected by the inputs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import api as dist_api
from repro.models import layers, mla, moe, ssm
from repro.models.config import ModelConfig

Params = dict[str, Any]
MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Single-layer init / apply.
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, use_moe: bool) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"ln1": jnp.zeros((d,), jnp.float32)}
    if cfg.family == "mla_moe":
        p["attn"] = mla.init_mla(ks[0], cfg)
    else:
        p["attn"] = layers.init_attention(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        )
    if cfg.family == "hybrid":
        d_inner = cfg.n_heads * cfg.head_dim
        p["ssm"] = ssm.init_ssm(ks[1], cfg, d_inner)
        p["attn_out_norm"] = jnp.zeros((d_inner,), jnp.float32)
        p["ssm_out_norm"] = jnp.zeros((d_inner,), jnp.float32)
        p["w_mix_out"] = layers.dense_init(ks[4], d_inner, d)
    if cfg.d_ff > 0 or use_moe:
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        if use_moe:
            p["ffn"] = moe.init_moe(ks[2], cfg)
        else:
            p["ffn"] = layers.init_mlp(ks[2], d, cfg.d_ff, cfg.mlp_kind)
    if cfg.post_norm:
        p["ln_post_attn"] = jnp.zeros((d,), jnp.float32)
        p["ln_post_ffn"] = jnp.zeros((d,), jnp.float32)
    return p


def _quantize_kv(x):
    """(B,S,H,D) -> (int8 values, per-(token,head) f32 scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _attend(p, cfg: ModelConfig, h, positions, is_global, cache_k, cache_v, cache_len,
            chunk_size, cache_extra=None):
    """GQA attention with optional KV cache append.  Returns (out, k, v) or,
    with an int8 cache, (out, (k_q, k_scale), (v_q, v_scale))."""
    dtype = h.dtype
    b, s, _ = h.shape
    q, k, v = layers.project_qkv(p, h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype)
    if cfg.mrope_sections:
        q = layers.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = layers.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        q_offset = 0  # M-RoPE prefill/train only uses full-sequence positions
    else:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
        q_offset = 0

    window = cfg.sliding_window if cfg.attn_pattern != "full" else 0
    window_active = None
    if window > 0:
        if cfg.attn_pattern == "sliding":
            window_active = jnp.bool_(True) if is_global is None else jnp.logical_not(is_global)
        else:  # local_global: traced flag from the scan
            window_active = jnp.logical_not(is_global)

    if cache_k is not None:
        if cfg.kv_cache_dtype == "int8":
            # int8 KV with per-(token, head) scales: halves the decode memory
            # term vs bf16 (EXPERIMENTS.md §Perf, decode hillclimb)
            k_q, k_s = _quantize_kv(k)
            v_q, v_s = _quantize_kv(v)
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache_k, k_q, cache_len, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache_v, v_q, cache_len, axis=1)
            ks_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_extra["k_scale"], k_s, cache_len, axis=1)
            vs_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_extra["v_scale"], v_s, cache_len, axis=1)
            k_all = _dequantize_kv(k_cache, ks_cache, dtype)
            v_all = _dequantize_kv(v_cache, vs_cache, dtype)
            out = layers.chunked_attention(
                q, k_all, v_all, causal=True, window=window, q_offset=cache_len,
                kv_valid_len=cache_len + s, window_active=window_active,
                logit_softcap=cfg.logit_softcap, chunk_size=chunk_size,
            )
            return out, (k_cache, ks_cache), (v_cache, vs_cache)
        k_all = jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_len, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_len, axis=1)
        out = layers.chunked_attention(
            q, k_all, v_all, causal=True, window=window, q_offset=cache_len,
            kv_valid_len=cache_len + s, window_active=window_active,
            logit_softcap=cfg.logit_softcap, chunk_size=chunk_size,
        )
        return out, k_all, v_all
    out = layers.chunked_attention(
        q, k, v, causal=True, window=window, window_active=window_active,
        logit_softcap=cfg.logit_softcap, chunk_size=chunk_size,
    )
    return out, None, None


def apply_layer(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    use_moe: bool,
    is_global: jax.Array | None = None,
    cache: dict | None = None,       # per-layer slices
    cache_len: jax.Array | None = None,
    chunk_size: int = 1024,
) -> tuple[jax.Array, dict, jax.Array]:
    """One block.  Returns (x, new_cache_slices, moe_aux_loss)."""
    dtype = x.dtype
    new_cache: dict = {}
    aux = jnp.zeros((), jnp.float32)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)

    if cfg.family == "mla_moe":
        attn_out, new_ckv, new_krope = mla.apply_mla(
            p["attn"], h, cfg, positions,
            cache_ckv=None if cache is None else cache["ckv"],
            cache_krope=None if cache is None else cache["krope"],
            cache_len=cache_len, chunk_size=chunk_size,
        )
        if cache is not None:
            new_cache.update(ckv=new_ckv, krope=new_krope)
    elif cfg.family == "hybrid":
        a_out, k_all, v_all = _attend(
            p["attn"], cfg, h, positions, is_global,
            None if cache is None else cache["k"],
            None if cache is None else cache["v"], cache_len, chunk_size,
        )
        d_inner = cfg.n_heads * cfg.head_dim
        a_out = a_out.reshape(*h.shape[:2], d_inner)
        s_out, conv_st, ssm_st = ssm.apply_ssm(
            p["ssm"], h, cfg,
            None if cache is None else cache["conv"],
            None if cache is None else cache["ssm"],
        )
        mixed = 0.5 * (
            layers.rms_norm(a_out, p["attn_out_norm"], cfg.norm_eps)
            + layers.rms_norm(s_out, p["ssm_out_norm"], cfg.norm_eps)
        )
        attn_out = mixed @ p["w_mix_out"].astype(dtype)
        if cache is not None:
            new_cache.update(k=k_all, v=v_all, conv=conv_st, ssm=ssm_st)
    else:
        extra = None
        if cache is not None and cfg.kv_cache_dtype == "int8":
            extra = {"k_scale": cache["k_scale"], "v_scale": cache["v_scale"]}
        raw, k_all, v_all = _attend(
            p["attn"], cfg, h, positions, is_global,
            None if cache is None else cache["k"],
            None if cache is None else cache["v"], cache_len, chunk_size,
            cache_extra=extra,
        )
        b, s = h.shape[:2]
        attn_out = raw.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["attn"]["wo"].astype(dtype)
        if cache is not None:
            if cfg.kv_cache_dtype == "int8":
                new_cache.update(k=k_all[0], k_scale=k_all[1],
                                 v=v_all[0], v_scale=v_all[1])
            else:
                new_cache.update(k=k_all, v=v_all)

    if cfg.post_norm:
        attn_out = layers.rms_norm(attn_out, p["ln_post_attn"], cfg.norm_eps)

    if "ffn" not in p:
        return x + attn_out, new_cache, aux

    if cfg.parallel_block:
        ffn_in = h
        x_mid = x
    else:
        x_mid = x + attn_out
        ffn_in = layers.rms_norm(x_mid, p["ln2"], cfg.norm_eps)

    if use_moe:
        ffn_out, aux = moe.apply_moe(p["ffn"], ffn_in, cfg)
    else:
        ffn_out = layers.apply_mlp(p["ffn"], ffn_in, cfg.mlp_kind, dtype)
    if cfg.post_norm:
        ffn_out = layers.rms_norm(ffn_out, p["ln_post_ffn"], cfg.norm_eps)

    if cfg.parallel_block:
        return x + attn_out + ffn_out, new_cache, aux
    return x_mid + ffn_out, new_cache, aux


# ---------------------------------------------------------------------------
# The full model.
# ---------------------------------------------------------------------------

def _stacked_init(key, n: int, fn) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


@dataclasses.dataclass(frozen=True)
class CausalLM:
    cfg: ModelConfig

    # ---------------- init ----------------
    def init(self, key) -> Params:
        cfg = self.cfg
        k_embed, k_layers, k_head = jax.random.split(key, 3)
        p: Params = {
            "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = layers.dense_init(k_head, cfg.d_model, cfg.vocab_size)
        if cfg.n_experts and cfg.moe_every == 2:
            ka, kb = jax.random.split(k_layers)
            n_pairs = cfg.n_layers // 2
            p["pairs"] = {
                "dense": _stacked_init(ka, n_pairs, lambda k: init_layer(k, cfg, False)),
                "moe": _stacked_init(kb, n_pairs, lambda k: init_layer(k, cfg, True)),
            }
        elif cfg.n_experts:
            ka, kb = jax.random.split(k_layers)
            if cfg.n_dense_leading:
                p["lead"] = _stacked_init(
                    ka, cfg.n_dense_leading, lambda k: init_layer(k, cfg, False)
                )
            p["blocks"] = _stacked_init(
                kb, cfg.n_layers - cfg.n_dense_leading,
                lambda k: init_layer(k, cfg, True),
            )
        else:
            p["blocks"] = _stacked_init(
                k_layers, cfg.n_layers, lambda k: init_layer(k, cfg, False)
            )
        return p

    # ---------------- caches ----------------
    def init_cache(self, batch_size: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = cfg.compute_dtype
        l = cfg.n_layers
        cache: dict = {"len": jnp.zeros((), jnp.int32)}
        if cfg.family == "mla_moe":
            cache["ckv"] = jnp.zeros((l, batch_size, max_len, cfg.kv_lora_rank), dt)
            cache["krope"] = jnp.zeros((l, batch_size, max_len, cfg.rope_head_dim), dt)
        elif cfg.kv_cache_dtype == "int8" and cfg.family != "hybrid":
            kv_shape = (l, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
            cache["k"] = jnp.zeros(kv_shape, jnp.int8)
            cache["v"] = jnp.zeros(kv_shape, jnp.int8)
            cache["k_scale"] = jnp.zeros(kv_shape[:-1], jnp.float32)
            cache["v_scale"] = jnp.zeros(kv_shape[:-1], jnp.float32)
        else:
            cache["k"] = jnp.zeros(
                (l, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), dt
            )
            cache["v"] = jnp.zeros_like(cache["k"])
        if cfg.family == "hybrid":
            d_inner = cfg.n_heads * cfg.head_dim
            cache["conv"] = jnp.zeros((l, batch_size, cfg.ssm_conv - 1, d_inner), dt)
            cache["ssm"] = jnp.zeros((l, batch_size, d_inner, cfg.ssm_state), jnp.float32)
        return cache

    # ---------------- forward ----------------
    def _block_fn(self, use_moe: bool, has_cache: bool, chunk_size: int):
        cfg = self.cfg

        def fn(x, positions, p_l, is_global_l, cache_l, cache_len):
            return apply_layer(
                p_l, cfg, x, positions, use_moe=use_moe, is_global=is_global_l,
                cache=cache_l if has_cache else None, cache_len=cache_len,
                chunk_size=chunk_size,
            )

        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn

    def _scan_stack(self, params_stack, x, positions, is_global, cache, cache_len,
                    use_moe: bool, chunk_size: int):
        """Scan one homogeneous group of layers.  cache: dict of (L,...) or None."""
        has_cache = cache is not None
        block = self._block_fn(use_moe, has_cache, chunk_size)

        if not has_cache:
            def body_nc(carry, xs_l):
                x, aux = carry
                p_l, glob_l = xs_l
                x, _, aux_l = block(x, positions, p_l, glob_l, None, cache_len)
                return (x, aux + aux_l), None

            (x, aux), _ = jax.lax.scan(body_nc, (x, jnp.zeros((), jnp.float32)),
                                       (params_stack, is_global))
            return x, None, aux

        def body(carry, xs_l):
            x, aux = carry
            p_l, glob_l, cache_l = xs_l
            x, new_cache_l, aux_l = block(x, positions, p_l, glob_l, cache_l, cache_len)
            return (x, aux + aux_l), new_cache_l

        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params_stack, is_global, cache)
        )
        return x, new_cache, aux

    def forward(
        self,
        params: Params,
        tokens: jax.Array,
        positions: jax.Array | None = None,
        cache: dict | None = None,
        embeds_override: jax.Array | None = None,
        logits_mode: str = "all",
        chunk_size: int = 1024,
    ):
        """Returns (logits, new_cache, moe_aux)."""
        cfg = self.cfg
        dt = cfg.compute_dtype
        b, s = tokens.shape
        x = params["embed"][tokens].astype(dt)
        if cfg.embed_scale:
            x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dt)
        if embeds_override is not None:
            # modality stub: precomputed frontend embeddings overwrite the
            # leading positions (vision patches / audio frames)
            n_pre = embeds_override.shape[1]
            x = jnp.concatenate([embeds_override.astype(dt), x[:, n_pre:]], axis=1)

        cache_len = None if cache is None else cache["len"]
        if positions is None:
            start = 0 if cache is None else cache_len
            positions = jnp.arange(s)[None, :] + (start if cache is not None else 0)
            positions = jnp.broadcast_to(positions, (b, s))

        glob_flags = jnp.array(
            [cfg.is_global_layer(i) for i in range(cfg.n_layers)], dtype=bool
        )
        new_cache = None if cache is None else dict(cache)
        aux_total = jnp.zeros((), jnp.float32)

        def cache_slice(sl):
            if cache is None:
                return None
            return {k: v[sl] for k, v in cache.items() if k != "len"}

        if cfg.n_experts and cfg.moe_every == 2:
            n_pairs = cfg.n_layers // 2
            flags = glob_flags.reshape(n_pairs, 2)
            c_pair = None
            if cache is not None:
                c_pair = {k: v.reshape(n_pairs, 2, *v.shape[1:])
                          for k, v in cache.items() if k != "len"}
            has_cache = cache is not None
            block_d = self._block_fn(False, has_cache, chunk_size)
            block_m = self._block_fn(True, has_cache, chunk_size)

            if has_cache:
                def body(carry, xs_l):
                    x, aux = carry
                    pd, pm, fl, cl = xs_l
                    cd = {k: v[0] for k, v in cl.items()}
                    cm = {k: v[1] for k, v in cl.items()}
                    x, ncd, aux_d = block_d(x, positions, pd, fl[0], cd, cache_len)
                    x, ncm, aux_m = block_m(x, positions, pm, fl[1], cm, cache_len)
                    ys = {k: jnp.stack([ncd[k], ncm[k]]) for k in ncd}
                    return (x, aux + aux_d + aux_m), ys

                (x, aux_total), ys = jax.lax.scan(
                    body, (x, aux_total),
                    (params["pairs"]["dense"], params["pairs"]["moe"], flags, c_pair),
                )
                for k in ys:
                    new_cache[k] = ys[k].reshape(cfg.n_layers, *ys[k].shape[2:])
            else:
                def body_nc(carry, xs_l):
                    x, aux = carry
                    pd, pm, fl = xs_l
                    x, _, aux_d = block_d(x, positions, pd, fl[0], None, cache_len)
                    x, _, aux_m = block_m(x, positions, pm, fl[1], None, cache_len)
                    return (x, aux + aux_d + aux_m), None

                (x, aux_total), _ = jax.lax.scan(
                    body_nc, (x, aux_total),
                    (params["pairs"]["dense"], params["pairs"]["moe"], flags),
                )
        else:
            n_lead = cfg.n_dense_leading if cfg.n_experts else 0
            if n_lead:
                x, nc_lead, aux_l = self._scan_stack(
                    params["lead"], x, positions, glob_flags[:n_lead],
                    cache_slice(slice(0, n_lead)), cache_len, False, chunk_size,
                )
                aux_total += aux_l
            x, nc_main, aux_m = self._scan_stack(
                params["blocks"], x, positions, glob_flags[n_lead:],
                cache_slice(slice(n_lead, cfg.n_layers)), cache_len,
                bool(cfg.n_experts), chunk_size,
            )
            aux_total += aux_m
            if cache is not None:
                for k in nc_main:
                    parts = [nc_lead[k], nc_main[k]] if n_lead else [nc_main[k]]
                    new_cache[k] = jnp.concatenate(parts, axis=0)

        x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if logits_mode == "last":
            x = x[:, -1:]
        x = dist_api.constrain(x, "batch", None, None)
        table = params.get("unembed")
        if table is None:
            logits = x @ params["embed"].T.astype(dt)
        else:
            logits = x @ table.astype(dt)
        # pin the canonical (batch@data, :, vocab@model) layout: without this
        # GSPMD's transpose strategy all-gathers full-batch fp32 logits
        logits = dist_api.constrain(logits, "batch", None, "vocab")
        if cache is not None:
            new_cache["len"] = cache_len + s
        return logits, new_cache, aux_total

    # ---------------- public entry points ----------------
    def loss(self, params: Params, batch: dict) -> jax.Array:
        logits, _, aux = self.forward(
            params, batch["tokens"],
            positions=batch.get("positions"),
            embeds_override=batch.get("frontend_embeds"),
        )
        ce = layers.softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        return ce + MOE_AUX_COEF * aux

    def prefill(self, params: Params, batch: dict, max_len: int):
        tokens = batch["tokens"]
        cache = self.init_cache(tokens.shape[0], max_len)
        logits, cache, _ = self.forward(
            params, tokens, positions=batch.get("positions"), cache=cache,
            embeds_override=batch.get("frontend_embeds"), logits_mode="last",
        )
        return logits, cache

    def decode_step(self, params: Params, cache: dict, tokens: jax.Array,
                    positions: jax.Array | None = None):
        logits, cache, _ = self.forward(
            params, tokens, positions=positions, cache=cache, logits_mode="last",
        )
        return logits, cache
