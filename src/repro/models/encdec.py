"""Encoder-decoder transformer (Seamless-M4T-v2 backbone).

The speech/text modality frontend is a STUB per the build brief: the encoder
consumes precomputed frame embeddings (B, S_src, d_model) supplied by
``input_specs``.  The decoder is a standard causal transformer with
cross-attention; decode caches hold the decoder self-attention KV plus the
cross-attention KV projected once from the encoder output at prefill.

TPU adaptation note (DESIGN.md §3): Seamless's conformer speech encoder is
replaced by a plain pre-norm transformer encoder over the stubbed frames --
the conv modules live in the (stubbed) frontend.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import api as dist_api
from repro.models import layers
from repro.models.config import ModelConfig

Params = dict[str, Any]


def init_encoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "attn": layers.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": jnp.zeros((d,), jnp.float32),
        "ffn": layers.init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_kind),
    }


def init_decoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "self_attn": layers.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_x": jnp.zeros((d,), jnp.float32),
        "cross_attn": layers.init_attention(ks[1], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": jnp.zeros((d,), jnp.float32),
        "ffn": layers.init_mlp(ks[2], d, cfg.d_ff, cfg.mlp_kind),
    }


def _mha(p, cfg: ModelConfig, q_in, kv_in, *, causal, positions_q, positions_kv,
         cache_k=None, cache_v=None, cache_len=None, rope=True, chunk_size=1024):
    dtype = q_in.dtype
    b, sq, _ = q_in.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (q_in @ p["wq"].astype(dtype)).reshape(b, sq, h, dh)
    k = (kv_in @ p["wk"].astype(dtype)).reshape(b, kv_in.shape[1], hkv, dh)
    v = (kv_in @ p["wv"].astype(dtype)).reshape(b, kv_in.shape[1], hkv, dh)
    if rope:
        q = layers.apply_rope(q, positions_q, cfg.rope_theta)
        k = layers.apply_rope(k, positions_kv, cfg.rope_theta)
    if cache_k is not None:
        k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_len, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_len, axis=1)
        out = layers.chunked_attention(
            q, k, v, causal=causal, q_offset=cache_len,
            kv_valid_len=cache_len + sq, chunk_size=chunk_size,
        )
    else:
        out = layers.chunked_attention(q, k, v, causal=causal, chunk_size=chunk_size)
    out = out.reshape(b, sq, h * dh) @ p["wo"].astype(dtype)
    return out, k, v


@dataclasses.dataclass(frozen=True)
class Seq2SeqLM:
    cfg: ModelConfig

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, kd, kt = jax.random.split(key, 3)
        return {
            "embed": layers.embed_init(kt, cfg.vocab_size, cfg.d_model),
            "enc_blocks": jax.vmap(lambda k: init_encoder_layer(k, cfg))(
                jax.random.split(ke, cfg.n_encoder_layers)
            ),
            "dec_blocks": jax.vmap(lambda k: init_decoder_layer(k, cfg))(
                jax.random.split(kd, cfg.n_layers)
            ),
            "ln_enc": jnp.zeros((cfg.d_model,), jnp.float32),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames: jax.Array, chunk_size: int = 1024) -> jax.Array:
        """frames: (B, S_src, d_model) stub frontend embeddings."""
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

        def block(x, p_l):
            h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            a, _, _ = _mha(p_l["attn"], cfg, h, h, causal=False,
                           positions_q=pos, positions_kv=pos, chunk_size=chunk_size)
            x = x + a
            h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            return x + layers.apply_mlp(p_l["ffn"], h2, cfg.mlp_kind, x.dtype)

        if cfg.remat:
            block = jax.checkpoint(block)
        x, _ = jax.lax.scan(lambda c, p_l: (block(c, p_l), None), x, params["enc_blocks"])
        return layers.rms_norm(x, params["ln_enc"], cfg.norm_eps)

    # ------------------------------------------------------------------
    def _decode_stack(self, params, x, enc_out, cache, chunk_size: int = 1024):
        """x: (B,S,d) target activations; enc_out: (B,S_src,d) or None when the
        cross KV comes from the cache."""
        cfg = self.cfg
        has_cache = cache is not None
        cache_len = None if cache is None else cache["len"]
        b, s = x.shape[:2]
        pos_q = jnp.arange(s)[None] + (0 if cache is None else cache_len)
        pos_q = jnp.broadcast_to(pos_q, (b, s))

        def block(x, p_l, c_l):
            h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            a, k_new, v_new = _mha(
                p_l["self_attn"], cfg, h, h, causal=True,
                positions_q=pos_q, positions_kv=pos_q,
                cache_k=None if c_l is None else c_l["k"],
                cache_v=None if c_l is None else c_l["v"],
                cache_len=cache_len, chunk_size=chunk_size,
            )
            x = x + a
            hx = layers.rms_norm(x, p_l["ln_x"], cfg.norm_eps)
            if enc_out is not None:
                # training or prefill: project the cross KV from the encoder
                xa, xk, xv = _mha(p_l["cross_attn"], cfg, hx, enc_out, causal=False,
                                  positions_q=pos_q, positions_kv=None, rope=False,
                                  chunk_size=chunk_size)
            else:
                # cross KV precomputed at prefill; pure attention here
                dtype = x.dtype
                q = (hx @ p_l["cross_attn"]["wq"].astype(dtype)).reshape(
                    b, s, cfg.n_heads, cfg.head_dim
                )
                xo = layers.chunked_attention(q, c_l["xk"], c_l["xv"], causal=False,
                                              chunk_size=chunk_size)
                xa = xo.reshape(b, s, -1) @ p_l["cross_attn"]["wo"].astype(dtype)
                xk, xv = c_l["xk"], c_l["xv"]
            x = x + xa
            h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + layers.apply_mlp(p_l["ffn"], h2, cfg.mlp_kind, x.dtype)
            return x, (k_new, v_new, xk, xv)

        if cfg.remat:
            block = jax.checkpoint(block)

        if has_cache:
            c_stack = {k: cache[k] for k in ("k", "v", "xk", "xv")}

            def body(carry, xs_l):
                p_l, c_l = xs_l
                out, (k_new, v_new, xk, xv) = block(carry, p_l, c_l)
                return out, {"k": k_new, "v": v_new, "xk": xk, "xv": xv}

            x, new_c = jax.lax.scan(body, x, (params["dec_blocks"], c_stack))
            new_cache = dict(cache)
            new_cache.update(new_c)
            return x, new_cache

        def body_nc(carry, p_l):
            out, _ = block(carry, p_l, None)
            return out, None

        x, _ = jax.lax.scan(body_nc, x, params["dec_blocks"])
        return x, None

    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int, src_len: int) -> dict:
        cfg = self.cfg
        dt = cfg.compute_dtype
        l = cfg.n_layers
        kshape = (l, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
        xshape = (l, batch_size, src_len, cfg.n_kv_heads, cfg.head_dim)
        return {
            "len": jnp.zeros((), jnp.int32),
            "k": jnp.zeros(kshape, dt), "v": jnp.zeros(kshape, dt),
            "xk": jnp.zeros(xshape, dt), "xv": jnp.zeros(xshape, dt),
        }

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frontend_embeds"])
        x = params["embed"][batch["tokens"]].astype(cfg.compute_dtype)
        x, _ = self._decode_stack(params, x, enc_out, None)
        x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        x = dist_api.constrain(x, "batch", None, None)
        logits = x @ params["embed"].T.astype(x.dtype)
        logits = dist_api.constrain(logits, "batch", None, "vocab")
        return layers.softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))

    def prefill(self, params, batch, max_len: int):
        """Encodes the source, projects cross KV, and runs the target prompt."""
        cfg = self.cfg
        frames = batch["frontend_embeds"]
        tokens = batch["tokens"]
        b = tokens.shape[0]
        enc_out = self.encode(params, frames)
        cache = self.init_cache(b, max_len, frames.shape[1])
        x = params["embed"][tokens].astype(cfg.compute_dtype)
        x, cache = self._decode_stack(params, x, enc_out, cache)
        x = layers.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        logits = x @ params["embed"].T.astype(x.dtype)
        cache["len"] = cache["len"] + tokens.shape[1]
        return logits, cache

    def decode_step(self, params, cache, tokens, positions=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.compute_dtype)
        x, cache = self._decode_stack(params, x, None, cache)
        x = layers.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        logits = x @ params["embed"].T.astype(x.dtype)
        cache["len"] = cache["len"] + tokens.shape[1]
        return logits, cache

    def forward(self, params, tokens, **kw):  # API parity for tests
        raise NotImplementedError("use loss/prefill/decode_step for enc-dec")
