"""Mixture-of-Experts layer with TPU-friendly sort-scatter dispatch.

Used by llama4-maverick (128 routed top-1 + 1 shared, alternating layers) and
deepseek-v2 (160 routed top-6 + 2 shared, fine-grained d_ff).

Dispatch strategy (static shapes, EP-shardable):
  1. router logits -> top-k expert ids + combine weights per token,
  2. tokens sorted by expert id (stable argsort),
  3. each token is scattered into its expert's capacity-C row buffer
     (slots past C are dropped -- GShard-style capacity),
  4. one batched einsum runs all experts' MLPs: (E, C, d) x (E, d, f),
  5. results gathered back and combined with the routing weights.

The (E, C, d) buffer's expert axis is the EP sharding axis: with experts
split over the ``model`` mesh axis, step 4 is fully local and the scatter /
gather in steps 3/5 lower to an all-to-all -- the canonical MoE pattern.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_unchecked

from repro.distributed import api as dist_api
from repro.models import layers
from repro.models.config import ModelConfig

Params = dict


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": layers.dense_init(ks[0], d, e),
        "routed": {
            "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
            "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
            "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * (1.0 / jnp.sqrt(f)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, cfg.d_ff_expert * cfg.n_shared_experts, cfg.mlp_kind
        )
    return p


def _capacity(n_tokens: int, k: int, n_experts: int, factor: float) -> int:
    cap = int(n_tokens * k * factor / n_experts)
    return max(8, (cap + 7) // 8 * 8)  # pad to a lane-friendly multiple


def apply_moe(p: Params, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss ()).

    aux_loss is the standard load-balancing loss (mean_prob * mean_assignment
    dot, scaled by E) -- returned for the training objective.

    With a registered mesh whose ``model`` axis divides E, dispatch runs on
    the explicit expert-parallel shard_map path (``_apply_moe_ep``): GSPMD
    lowers the scatter-into-expert-buffers of the generic path to
    partial-sum + all-reduce of the FULL (E*C, d) buffer (measured
    57 GB/chip/layer on deepseek-v2 train_4k), whereas the EP path's only
    cross-shard traffic is one (T_local, d) psum over ``model``
    (EXPERIMENTS.md §Perf cell 2).
    """
    mesh = dist_api.get_mesh()
    t_tokens = x.shape[0] * x.shape[1]
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0
            and t_tokens % _data_size(mesh) == 0
            and t_tokens >= 8 * cfg.n_experts):
        # EP shard_map pays off when the token buffers dominate; decode-sized
        # calls (T ~ batch) stay on the generic path where the 2D-TP expert
        # weights remain stationary.
        return _apply_moe_ep(p, x, cfg, mesh)
    dtype = x.dtype
    b, s, d = x.shape
    t = b * s
    k = cfg.n_experts_per_token
    e = cfg.n_experts
    cap = _capacity(t, k, e, cfg.capacity_factor)

    xt = x.reshape(t, d)
    router_logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                        # (T, k)
    # DeepSeek-style renormalized top-k combine weights.
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch/GShard form) ----
    me = jnp.mean(probs, axis=0)                                           # (E,)
    assign_onehot = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(assign_onehot, axis=0)
    aux_loss = e * jnp.sum(me * ce)

    # ---- sort-scatter dispatch ----
    flat_expert = expert_ids.reshape(-1)                                   # (T*k,)
    token_idx = jnp.repeat(jnp.arange(t), k)                               # (T*k,)
    slot_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = token_idx[order]
    sorted_gate = slot_gate[order]
    # position of each sorted slot within its expert group
    group_start = jnp.searchsorted(sorted_expert, jnp.arange(e), side="left")
    pos_in_expert = jnp.arange(t * k) - group_start[sorted_expert]
    keep = pos_in_expert < cap                                             # capacity drop
    dest = sorted_expert * cap + jnp.where(keep, pos_in_expert, 0)

    buf = jnp.zeros((e * cap, d), dtype=dtype)
    # keep the gathered token values sharded along the token dim -- without
    # the constraint GSPMD replicates this (T*k, d) tensor on every chip
    # (measured 128 GB/chip on deepseek-v2 train_4k; EXPERIMENTS.md §Perf)
    gathered = dist_api.constrain(xt[sorted_token], "batch", None)
    gathered = gathered * keep[:, None].astype(dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], gathered, 0.0))
    buf = dist_api.constrain(buf.reshape(e, cap, d), "expert", None, None)

    # ---- expert MLPs: one grouped einsum over the expert axis ----
    w_gate = p["routed"]["w_gate"].astype(dtype)
    w_up = p["routed"]["w_up"].astype(dtype)
    w_down = p["routed"]["w_down"].astype(dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up
    )
    # (tried and refuted: constraining h's hidden dim to the f@data expert
    # weight sharding did not remove the w_down gather on this backend and
    # added a small all-to-all -- §Perf cell 1, iteration 1.4)
    expert_out = jnp.einsum("ecf,efd->ecd", h, w_down)
    expert_out = dist_api.constrain(expert_out, "expert", None, None)
    expert_out = expert_out.reshape(e * cap, d)

    # ---- gather back + combine ----
    slot_out = dist_api.constrain(expert_out[dest], "batch", None)
    slot_out = slot_out * (sorted_gate * keep)[:, None].astype(dtype)
    out = jnp.zeros((t, d), dtype=dtype).at[sorted_token].add(slot_out)
    out = dist_api.constrain(out, "batch", None)

    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], xt, cfg.mlp_kind, dtype)
    return out.reshape(b, s, d), aux_loss


def _data_size(mesh) -> int:
    n = 1
    for ax in mesh.axis_names:
        if ax in ("pod", "data"):
            n *= mesh.shape[ax]
    return n


def _apply_moe_ep(p: Params, x: jax.Array, cfg: ModelConfig, mesh):
    """Expert-parallel dispatch under shard_map.

    Tokens are sharded over (pod, data) and replicated over ``model``; each
    model shard owns E/model_n experts.  Every device locally selects, from
    its resident tokens, the slots routed to ITS experts (local sort-scatter
    with per-(data-shard, expert) capacity), runs its experts, scatters the
    results back to token positions, and a single psum over ``model``
    combines the per-shard sparse outputs -- each token's expert lives on
    exactly one model shard, so the sum is exact.  Cross-device traffic per
    layer: one (T_local, d) all-reduce over model (plus the routing psum for
    the aux loss), replacing the generic path's full-buffer all-reduce.
    """
    dtype = x.dtype
    b, s, d = x.shape
    t = b * s
    k = cfg.n_experts_per_token
    e = cfg.n_experts
    model_n = mesh.shape["model"]
    e_loc = e // model_n
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    data_spec = data_axes if len(data_axes) > 1 else data_axes[0]
    data_n = _data_size(mesh)
    t_loc = t // data_n
    cap = _capacity(t_loc, k, e, cfg.capacity_factor)

    def local_fn(xt, router, w_gate, w_up, w_down):
        # xt (T_loc, d); router (d, E); w_* (E_loc, d|f, f|d)
        probs = (xt @ router.astype(dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(probs, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)

        j = jax.lax.axis_index("model")
        lo = j * e_loc
        flat_e = expert_ids.reshape(-1)
        tok = jnp.repeat(jnp.arange(t_loc), k)
        gates = gate_vals.reshape(-1)
        mine = (flat_e >= lo) & (flat_e < lo + e_loc)
        local_e = jnp.where(mine, flat_e - lo, e_loc)      # e_loc = drop bucket
        order = jnp.argsort(local_e, stable=True)
        se, stok, sg = local_e[order], tok[order], gates[order]
        gstart = jnp.searchsorted(se, jnp.arange(e_loc + 1), side="left")
        pos = jnp.arange(t_loc * k) - gstart[jnp.minimum(se, e_loc)]
        keep = (se < e_loc) & (pos < cap)
        dest = jnp.where(keep, se * cap + pos, e_loc * cap)  # trash slot at end

        buf = jnp.zeros((e_loc * cap + 1, d), dtype=dtype)
        vals = xt[stok] * keep[:, None].astype(dtype)
        buf = buf.at[dest].add(vals)
        buf = buf[: e_loc * cap].reshape(e_loc, cap, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dtype))
        eo = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dtype))
        eo = jnp.concatenate([eo.reshape(e_loc * cap, d),
                              jnp.zeros((1, d), dtype)], axis=0)
        slot_out = eo[dest] * (sg * keep.astype(jnp.float32)).astype(dtype)[:, None]
        out = jnp.zeros((t_loc, d), dtype=dtype).at[stok].add(slot_out)
        out = jax.lax.psum(out, "model")
        return out, aux

    fn = shard_map_unchecked(
        local_fn,
        mesh=mesh,
        in_specs=(P(data_spec, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(data_spec, None), P()),
    )
    out, aux = fn(x.reshape(t, d), p["router"],
                  p["routed"]["w_gate"], p["routed"]["w_up"],
                  p["routed"]["w_down"])
    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], x.reshape(t, d), cfg.mlp_kind, dtype)
    return out.reshape(b, s, d), aux


def apply_moe_dense_ref(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Oracle: run every expert densely and combine by routing weights.
    O(E) compute -- tests only."""
    dtype = x.dtype
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.n_experts_per_token)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    weights = jnp.zeros_like(probs)
    weights = jnp.put_along_axis(weights, expert_ids, gate_vals, axis=-1, inplace=False)

    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["routed"]["w_gate"].astype(dtype)))
    h = h * jnp.einsum("td,edf->tef", xt, p["routed"]["w_up"].astype(dtype))
    y = jnp.einsum("tef,efd->ted", h, p["routed"]["w_down"].astype(dtype))
    out = jnp.einsum("ted,te->td", y, weights.astype(dtype))
    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], xt, cfg.mlp_kind, dtype)
    return out.reshape(b, s, d)
