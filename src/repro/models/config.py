"""Unified architecture configuration covering the 10 assigned architectures.

One dataclass parameterizes dense / MoE / MLA / SSM / hybrid / enc-dec
families; ``family`` selects the block wiring, the rest are hyperparameters.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "mla_moe", "ssm", "hybrid", "encdec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavour ---
    attn_pattern: str = "full"        # full | sliding | local_global
    sliding_window: int = 0
    global_every: int = 0             # local_global: 1 global per this many layers
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()   # Qwen2-VL M-RoPE half-dim sections
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0
    parallel_block: bool = False      # Command-R style parallel attn+FFN

    # --- MLP flavour ---
    mlp_kind: str = "swiglu"          # swiglu | geglu | gelu

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1                # MoE layer period (2 = alternate dense/MoE)
    n_dense_leading: int = 0          # DeepSeek: first k layers stay dense
    capacity_factor: float = 1.25

    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / recurrent ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0              # xLSTM: 1 sLSTM block per this many blocks

    # --- hybrid (Hymba) ---
    n_ssm_heads: int = 0

    # --- enc-dec (Seamless) ---
    n_encoder_layers: int = 0

    # --- modality frontends (stub) ---
    frontend: str = "none"            # none | vision | audio

    # --- numerics / execution ---
    norm_eps: float = 1e-6
    post_norm: bool = False           # gemma3 sandwich norms
    embed_scale: bool = False         # gemma: embeddings scaled by sqrt(d)
    kv_cache_dtype: str = "compute"   # "compute" | "int8" (per-token/head scales)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: bool = True
    use_pallas: bool = False          # Pallas kernels (TPU target; CPU uses refs)
    # fraction of mean-capacity tokens each expert can take before dropping

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def is_global_layer(self, i: int) -> bool:
        if self.attn_pattern == "full":
            return True
        if self.attn_pattern == "sliding":
            return False
        # local_global: every ``global_every``-th layer is global (gemma3: 6th)
        return (i % self.global_every) == (self.global_every - 1)

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.n_dense_leading:
            return False
        return ((i - self.n_dense_leading) % self.moe_every) == (self.moe_every - 1)

    # ------------------------------------------------------------------
    # Parameter counting (exact, from the init functions' shapes).
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        from repro.models import registry  # lazy: avoid cycle
        import jax
        import math

        model = registry.build_model(self)
        shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
        # python-int product: jnp.prod would overflow int32 at >2B params
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed experts count k/E)."""
        from repro.models import registry
        import jax

        model = registry.build_model(self)
        shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
        total = 0
        k_frac = self.n_experts_per_token / max(self.n_experts, 1)

        def add(path, x):
            nonlocal total
            n = 1
            for s in x.shape:
                n *= int(s)
            path_str = jax.tree_util.keystr(path)
            if "routed" in path_str:
                n = int(n * k_frac)
            total += n

        jax.tree_util.tree_map_with_path(add, shapes)
        return total


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (used by per-arch tests)."""
    small = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_experts_per_token=min(cfg.n_experts_per_token, 2),
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        q_lora_rank=48 if cfg.q_lora_rank else 0,
        rope_head_dim=16 if cfg.rope_head_dim else 0,
        v_head_dim=32 if cfg.v_head_dim else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        n_ssm_heads=2 if cfg.n_ssm_heads else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        mrope_sections=(8, 4, 4) if cfg.mrope_sections else (),
        # avoid capacity drops at smoke-test token counts so cached decode
        # matches the uncached oracle exactly
        capacity_factor=4.0 if cfg.n_experts else cfg.capacity_factor,
        dtype="float32",
        remat=False,
    )
    if cfg.global_every:
        small["global_every"] = min(cfg.global_every, 2)
    if cfg.slstm_every:
        small["slstm_every"] = min(cfg.slstm_every, 2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
