"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries go through a low-rank bottleneck (q_lora_rank); keys/values are
compressed into a single latent c_kv of dim kv_lora_rank plus one shared
RoPE key head of dim rope_head_dim.  The decode cache stores only
(c_kv, k_rope) -- ~(512+64) floats/token instead of 2*H*Dh -- which is the
architecture's point: O(9x) smaller KV cache at 128 heads.

Per-head dims: qk = head_dim (nope part) + rope_head_dim; v = v_head_dim.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed import api as dist_api
from repro.models import layers
from repro.models.config import ModelConfig

Params = dict


def init_mla(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    qk_nope, qk_rope, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": layers.dense_init(ks[0], d, cfg.q_lora_rank),
        "q_a_norm": jnp.zeros((cfg.q_lora_rank,), jnp.float32),
        "wq_b": layers.dense_init(ks[1], cfg.q_lora_rank, h * (qk_nope + qk_rope)),
        "wkv_a": layers.dense_init(ks[2], d, cfg.kv_lora_rank + qk_rope),
        "kv_a_norm": jnp.zeros((cfg.kv_lora_rank,), jnp.float32),
        "wkv_b": layers.dense_init(ks[3], cfg.kv_lora_rank, h * (qk_nope + dv)),
        "wo": layers.dense_init(ks[4], h * dv, d),
    }


def _project_q(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    b, s, _ = x.shape
    h, qk_nope, qk_rope = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    dtype = x.dtype
    q = layers.rms_norm(x @ p["wq_a"].astype(dtype), p["q_a_norm"])
    q = (q @ p["wq_b"].astype(dtype)).reshape(b, s, h, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _compress_kv(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """x -> (c_kv (B,S,R), k_rope (B,S,1,Dr)) -- exactly what the cache stores."""
    dtype = x.dtype
    kv = x @ p["wkv_a"].astype(dtype)
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = layers.rms_norm(c_kv, p["kv_a_norm"])
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope


def _expand_kv(p: Params, c_kv: jax.Array, cfg: ModelConfig):
    """latent (B,S,R) -> k_nope (B,S,H,Dn), v (B,S,H,Dv) via the up-projection."""
    b, s, _ = c_kv.shape
    h, qk_nope, dv = cfg.n_heads, cfg.head_dim, cfg.v_head_dim
    kv = (c_kv @ p["wkv_b"].astype(c_kv.dtype)).reshape(b, s, h, qk_nope + dv)
    return kv[..., :qk_nope], kv[..., qk_nope:]


def apply_mla(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache_ckv: jax.Array | None = None,   # (B, Smax, R)
    cache_krope: jax.Array | None = None,  # (B, Smax, Dr)
    cache_len: jax.Array | None = None,
    chunk_size: int = 1024,
):
    """Returns (out, new_cache_ckv, new_cache_krope).

    Without a cache: training/prefill over the full sequence.
    With a cache: the current x tokens are appended at cache_len and attention
    runs against the whole (compressed) cache, decompressing k/v on the fly --
    the MLA trade of extra up-projection FLOPs for tiny KV storage.
    """
    b, s, _ = x.shape
    dtype = x.dtype
    h, qk_nope, qk_rope, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q = _project_q(p, x, cfg, positions)
    c_kv_new, k_rope_new = _compress_kv(p, x, cfg, positions)

    if cache_ckv is None:
        c_kv_all, k_rope_all = c_kv_new, k_rope_new
        kv_valid, q_offset = None, 0
        new_ckv = new_krope = None
    else:
        new_ckv = jax.lax.dynamic_update_slice_in_dim(cache_ckv, c_kv_new, cache_len, axis=1)
        new_krope = jax.lax.dynamic_update_slice_in_dim(
            cache_krope, k_rope_new[:, :, 0, :], cache_len, axis=1
        )
        c_kv_all, k_rope_all = new_ckv, new_krope[:, :, None, :]
        kv_valid, q_offset = cache_len + s, cache_len

    if cache_ckv is not None and s <= 4:
        # ---- absorbed decode path (the DeepSeek-V2 inference optimization) --
        # Instead of decompressing the whole cache to per-head k/v
        # (2*B*S*R*H*(Dn+Dv) FLOPs per step -- measured 110x the useful work
        # at 32k context; EXPERIMENTS.md §Perf cell 1), fold wkv_b into the
        # query/output sides and attend directly in the latent space:
        #   q_nope^T k_nope = (q_nope W_UK)^T c_kv     (absorb into q)
        #   out = (probs @ c_kv) W_UV                  (absorb into o)
        w_kv = p["wkv_b"].astype(dtype).reshape(cfg.kv_lora_rank, h, qk_nope + dv)
        w_uk, w_uv = w_kv[..., :qk_nope], w_kv[..., qk_nope:]
        q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)       # (B,s,H,R)
        scale = 1.0 / math.sqrt(qk_nope + qk_rope)
        # The dots accumulate in f32; the CPU backend emulates bf16 dots by
        # upconverting operands, and GSPMD then model-shards that convert and
        # all-gathers it back (2 x 0.54 GB/chip/layer measured).  Pinning the
        # converted cache to its (batch@data, replicated) layout removes the
        # gather on both backends (§Perf cell 1, iteration 1.3).
        c_kv_att = dist_api.constrain(
            c_kv_all.astype(jnp.float32), "batch", None, None)
        k_rope_att = dist_api.constrain(
            new_krope.astype(jnp.float32), "batch", None, None)
        logits = (
            jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), c_kv_att)
            + jnp.einsum("bshn,btn->bhst", q_rope.astype(jnp.float32), k_rope_att)
        ) * scale
        mask = layers.make_attention_mask(
            s, c_kv_all.shape[1], q_offset=q_offset, causal=True,
            kv_valid_len=kv_valid)
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv_att).astype(dtype)
        out = jnp.einsum("bshr,rhn->bshn", out_lat, w_uv)        # (B,s,H,Dv)
        out = out.reshape(b, s, h * dv) @ p["wo"].astype(dtype)
        return out, new_ckv, new_krope

    k_nope, v = _expand_kv(p, c_kv_all, cfg)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all, (*k_nope.shape[:3], qk_rope))], axis=-1
    )
    out = layers.chunked_attention(
        q, k, v,
        causal=True, q_offset=q_offset, kv_valid_len=kv_valid,
        scale=1.0 / math.sqrt(qk_nope + qk_rope), chunk_size=chunk_size,
    )
    out = out.reshape(b, s, h * dv) @ p["wo"].astype(dtype)
    return out, new_ckv, new_krope
