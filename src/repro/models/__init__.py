"""Architecture zoo: dense / MoE / MLA / SSM / hybrid / enc-dec model families
with scan-over-layers stacks, KV/state caches, and dry-run input specs."""
from repro.models.config import ModelConfig, reduced  # noqa: F401
from repro.models.registry import (  # noqa: F401
    SHAPES,
    build_model,
    input_specs,
    param_specs,
    supports,
)
