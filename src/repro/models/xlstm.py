"""xLSTM language model (arXiv:2405.04517): a stack of mLSTM blocks
(matrix-memory, chunkwise-parallel) with one sLSTM block (scalar-memory,
sequential) every ``slstm_every`` blocks -- the paper's a:b block ratio.

Block wiring follows the paper:
  * mLSTM block: pre-norm -> up-projection x2 (value + gate lanes) -> short
    causal conv on the value lane -> mLSTM -> silu-gate -> down-projection.
  * sLSTM block: pre-norm -> sLSTM (head-blocked recurrence) -> residual,
    then a GeGLU FFN sub-block at projection factor 4/3.

Blocks are grouped into super-blocks of (slstm_every-1) mLSTM + 1 sLSTM and
scanned: outer scan over super-blocks, inner scan over the mLSTM run, so the
HLO holds exactly one mLSTM body and one sLSTM body.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import api as dist_api
from repro.models import layers, ssm
from repro.models.config import ModelConfig

Params = dict[str, Any]


def _ffn_dim(d: int) -> int:
    return ((4 * d // 3) + 63) // 64 * 64


def init_mlstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "w_up": layers.dense_init(ks[0], d, 2 * d_inner),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, d_inner), jnp.float32) * 0.2,
        "cell": ssm.init_mlstm(ks[2], cfg, d_inner),
        "w_down": layers.dense_init(ks[3], d_inner, d),
    }


def apply_mlstm_block(p: Params, cfg: ModelConfig, x, state=None):
    """state = (conv_state, C, n, m) or None (training)."""
    dtype = x.dtype
    d_inner = cfg.ssm_expand * cfg.d_model
    h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    up = h @ p["w_up"].astype(dtype)
    a, g = up[..., :d_inner], up[..., d_inner:]
    conv_state = None if state is None else state[0]
    a, conv_state_new = ssm.causal_depthwise_conv(a, p["conv_w"], conv_state)
    a = jax.nn.silu(a)
    cell_state = None if state is None else state[1:]
    y, cell_state_new = ssm.apply_mlstm(p["cell"], a, cfg, d_inner, cell_state)
    y = y * jax.nn.silu(g)
    out = x + y @ p["w_down"].astype(dtype)
    if state is None:
        return out, None
    return out, (conv_state_new, *cell_state_new)


def init_slstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "cell": ssm.init_slstm(ks[0], cfg, d),
        "ln_ffn": jnp.zeros((d,), jnp.float32),
        "ffn": layers.init_mlp(ks[1], d, _ffn_dim(d), "geglu"),
    }


def apply_slstm_block(p: Params, cfg: ModelConfig, x, state=None):
    dtype = x.dtype
    h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    y, state_new = ssm.apply_slstm(p["cell"], h, cfg, cfg.d_model, state)
    x = x + y
    h2 = layers.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    x = x + layers.apply_mlp(p["ffn"], h2, "geglu", dtype)
    return x, state_new


@dataclasses.dataclass(frozen=True)
class XLSTMLM:
    cfg: ModelConfig

    @property
    def _layout(self) -> tuple[int, int]:
        """(n_super_blocks, mlstm_per_super)."""
        cfg = self.cfg
        if cfg.slstm_every <= 0:
            return 1, cfg.n_layers
        assert cfg.n_layers % cfg.slstm_every == 0
        return cfg.n_layers // cfg.slstm_every, cfg.slstm_every - 1

    def init(self, key) -> Params:
        cfg = self.cfg
        n_super, n_m = self._layout
        k_embed, k_m, k_s, k_head = jax.random.split(key, 4)
        p: Params = {
            "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        m_keys = jax.random.split(k_m, n_super * n_m).reshape(n_super, n_m)
        p["m_blocks"] = jax.vmap(jax.vmap(lambda k: init_mlstm_block(k, cfg)))(m_keys)
        if cfg.slstm_every > 0:
            p["s_blocks"] = jax.vmap(lambda k: init_slstm_block(k, cfg))(
                jax.random.split(k_s, n_super)
            )
        if not cfg.tie_embeddings:
            p["unembed"] = layers.dense_init(k_head, cfg.d_model, cfg.vocab_size)
        return p

    def init_cache(self, batch_size: int, max_len: int) -> dict:
        cfg = self.cfg
        n_super, n_m = self._layout
        d_inner = cfg.ssm_expand * cfg.d_model
        h = cfg.n_heads
        dh_m = d_inner // h
        dh_s = cfg.d_model // h
        dt = cfg.compute_dtype
        cache = {
            "len": jnp.zeros((), jnp.int32),
            "m_conv": jnp.zeros((n_super, n_m, batch_size, cfg.ssm_conv - 1, d_inner), dt),
            "m_C": jnp.zeros((n_super, n_m, batch_size, h, dh_m, dh_m), jnp.float32),
            "m_n": jnp.zeros((n_super, n_m, batch_size, h, dh_m), jnp.float32),
            "m_m": jnp.full((n_super, n_m, batch_size, h), -1e30, jnp.float32),
        }
        if cfg.slstm_every > 0:
            z = jnp.zeros((n_super, batch_size, h, dh_s), jnp.float32)
            cache.update(
                s_c=z, s_n=z, s_h=z.astype(dt),
                s_m=jnp.full((n_super, batch_size, h, dh_s), -1e30, jnp.float32),
            )
        return cache

    def _stack_forward(self, params, x, cache):
        cfg = self.cfg
        n_super, n_m = self._layout
        has_cache = cache is not None

        def m_block(x, p_l, st):
            return apply_mlstm_block(p_l, cfg, x, st)

        def s_block(x, p_l, st):
            return apply_slstm_block(p_l, cfg, x, st)

        if cfg.remat:
            m_block = jax.checkpoint(m_block)
            s_block = jax.checkpoint(s_block)

        def inner(x, m_params, m_cache):
            def body(carry, xs_l):
                if has_cache:
                    p_l, (conv, C, n, m) = xs_l
                    out, st = m_block(carry, p_l, (conv, C, n, m))
                    return out, st
                p_l = xs_l
                out, _ = m_block(carry, p_l, None)
                return out, None

            xs = (m_params, m_cache) if has_cache else m_params
            return jax.lax.scan(body, x, xs)

        def outer_body(carry, xs_s):
            x = carry
            if has_cache:
                mp, sp, mc, sc = xs_s
                x, m_states = inner(x, mp, mc)
                x, s_state = s_block(x, sp, sc)
                return x, (m_states, s_state)
            if cfg.slstm_every > 0:
                mp, sp = xs_s
                x, _ = inner(x, mp, None)
                x, _ = s_block(x, sp, None)
            else:
                (mp,) = xs_s
                x, _ = inner(x, mp, None)
            return x, None

        if has_cache:
            m_cache = (cache["m_conv"], cache["m_C"], cache["m_n"], cache["m_m"])
            s_cache = (cache["s_c"], cache["s_n"], cache["s_h"], cache["s_m"])
            x, (m_states, s_state) = jax.lax.scan(
                outer_body, x, (params["m_blocks"], params["s_blocks"], m_cache, s_cache)
            )
            new_cache = dict(cache)
            new_cache.update(
                m_conv=m_states[0], m_C=m_states[1], m_n=m_states[2], m_m=m_states[3],
                s_c=s_state[0], s_n=s_state[1], s_h=s_state[2], s_m=s_state[3],
            )
            return x, new_cache
        xs = (params["m_blocks"], params["s_blocks"]) if cfg.slstm_every > 0 else (params["m_blocks"],)
        x, _ = jax.lax.scan(outer_body, x, xs)
        return x, None

    def forward(self, params, tokens, cache=None, logits_mode="all", **_):
        cfg = self.cfg
        dt = cfg.compute_dtype
        x = params["embed"][tokens].astype(dt)
        x, new_cache = self._stack_forward(params, x, cache)
        x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if logits_mode == "last":
            x = x[:, -1:]
        x = dist_api.constrain(x, "batch", None, None)
        table = params.get("unembed")
        logits = x @ (params["embed"].T.astype(dt) if table is None else table.astype(dt))
        logits = dist_api.constrain(logits, "batch", None, "vocab")
        if new_cache is not None:
            new_cache["len"] = cache["len"] + tokens.shape[1]
        return logits, new_cache, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _, _ = self.forward(params, batch["tokens"])
        return layers.softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))

    def prefill(self, params, batch, max_len: int):
        cache = self.init_cache(batch["tokens"].shape[0], max_len)
        logits, cache, _ = self.forward(params, batch["tokens"], cache, logits_mode="last")
        return logits, cache

    def decode_step(self, params, cache, tokens, positions=None):
        logits, cache, _ = self.forward(params, tokens, cache, logits_mode="last")
        return logits, cache
