"""Shared neural-network layers for the architecture zoo.

Pure-JAX (no flax): parameters are nested dicts of arrays, initialized by
``init_*`` functions and consumed by the matching ``apply`` functions.  All
layers take an explicit compute ``dtype`` (params are stored in fp32 and cast
at use -- standard mixed precision).

Conventions:
  * activations: (batch, seq, d_model)
  * attention heads: q (B, S, Hq, Dh); k/v (B, S, Hkv, Dh) with Hq % Hkv == 0
  * weights: (in_features, out_features) so forward is x @ w
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: float | None = None) -> jax.Array:
    s = (1.0 / math.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * s)


def embed_init(key, vocab: int, d_model: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d_model), dtype=jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# Normalization.
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + multimodal M-RoPE).
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> (cos, sin) of shape (..., dim//2), fp32."""
    half = dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S).  Rotates the full head dim."""
    b, s, h, d = x.shape
    cos, sin = _rope_angles(positions, d, theta)      # (B, S, D/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_partial_rope(x: jax.Array, positions: jax.Array, rope_dim: int,
                       theta: float = 10_000.0) -> jax.Array:
    """Rotate only the first ``rope_dim`` features of the head (DeepSeek MLA)."""
    rot, keep = x[..., :rope_dim], x[..., rope_dim:]
    return jnp.concatenate([apply_rope(rot, positions, theta), keep], axis=-1)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 10_000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): ``positions`` is (3, B, S) carrying
    (temporal, height, width) indices; the head dim's frequency bands are
    partitioned into ``sections`` (in half-dim units, sum = D/2), each band
    rotated by its own position stream."""
    b, s, h, d = x.shape
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # pick, per frequency band, which of the 3 position streams drives it
    stream_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )                                                   # (half,)
    pos = positions.astype(jnp.float32)                 # (3, B, S)
    pos_sel = pos[stream_id]                            # (half, B, S)
    ang = jnp.transpose(pos_sel, (1, 2, 0)) * freq      # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention.
# ---------------------------------------------------------------------------

def make_attention_mask(
    q_len: int,
    kv_len: int,
    q_offset: jax.Array | int = 0,
    causal: bool = True,
    window: int = 0,
    kv_valid_len: jax.Array | None = None,
    window_active: jax.Array | None = None,
) -> jax.Array:
    """(q_len, kv_len) bool mask.  ``q_offset`` is the absolute position of the
    first query (decode: q_offset = cache length).  ``window`` > 0 restricts to
    a sliding window of that many past positions.  ``kv_valid_len`` masks the
    unwritten tail of a KV cache.  ``window_active`` (traced bool scalar)
    toggles the window per layer inside a scan over mixed local/global layers
    (None = window unconditionally applied when window > 0)."""
    q_pos = jnp.arange(q_len) + q_offset          # absolute query positions
    kv_pos = jnp.arange(kv_len)
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window > 0:
        in_window = q_pos[:, None] - kv_pos[None, :] < window
        if window_active is None:
            mask &= in_window
        else:
            mask &= jnp.logical_or(jnp.logical_not(window_active), in_window)
    if kv_valid_len is not None:
        mask &= kv_pos[None, :] < kv_valid_len
    return mask


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array | None,
    *,
    scale: float | None = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Grouped-query attention.  q (B,Sq,Hq,D), k/v (B,Skv,Hkv,D) -> (B,Sq,Hq,D).

    Softmax runs in fp32 regardless of input dtype.
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = (1.0 / math.sqrt(d)) if scale is None else scale
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * s
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    if mask is not None:
        logits = jnp.where(mask[None, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, dv)


def init_attention(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    qkv_bias: bool = False,
    qk_norm: bool = False,
) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((head_dim,), jnp.float32)
    return p


def project_qkv(p: Params, x: jax.Array, n_heads: int, n_kv_heads: int, head_dim: int,
                dtype) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_valid_len: jax.Array | None = None,
    window_active: jax.Array | None = None,
    scale: float | None = None,
    logit_softcap: float = 0.0,
    chunk_size: int = 1024,
) -> jax.Array:
    """Query-chunked attention: O(chunk * S_kv) score memory instead of
    O(S_q * S_kv).  Masks are built inline from iota comparisons (never
    materialized as model inputs).  This is also the pure-jnp oracle for the
    Pallas flash-attention kernel."""
    b, sq, hq, d = q.shape
    if sq <= chunk_size:
        mask = make_attention_mask(sq, k.shape[1], q_offset, causal, window,
                                   kv_valid_len, window_active)
        return attention(q, k, v, mask, scale=scale, logit_softcap=logit_softcap)
    assert sq % chunk_size == 0, (sq, chunk_size)
    n_chunks = sq // chunk_size
    qs = q.reshape(b, n_chunks, chunk_size, hq, d).transpose(1, 0, 2, 3, 4)

    def one_chunk(i, q_chunk):
        off = q_offset + i * chunk_size
        mask = make_attention_mask(chunk_size, k.shape[1], off, causal, window,
                                   kv_valid_len, window_active)
        return attention(q_chunk, k, v, mask, scale=scale, logit_softcap=logit_softcap)

    # remat each chunk: otherwise the backward saves every chunk's (BQ, Skv)
    # score matrix simultaneously, re-materializing the full S^2 attention
    # the chunking was meant to avoid (measured 8.6 GB/layer/chip on
    # deepseek-v2 train_4k -- EXPERIMENTS.md §Perf)
    one_chunk = jax.checkpoint(one_chunk)
    out = jax.lax.map(lambda args: one_chunk(*args), (jnp.arange(n_chunks), qs))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, v.shape[-1])


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff),
            "w_up": dense_init(ks[1], d_model, d_ff),
            "w_down": dense_init(ks[2], d_ff, d_model),
        }
    return {  # plain gelu MLP
        "w_up": dense_init(ks[0], d_model, d_ff),
        "w_down": dense_init(ks[1], d_ff, d_model),
    }


def apply_mlp(p: Params, x: jax.Array, kind: str, dtype) -> jax.Array:
    if kind == "swiglu":
        act = jax.nn.silu(x @ p["w_gate"].astype(dtype))
        return (act * (x @ p["w_up"].astype(dtype))) @ p["w_down"].astype(dtype)
    if kind == "geglu":
        act = jax.nn.gelu(x @ p["w_gate"].astype(dtype), approximate=True)
        return (act * (x @ p["w_up"].astype(dtype))) @ p["w_down"].astype(dtype)
    if kind == "gelu":
        return jax.nn.gelu(x @ p["w_up"].astype(dtype), approximate=True) @ p["w_down"].astype(dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token loss.  logits (B,S,V) any float dtype; labels (B,S) int.

    The gold logit is extracted with a one-hot dot (not take_along_axis):
    under a vocab-sharded ``model`` axis the one-hot compare stays local and
    reduces with a tiny psum, whereas a gather on the sharded dim forces XLA
    to all-gather the full logits (measured: ~140 GB/step on gemma-2b
    train_4k before this change -- see EXPERIMENTS.md §Perf)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = (labels[..., None] == jnp.arange(vocab)[None, None, :])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
