"""Recurrent sequence-mixing layers: selective SSM (Mamba-style, used by the
Hymba hybrid heads) and the xLSTM cells (mLSTM matrix memory; sLSTM scalar
memory with exponential gating), each with a parallel training form and an
O(1)-state decode step.

Training forms:
  * selective SSM  -- associative scan over the diagonal recurrence
                      h_t = a_t * h_{t-1} + b_t  (a_t = exp(dt*A)).
  * mLSTM          -- quadratic "attention-like" form with log-gate cumsums
                      and running-max stabilization (xLSTM paper eq. 19-27);
                      this is the pure-jnp oracle of the chunked Pallas kernel.
  * sLSTM          -- inherently sequential lax.scan (used 1-in-N blocks).

Decode steps carry (conv_state, ssm_state) / (C, n, m) / (c, n, h, m).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

Params = dict


# ---------------------------------------------------------------------------
# Depthwise causal conv (Mamba front conv).
# ---------------------------------------------------------------------------

def causal_depthwise_conv(x: jax.Array, w: jax.Array,
                          state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """x (B,S,D), w (K,D) -> (y (B,S,D), new_state (B,K-1,D)).

    ``state`` holds the trailing K-1 inputs of the previous segment (decode)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k))
    return y, xp[:, -(k - 1) :, :]


# ---------------------------------------------------------------------------
# Selective SSM (Mamba-style) head block.
# ---------------------------------------------------------------------------

def init_ssm(key, cfg: ModelConfig, d_inner: int) -> Params:
    d, n = cfg.d_model, cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": layers.dense_init(ks[0], d, d_inner),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, d_inner), jnp.float32) * 0.2,
        "w_bc": layers.dense_init(ks[2], d_inner, 2 * n),
        "w_dt": layers.dense_init(ks[3], d_inner, d_inner, scale=0.01),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            ks[4], (d_inner,), minval=math.log(1e-3), maxval=math.log(1e-1))))),
        "a_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, :]
        * jnp.ones((d_inner, 1), jnp.float32),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "w_out": layers.dense_init(ks[5], d_inner, d_inner),
    }


def _ssm_scan(a: jax.Array, bx: jax.Array, h0: jax.Array | None = None):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + bx_t along axis 1.
    a, bx: (B, S, D, N).  Associative scan (parallel-prefix, O(log S) depth)."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def apply_ssm(p: Params, x: jax.Array, cfg: ModelConfig,
              conv_state: jax.Array | None = None,
              ssm_state: jax.Array | None = None):
    """x (B,S,d_model-projected? no: d_model) -> (y (B,S,d_inner), states).

    Training: conv_state/ssm_state None -> zero init, returns final states.
    Decode:   pass both states (S may be 1)."""
    dtype = x.dtype
    n = cfg.ssm_state
    xz = x @ p["w_in"].astype(dtype)                       # (B,S,Di)
    xc, conv_state_new = causal_depthwise_conv(xz, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    bc = xc @ p["w_bc"].astype(dtype)                      # (B,S,2N)
    b_in, c_out = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(xc @ p["w_dt"].astype(dtype) + p["dt_bias"].astype(dtype))
    a = -jnp.exp(p["a_log"]).astype(jnp.float32)           # (Di,N), negative
    # discretize: a_bar = exp(dt*A); b_bar x = dt * B * x
    a_bar = jnp.exp(dt.astype(jnp.float32)[..., None] * a)            # (B,S,Di,N)
    bx = (dt * xc).astype(jnp.float32)[..., None] * b_in.astype(jnp.float32)[..., None, :]
    h = _ssm_scan(a_bar, bx, ssm_state)                    # (B,S,Di,N)
    y = jnp.einsum("bsdn,bsn->bsd", h.astype(dtype), c_out)
    y = y + xc * p["d_skip"].astype(dtype)
    y = y * jax.nn.silu(xz)                                # gated output
    y = y @ p["w_out"].astype(dtype)
    return y, conv_state_new, h[:, -1].astype(jnp.float32)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell).
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, d_inner: int) -> Params:
    h = cfg.n_heads
    dh = d_inner // h
    ks = jax.random.split(key, 6)
    return {
        # block-diagonal per-head qkv (the official xLSTM layout): (H, Dh, 3Dh)
        # -- a dense (d_inner, 3 d_inner) matrix would be h x larger and is
        # not what the 1.3B config's parameter budget implies
        "w_qkv": jax.random.normal(ks[0], (h, dh, 3 * dh), jnp.float32)
        / jnp.sqrt(dh),
        "w_if": layers.dense_init(ks[1], d_inner, 2 * h, scale=0.01),
        "if_bias": jnp.concatenate(
            [jnp.zeros((h,), jnp.float32), 3.0 * jnp.ones((h,), jnp.float32)]
        ),
        "o_norm": jnp.zeros((dh,), jnp.float32),
    }


def mlstm_parallel(q, k, v, i_gate, f_gate):
    """Stabilized parallel mLSTM (the pure-jnp oracle for the Pallas kernel).

    q,k,v: (B,H,S,Dh); i_gate,f_gate: (B,H,S) pre-activations.
    Returns (B,H,S,Dh).

    log f cumulative sums give the decay matrix
        D_ij = exp(F_i - F_j + i_j - m_i),  F_t = sum_{u<=t} log sig(f_u),
    masked to j <= i; m_i is the row max for stability; the output is
        y = (S ⊙ D) V / max(|row-sum|, exp(-m_i)) with S = QK^T/sqrt(d).
    """
    b, h, s, dh = q.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))          # (B,H,S)
    fcum = jnp.cumsum(logf, axis=-1)
    dmat = fcum[..., :, None] - fcum[..., None, :] + i_gate.astype(jnp.float32)[..., None, :]
    mask = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(mask, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1, keepdims=True)                      # (B,H,S,1)
    m = jnp.maximum(m, -1e30)                                      # guard all -inf
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) / math.sqrt(dh)
    w = scores * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=-1, keepdims=True)), jnp.exp(-m))
    return (jnp.einsum("bhst,bhtd->bhsd", (w / norm).astype(v.dtype), v),
            fcum, m[..., 0])


def mlstm_chunkwise(q, k, v, i_gate, f_gate, state=None, chunk: int = 256):
    """Chunkwise-parallel mLSTM: O(S/L) sequential steps, O(L^2) intra-chunk
    parallel work, exact (up to fp) match with the fully-parallel form.

    q,k,v (B,H,S,Dh); gates (B,H,S).  Returns (y, (C,n,m) final state).
    This is the algorithm the Pallas kernel implements; the jnp version here
    doubles as its oracle at chunk granularity.
    """
    b, h, s, dh = q.shape
    if state is None:
        state = (
            jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    resh = lambda x: x.reshape(b, h, n_chunks, chunk, *x.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)
    # chunk-major: (n_chunks, B, H, L, ...)
    qs, ks, vs = resh(q), resh(k), resh(v)
    is_, fs = resh(i_gate), resh(f_gate)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, xs):
        C, n, m = carry                                     # (B,H,Dh,Dh),(B,H,Dh),(B,H)
        qc, kc, vc, ic, fc = xs                             # (B,H,L,...)
        logf = jax.nn.log_sigmoid(fc.astype(jnp.float32))   # (B,H,L)
        bcum = jnp.cumsum(logf, axis=-1)                    # b_t
        icast = ic.astype(jnp.float32)
        # stabilizer per token: max(inter, intra)
        intra_arg = bcum[..., :, None] - bcum[..., None, :] + icast[..., None, :]
        intra_arg = jnp.where(tri, intra_arg, -jnp.inf)
        m_intra = jnp.max(intra_arg, axis=-1)               # (B,H,L)
        m_inter = bcum + m[..., None]
        m_t = jnp.maximum(jnp.maximum(m_inter, m_intra), -1e30)
        # inter-chunk contribution
        qf = qc.astype(jnp.float32) / math.sqrt(dh)
        g_inter = jnp.exp(m_inter - m_t)                    # (B,H,L)
        y_inter = jnp.einsum("bhld,bhde->bhle", qf, C) * g_inter[..., None]
        n_inter = jnp.einsum("bhld,bhd->bhl", qf, n) * g_inter
        # intra-chunk contribution
        dexp = jnp.exp(intra_arg - m_t[..., None])          # (B,H,L,L)
        scores = jnp.einsum("bhld,bhtd->bhlt", qf, kc.astype(jnp.float32))
        w = scores * dexp
        y_intra = jnp.einsum("bhlt,bhtd->bhld", w, vc.astype(jnp.float32))
        n_intra = jnp.sum(w, axis=-1)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))[..., None]
        y = ((y_inter + y_intra) / denom).astype(vc.dtype)
        # state update to end of chunk
        b_last = bcum[..., -1]
        m_new = jnp.maximum(b_last + m, jnp.max(b_last[..., None] - bcum + icast, axis=-1))
        scale_old = jnp.exp(b_last + m - m_new)[..., None, None]
        kv_w = jnp.exp(b_last[..., None] - bcum + icast - m_new[..., None])  # (B,H,L)
        C_new = scale_old * C + jnp.einsum(
            "bhl,bhld,bhle->bhde", kv_w, kc.astype(jnp.float32), vc.astype(jnp.float32)
        )
        n_new = scale_old[..., 0] * n + jnp.einsum("bhl,bhld->bhd", kv_w, kc.astype(jnp.float32))
        return (C_new, n_new, m_new), y

    state, ys = jax.lax.scan(step, state, (qs, ks, vs, is_, fs))
    y = ys.swapaxes(1, 2).swapaxes(0, 2).reshape(b, h, s, dh)
    return y, state


def mlstm_step(q, k, v, i_gate, f_gate, C, n, m):
    """One recurrent mLSTM step.  q,k,v (B,H,Dh); gates (B,H);
    C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)."""
    dh = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, i_gate.astype(jnp.float32))
    f_sc = jnp.exp(logf + m - m_new)[..., None, None]
    i_sc = jnp.exp(i_gate.astype(jnp.float32) - m_new)[..., None, None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C_new = f_sc * C + i_sc * (kf[..., :, None] * vf[..., None, :])
    n_new = f_sc[..., 0] * n + i_sc[..., 0] * kf
    qf = q.astype(jnp.float32) / math.sqrt(dh)
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    den = jnp.maximum(jnp.abs(jnp.sum(n_new * qf, axis=-1, keepdims=True)),
                      jnp.exp(-m_new)[..., None])
    return (num / den).astype(v.dtype), C_new, n_new, m_new


def apply_mlstm(p: Params, x: jax.Array, cfg: ModelConfig, d_inner: int,
                state: tuple | None = None):
    """x (B,S,Di) -> (y (B,S,Di), new_state).  state = (C, n, m)."""
    dtype = x.dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    dh = d_inner // h
    xh = x.reshape(b, s, h, dh)
    qkv = jnp.einsum("bshd,hde->bshe", xh, p["w_qkv"].astype(dtype))
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3) / math.sqrt(dh)
    v = v.transpose(0, 2, 1, 3)
    gates = x @ p["w_if"].astype(dtype) + p["if_bias"].astype(dtype)
    i_gate = gates[..., :h].transpose(0, 2, 1)             # (B,H,S)
    f_gate = gates[..., h:].transpose(0, 2, 1)

    if s > 1:
        chunk = min(256, s)
        y, new_state = mlstm_chunkwise(q, k, v, i_gate, f_gate, state,
                                       chunk=chunk if s % chunk == 0 else s)
    else:
        C, n, m = state if state is not None else (
            jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )

        def step(carry, inputs):
            C, n, m = carry
            qt, kt, vt, it, ft = inputs
            y, C, n, m = mlstm_step(qt, kt, vt, it, ft, C, n, m)
            return (C, n, m), y

        xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
              v.transpose(2, 0, 1, 3), i_gate.transpose(2, 0, 1),
              f_gate.transpose(2, 0, 1))
        (C, n, m), ys = jax.lax.scan(step, (C, n, m), xs)
        y = ys.transpose(1, 2, 0, 3)                       # (B,H,S,Dh)
        new_state = (C, n, m)

    y = layers.rms_norm(y, p["o_norm"])
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return y, new_state


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell) -- sequential scan.
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, d_inner: int) -> Params:
    h = cfg.n_heads
    dh = d_inner // h
    ks = jax.random.split(key, 3)
    return {
        "w_zifo": layers.dense_init(ks[0], d_inner, 4 * d_inner),
        "r_zifo": jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32) / math.sqrt(dh),
        "b_zifo": jnp.zeros((4 * d_inner,), jnp.float32),
        "o_norm": jnp.zeros((dh,), jnp.float32),
    }


def slstm_step(p: Params, xt: jax.Array, state, cfg: ModelConfig, d_inner: int):
    """xt (B, 4*Di) preactivation from the input projection; state (c,n,h,m)
    each (B,H,Dh).  Head-blocked recurrent weights (block-diagonal R)."""
    c, n, hid, m = state
    b = xt.shape[0]
    nh = cfg.n_heads
    dh = d_inner // nh
    rec = jnp.einsum("bhd,hde->bhe", hid, p["r_zifo"].astype(hid.dtype))  # (B,H,4Dh)
    pre = xt.reshape(b, nh, 4 * dh) + rec + p["b_zifo"].reshape(nh, 4 * dh).astype(xt.dtype)
    z, i_raw, f_raw, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i_sc = jnp.exp(i_raw - m_new)
    f_sc = jnp.exp(logf + m - m_new)
    c_new = f_sc * c + i_sc * z
    n_new = f_sc * n + i_sc
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new.astype(xt.dtype), m_new)


def apply_slstm(p: Params, x: jax.Array, cfg: ModelConfig, d_inner: int,
                state=None):
    """x (B,S,Di) -> (y (B,S,Di), state).  Sequential over S by construction."""
    dtype = x.dtype
    b, s, _ = x.shape
    nh = cfg.n_heads
    dh = d_inner // nh
    if state is None:
        zeros = jnp.zeros((b, nh, dh), jnp.float32)
        state = (zeros, zeros, zeros.astype(dtype), jnp.full((b, nh, dh), -1e30, jnp.float32))
    xin = x @ p["w_zifo"].astype(dtype)                    # (B,S,4Di)

    def step(carry, xt):
        new = slstm_step(p, xt, carry, cfg, d_inner)
        return new, new[2]

    state, hs = jax.lax.scan(step, state, xin.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3)                           # (B,S,H,Dh)
    y = layers.rms_norm(y, p["o_norm"]).reshape(b, s, nh * dh)
    return y, state
