"""Model construction + dry-run input specifications.

``build_model(cfg)`` returns the family-appropriate model object (all expose
init / loss / prefill / decode_step / init_cache).

``input_specs(cfg, shape_name)`` returns ShapeDtypeStruct stand-ins for every
input of the step function that the (arch x shape) dry-run cell lowers --
weak-type-correct, shardable, no device allocation.  Modality frontends are
stubs: [vlm] cells get precomputed patch embeddings, [audio] cells get
precomputed frame embeddings, per the build brief.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.encdec import Seq2SeqLM
from repro.models.transformer import CausalLM
from repro.models.xlstm import XLSTMLM

# (seq_len, global_batch, kind)
SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

N_VISION_PATCHES = 256  # stub ViT output length prepended to [vlm] sequences


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return Seq2SeqLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    return CausalLM(cfg)


def is_subquadratic(cfg: ModelConfig) -> bool:
    """Eligibility for long_500k: SSM / hybrid / sliding-window-dominant."""
    return cfg.family in ("ssm", "hybrid") or cfg.attn_pattern in ("sliding", "local_global")


def supports(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return is_subquadratic(cfg)
    return True


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    """Specs for the step function of this cell.

    train  -> {"batch": {tokens, labels, ...}}
    prefill-> {"batch": {tokens, ...}}
    decode -> {"cache": <full-cache spec>, "tokens", ...}
    """
    seq, batch, kind = SHAPES[shape_name]
    model = build_model(cfg)

    if kind == "train":
        b = {"tokens": _tok(batch, seq), "labels": _tok(batch, seq)}
        if cfg.mrope_sections:
            b["positions"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
        if cfg.frontend == "vision":
            b["frontend_embeds"] = jax.ShapeDtypeStruct(
                (batch, N_VISION_PATCHES, cfg.d_model), cfg.compute_dtype
            )
        if cfg.frontend == "audio":
            b["frontend_embeds"] = jax.ShapeDtypeStruct(
                (batch, seq, cfg.d_model), cfg.compute_dtype
            )
        return {"batch": b}

    if kind == "prefill":
        b = {"tokens": _tok(batch, seq)}
        if cfg.mrope_sections:
            b["positions"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
        if cfg.frontend == "vision":
            b["frontend_embeds"] = jax.ShapeDtypeStruct(
                (batch, N_VISION_PATCHES, cfg.d_model), cfg.compute_dtype
            )
        if cfg.frontend == "audio":
            b["frontend_embeds"] = jax.ShapeDtypeStruct(
                (batch, seq, cfg.d_model), cfg.compute_dtype
            )
        return {"batch": b}

    # decode: one new token against a KV cache of length `seq`
    if cfg.family == "encdec":
        cache = jax.eval_shape(lambda: model.init_cache(batch, seq, seq))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(batch, seq))
    spec = {"cache": cache, "tokens": _tok(batch, 1)}
    if cfg.mrope_sections:
        spec["positions"] = jax.ShapeDtypeStruct((3, batch, 1), jnp.int32)
    return spec


def param_specs(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))
