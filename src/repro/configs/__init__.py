"""Architecture registry: one module per assigned architecture (exact public
configs) plus reduced smoke variants for CPU tests."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "gemma-2b": "gemma_2b",
    "gemma-7b": "gemma_7b",
    "command-r-35b": "command_r_35b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)
