"""gemma3-1b [dense]: 26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144.
5:1 local:global sliding-window interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    attn_pattern="local_global",
    sliding_window=1024,
    global_every=6,          # 5 local : 1 global
    rope_theta=1_000_000.0,
    qk_norm=True,
    post_norm=True,
    embed_scale=True,
    mlp_kind="geglu",
)
