"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
M-RoPE (sections t/h/w = 16/24/24 half-dims), dynamic resolution via a STUB
ViT frontend (precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152_064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision",
    mlp_kind="swiglu",
)
