"""seamless-m4t-large-v2 [audio]: enc-dec, 24+24L d_model=1024 16H (MHA)
d_ff=8192 vocab=256206.  Audio frontend is a STUB (precomputed frame
embeddings); conformer convs live in the stubbed frontend (DESIGN.md §3).
[arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    frontend="audio",
    mlp_kind="gelu",
)
