"""deepseek-v2-236b [moe]: 60L d_model=5120 128H, MLA (kv_lora=512,
q_lora=1536, rope_head_dim=64, nope=128, v=128), d_ff_expert=1536,
vocab=102400, MoE 160 routed top-6 + 2 shared, 1 leading dense layer
(d_ff=12288).  [arXiv:2405.04434; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="mla_moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,          # nope qk dim
    rope_head_dim=64,
    v_head_dim=128,
    kv_lora_rank=512,
    q_lora_rank=1536,
    d_ff=12288,            # the leading dense layer
    d_ff_expert=1536,
    vocab_size=102_400,
    n_experts=160,
    n_experts_per_token=6,
    n_shared_experts=2,
    n_dense_leading=1,
    moe_every=1,
    capacity_factor=1.0,
    mlp_kind="swiglu",
)
