"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff_expert=8192 vocab=202048, MoE 128 routed top-1 + 1 shared, alternating
dense/MoE layers (dense d_ff=16384).  Early-fusion multimodal -- text backbone
only here per the brief.  [hf:meta-llama/Llama-4; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,           # dense (non-MoE) layers
    d_ff_expert=8192,     # routed + shared experts
    vocab_size=202_048,
    n_experts=128,
    n_experts_per_token=1,
    n_shared_experts=1,
    moe_every=2,          # alternate dense / MoE
    rope_theta=500_000.0,
    mlp_kind="swiglu",
)
