"""xlstm-1.3b [ssm]: 48 blocks d_model=2048 4H vocab=50304, no FFN on mLSTM
blocks (pf=2 up-projection inside), 1 sLSTM block per 8 (7:1 m:s ratio).
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    ssm_expand=2,
    ssm_conv=4,
    slstm_every=8,
)
