"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 -- parallel attention + mamba heads per block, sliding-window
attention with sparse global layers.  Meta tokens are omitted (stub note in
DESIGN.md).  [arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    attn_pattern="local_global",
    sliding_window=1024,
    global_every=16,       # sparse global layers
    ssm_state=16,
    ssm_conv=4,
    mlp_kind="swiglu",
)
