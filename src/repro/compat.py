"""Version-compatibility shims for JAX API movement.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, ``jax.sharding.AxisType`` and the ``axis_types`` kwarg of
``jax.make_mesh`` appeared later still.  Import from here so the rest of the
codebase works on both sides of each move.
"""
from __future__ import annotations

import enum

import jax

try:  # JAX >= 0.6: top-level export
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # older JAX: experimental namespace
    from jax.experimental.shard_map import shard_map  # noqa: F401

try:  # JAX >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # older JAX: every mesh axis is implicitly "auto"

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off.

    JAX 0.4.x's ``check_rep`` pass rejects valid ``lax.scan`` carries whose
    replication differs between input and output (jax-ml/jax#21931-style);
    newer JAX renamed the flag to ``check_vma``.  Try each spelling.
    """
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise TypeError("no compatible shard_map signature found")


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates older signatures without ``axis_types``."""
    try:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                             axis_types=axis_types)
    except TypeError:  # pre-axis_types JAX: all axes behave as Auto already
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def flat_mesh(n_devices: int | None = None, axis_name: str = "data",
              devices=None):
    """One-axis device mesh over the first ``n_devices`` devices.

    The single mesh-construction path for every batch/seed-sharded solver
    (``fl.simulator.run_fleet``, ``core.disba.disba_sharded``,
    ``launch.mesh.make_fleet_mesh``): one place encodes the device selection
    and the version-tolerant ``make_mesh`` call.  ``n_devices=None`` takes
    every visible device.
    """
    if devices is None:
        devices = jax.devices()
    if n_devices is None:
        n_devices = len(devices)
    if not 1 <= n_devices <= len(devices):
        raise ValueError(
            f"n_devices={n_devices} outside [1, {len(devices)}] visible "
            f"devices")
    return make_mesh((n_devices,), (axis_name,),
                     axis_types=(AxisType.Auto,),
                     devices=devices[:n_devices])


def abstract_mesh(axis_shapes, axis_names, *, axis_types=None):
    """``jax.sharding.AbstractMesh`` across its two historical signatures:
    new JAX takes (sizes, names, axis_types=tuple); 0.4.x takes a single
    ((name, size), ...) tuple."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes), tuple(axis_names),
                                         axis_types=axis_types)
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


__all__ = ["shard_map", "shard_map_unchecked", "AxisType", "make_mesh",
           "flat_mesh", "abstract_mesh"]
