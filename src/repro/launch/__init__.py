"""Launch layer: production mesh construction, the multi-pod dry-run, and
end-to-end train/serve drivers."""
