"""End-to-end multi-service FL training driver (deliverable b's main entry).

Simulates the paper's full system with REAL training inside it: N FL services
(each an architecture from the zoo, reduced by default so the driver runs on
CPU) train concurrently; every period the allocator (DISBA / auction /
baseline) splits the wireless bandwidth, the intra-service solver splits it
across clients, the round-time model turns allocations into wall-clock time,
and each service runs as many *actual* FedAvg rounds as fit in the period --
with straggler deadlines, optional uplink compression (which feeds back into
s^UT), and step-atomic checkpointing for crash recovery.

Usage:
  PYTHONPATH=src python -m repro.launch.train --services gemma-2b,xlstm-1.3b \
      --policy coop --periods 4 --reduced
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core import intra, network
from repro.core import policy as policy_mod
from repro.core.types import stack_services
from repro.data import SyntheticLM
from repro.fl import compression as fl_comp
from repro.fl import server as fl_server
from repro.fl.service import arch_service_tuple
from repro.models import registry


def allocate(policy, svc, b_total, n_bids=5, alpha_fair=0.5,
             intra_backend="reference"):
    """Inter-service split through the AllocationPolicy registry."""
    b, _ = policy_mod.allocate(policy, svc, b_total, n_bids=n_bids,
                               alpha_fair=alpha_fair,
                               intra_backend=intra_backend)
    return b


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--services", default="gemma-2b,xlstm-1.3b")
    # "ec" is excluded: the driver applies the optimal per-client split to
    # the service totals, which would mislabel Equal-Client (whose defining
    # property is the *uniform* per-client split) as something better.
    ap.add_argument("--policy", default="coop",
                    choices=sorted(set(policy_mod.available()) - {"ec"}))
    ap.add_argument("--intra-backend", default="reference",
                    choices=list(policy_mod.INTRA_BACKENDS),
                    help="intra-service solver: reference jnp bisection or "
                         "the Pallas bisect_alloc kernel (interpret off-TPU)")
    ap.add_argument("--periods", type=int, default=3)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    # --reduced / --no-reduced: the old `action="store_true", default=True`
    # declaration could never be switched off, leaving the full-config branch
    # dead (the same bug PR 7's serve.py fix pinned; tests/test_train_launch.py
    # pins both directions here).
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="train the smoke-reduced configs (default); "
                         "--no-reduced trains the full public configs")
    ap.add_argument("--compression", default="none",
                    choices=list(fl_comp.METHODS))
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="kept fraction for topk/topk_int8 -- one value "
                         "feeds BOTH the s^UT pricing (compression_ratio) "
                         "and the round step's sparsifier")
    ap.add_argument("--error-feedback", action="store_true", default=False,
                    help="carry client-held compression residuals across "
                         "rounds (Karimireddy-style EF)")
    ap.add_argument("--straggler-deadline-x", type=float, default=3.0,
                    help="deadline = x * optimal round time")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-rounds-per-period", type=int, default=6)
    return ap


def resolve_config(arch: str, reduced: bool):
    """The config branch ``--reduced`` selects (both directions reachable)."""
    return configs.get_smoke_config(arch) if reduced else configs.get_config(arch)


def compression_setup(args) -> dict:
    """Single source of truth for the driver's compression knobs.

    Returns ``ratio`` -- the s^UT multiplier priced into every service tuple
    -- and ``round_step_kwargs``, the matching ``make_fl_round_step``
    settings.  Both sides read the SAME ``--topk-frac``, so the allocator can
    never price a different sparsity than the round step transmits (the old
    code let each fall back to its own hard-coded default).
    """
    ratio = fl_comp.compression_ratio(args.compression,
                                      k_frac=args.topk_frac)
    return dict(
        ratio=ratio,
        round_step_kwargs=dict(
            compression=args.compression,
            topk_frac=args.topk_frac,
            error_feedback=args.error_feedback,
        ),
    )


def main() -> None:
    args = build_parser().parse_args()

    arch_names = args.services.split(",")
    rng = np.random.default_rng(args.seed)
    net = network.NetworkConfig()
    comp = compression_setup(args)

    # ---- build one FL service per arch: model + data + round step + tuple
    services = []
    for i, name in enumerate(arch_names):
        cfg = resolve_config(name, args.reduced)
        model = registry.build_model(cfg)
        params = model.init(jax.random.key(args.seed + i))
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           seed=args.seed + i, temperature=0.3)
        k = args.clients
        pl_db = 85.0 + rng.normal(0, 2.0, size=k)
        raw = arch_service_tuple(
            cfg,
            r_dl=network.base_rate(jnp.float32(0.2), jnp.asarray(pl_db)),
            r_ul=network.base_rate(jnp.float32(0.1), jnp.asarray(pl_db)),
            client_flops=jnp.asarray(rng.uniform(2e11, 8e11, size=k)),
            tokens_per_round=args.batch * args.seq,
            uplink_compression=comp["ratio"],
        )
        if cfg.family == "encdec":
            def loss_fn(p, b, model=model, cfg=cfg):
                b = dict(b)
                b["frontend_embeds"] = jnp.zeros(
                    (b["tokens"].shape[0], b["tokens"].shape[1], cfg.d_model))
                return model.loss(p, b)
        else:
            loss_fn = model.loss
        round_step = jax.jit(fl_server.make_fl_round_step(
            loss_fn, local_steps=args.local_steps, client_lr=1.0,
            **comp["round_step_kwargs"]))
        residuals = (fl_server.init_residuals(params, args.clients)
                     if args.error_feedback else None)
        services.append(dict(name=name, cfg=cfg, model=model, params=params,
                             data=data, raw=raw, round_step=round_step,
                             residuals=residuals, rounds_done=0, losses=[]))

    svc_set = stack_services([s["raw"] for s in services])
    mgr = None
    start_period = 0
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir, keep=2)
        like = {s["name"]: s["params"] for s in services}
        step, restored, extra = mgr.restore_latest(like)
        if step is not None:
            start_period = step
            for s in services:
                s["params"] = jax.tree.map(jnp.asarray, restored[s["name"]])
                s["rounds_done"] = extra["rounds_done"][s["name"]]
            print(f"[resume] from period {start_period}")

    # ---- the period loop: allocate -> time rounds -> really train
    client_split = policy_mod.client_split_fn(args.intra_backend)
    for period in range(start_period, args.periods):
        b_alloc = allocate(args.policy, svc_set, net.total_bandwidth_mhz,
                           intra_backend=args.intra_backend)
        t_round = intra.solve_round_time(svc_set, b_alloc)
        client_alloc = client_split(svc_set, b_alloc)
        n_rounds = np.minimum(
            np.floor(net.period_s / np.asarray(t_round)).astype(int),
            args.max_rounds_per_period,
        )
        print(f"\n[period {period}] policy={args.policy} "
              f"b={np.round(np.asarray(b_alloc), 3)} MHz "
              f"t_round={np.round(np.asarray(t_round), 3)} s rounds={n_rounds}")
        for si, s in enumerate(services):
            # per-client realized latency -> straggler weights
            lat = svc_set.t_comp[si] + svc_set.alpha[si] / jnp.maximum(
                client_alloc[si], 1e-30)
            lat = jnp.where(svc_set.mask[si], lat, 0.0)[: args.clients]
            deadline = float(t_round[si]) * args.straggler_deadline_x
            weights = fl_server.straggler_weights(lat, deadline)
            for r in range(int(n_rounds[si])):
                step_id = s["rounds_done"]
                batches = [
                    jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[s["data"].batch(step_id * 97 + e, args.batch, client_id=c)
                          for e in range(args.local_steps)],
                    )
                    for c in range(args.clients)
                ]
                batches = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
                t0 = time.time()
                if args.error_feedback:
                    s["params"], metrics, s["residuals"] = s["round_step"](
                        s["params"], batches, weights, s["residuals"])
                else:
                    s["params"], metrics = s["round_step"](
                        s["params"], batches, weights)
                s["rounds_done"] += 1
                s["losses"].append(float(metrics["loss"]))
            if int(n_rounds[si]):
                print(f"  {s['name']:26s} rounds+={int(n_rounds[si])} "
                      f"loss={s['losses'][-1]:.4f} "
                      f"participants={int(jnp.sum(weights))}/{args.clients}")
        if mgr is not None:
            mgr.save(period + 1,
                     {s["name"]: s["params"] for s in services},
                     extra={"rounds_done": {s["name"]: s["rounds_done"]
                                            for s in services}})

    print("\n[summary]")
    for s in services:
        l0 = s["losses"][0] if s["losses"] else float("nan")
        l1 = s["losses"][-1] if s["losses"] else float("nan")
        print(f"  {s['name']:26s} rounds={s['rounds_done']:3d} "
              f"loss {l0:.4f} -> {l1:.4f}")


if __name__ == "__main__":
    main()
