"""Batched serving driver: prefill + decode loop with a KV/state cache.

Serves a (reduced by default; ``--no-reduced`` selects the full public
config) architecture on CPU for demonstration; the full-config serve_step is
exercised at scale by the dry-run cells (decode_32k / long_500k).  Prefill
time is measured after blocking on the logits (compute, not async dispatch),
and every generated token -- including the first, sampled from the prefill
logits -- goes through the same ``--temperature`` path, so the driver emits
exactly ``--gen`` sampled tokens.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --batch 4 \
      --prompt-len 64 --gen 32 [--no-reduced]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import registry


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    # --reduced / --no-reduced: the old `action="store_true", default=True`
    # declaration could never be switched off, leaving the full-config branch
    # dead (tests/test_serve.py pins both directions).
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve the smoke-reduced config (default); "
                         "--no-reduced serves the full public config")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def resolve_config(arch: str, reduced: bool):
    """The config branch ``--reduced`` selects (both directions reachable)."""
    return configs.get_smoke_config(arch) if reduced else configs.get_config(arch)


def sample_token(key: jax.Array, logits: jax.Array, temperature: float) -> jax.Array:
    """(B, 1) next token from final-position logits: categorical at
    ``temperature`` > 0, greedy argmax at 0.  Used for EVERY generated token,
    including the first one off the prefill logits."""
    if temperature > 0:
        return jax.random.categorical(key, logits[:, -1] / temperature)[:, None]
    return jnp.argmax(logits[:, -1], axis=-1)[:, None]


def generate(model, params, batch: dict, *, max_len: int, gen: int,
             temperature: float, key: jax.Array, jit_prefill: bool = True):
    """Prefill then decode ``gen`` tokens.  Returns (tokens (B, gen), info).

    ``info`` carries wall-clock timings measured on device-ready outputs:
    ``t_prefill`` blocks on the prefill logits before reading the clock, and
    ``decode_steps`` counts the ``gen - 1`` decode launches that follow the
    first token (sampled from the prefill logits through the same
    temperature path as the rest).
    """
    if gen < 1:
        raise ValueError(f"gen must be >= 1, got {gen}")
    t0 = time.perf_counter()
    if jit_prefill:
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))(params, batch)
    else:
        logits, cache = model.prefill(params, batch, max_len=max_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step)
    key, sub = jax.random.split(key)
    tok = sample_token(sub, logits, temperature)
    generated = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, tok)
        key, sub = jax.random.split(key)
        tok = sample_token(sub, logits, temperature)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    info = {"t_prefill": t_prefill, "t_decode": t_decode,
            "decode_steps": gen - 1, "cache": cache}
    return out, info


def main(argv: list[str] | None = None) -> None:
    args = build_parser().parse_args(argv)
    cfg = resolve_config(args.arch, args.reduced)
    model = registry.build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen

    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.key(2), (args.batch, args.prompt_len, cfg.d_model)) * 0.1

    out, info = generate(
        model, params, batch, max_len=max_len, gen=args.gen,
        temperature=args.temperature, key=key,
        jit_prefill=cfg.family != "encdec",
    )
    print(f"[prefill] {args.batch}x{args.prompt_len} in "
          f"{info['t_prefill']:.3f}s")
    print(f"[decode] {info['decode_steps']} steps in {info['t_decode']:.3f}s "
          f"({1000 * info['t_decode'] / max(info['decode_steps'], 1):.1f} "
          f"ms/tok/batch)")
    print(f"[tokens] {out.shape[1]} generated; first sequence: "
          f"{out[0][:16].tolist()} ...")
    print(f"[cache]  len={int(info['cache']['len'])}")


if __name__ == "__main__":
    main()
