"""Batched serving driver: prefill + decode loop with a KV/state cache.

Serves a (reduced by default) architecture on CPU for demonstration; the
full-config serve_step is exercised at scale by the dry-run cells
(decode_32k / long_500k).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --batch 4 \
      --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import registry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch) if args.reduced \
        else configs.get_config(args.arch)
    model = registry.build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen

    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.key(2), (args.batch, args.prompt_len, cfg.d_model)) * 0.1

    t0 = time.time()
    if cfg.family == "encdec":
        logits, cache = model.prefill(params, batch, max_len=max_len)
    else:
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))(params, batch)
    t_prefill = time.time() - t0
    print(f"[prefill] {args.batch}x{args.prompt_len} in {t_prefill:.3f}s")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [tok]
    t0 = time.time()
    for step in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"[decode] {args.gen - 1} steps in {t_decode:.3f}s "
          f"({1000 * t_decode / max(args.gen - 1, 1):.1f} ms/tok/batch)")
    print(f"[tokens] first sequence: {out[0][:16].tolist()} ...")
    print(f"[cache]  len={int(cache['len'])}")


if __name__ == "__main__":
    main()
