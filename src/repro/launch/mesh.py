"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count BEFORE any jax init, and
smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from repro.compat import AxisType, flat_mesh, make_mesh

FLEET_AXIS = "seeds"


def make_fleet_mesh(n_devices: int | None = None):
    """One-axis mesh over the seed dimension for Monte-Carlo episode sweeps.

    ``fl.simulator.run_fleet`` shards its fleet of episodes over this axis;
    defaults to every visible device (8 under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, a full pod slice
    in production).  Routed through ``compat.flat_mesh`` so fleet sweeps and
    ``disba_sharded`` share one mesh-construction path.
    """
    return flat_mesh(n_devices, axis_name=FLEET_AXIS)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); the pod axis crosses
    the data-center interconnect, so steady-state traffic on it is limited to
    gradient all-reduce (DESIGN.md §4)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_elastic_mesh(n_devices: int, model_parallel: int = 16):
    """Elastic variant: factor whatever device count survives a failure into
    (data, model), shrinking model-parallel if needed (repro.distributed.elastic)."""
    while model_parallel > 1 and n_devices % model_parallel != 0:
        model_parallel //= 2
    data = n_devices // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))
