import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the module docstring sits below the XLA_FLAGS lines on purpose -- the
# flag must be set before ANY jax import (jax locks the device count at first
# init), and __future__ imports are therefore not used in this file.
DOC = """Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, builds the production mesh
(single-pod 16x16 = 256 chips, or multi-pod 2x16x16 = 512), jits the step
function with the arch's sharding rules, and proves the distribution config
is coherent by running ``.lower().compile()`` on 512 host placeholder
devices -- printing ``memory_analysis()`` (fits?) and ``cost_analysis()``
(FLOPs/bytes for §Roofline), and summing the collective-op bytes from the
post-SPMD HLO (not in cost_analysis).

The XLA_FLAGS line above MUST run before any jax import -- jax locks the
device count at first init.  Never set that flag globally: smoke tests and
benchmarks must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out artifacts/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.distributed import api as dist_api
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim import adamw

# v5e hardware constants for §Roofline (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.MULTILINE)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO.
    (-start async forms counted once; -done forms carry no shape of their own
    that we match because they have no '(' pattern with an op name.)"""
    out: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes_txt = m.group(1) or m.group(2) or ""
        op = m.group(3)
        out[op] = out.get(op, 0) + _shape_bytes(shapes_txt)
    return out


def _split_micro(batch, micro: int):
    """Reshape every batch leaf to (micro, B/micro, ...); M-RoPE positions
    carry batch at axis 1."""
    def f(path, x):
        ax = 1 if ("positions" in jax.tree_util.keystr(path) and x.ndim == 3) else 0
        b = x.shape[ax]
        assert b % micro == 0, (b, micro)
        moved = jnp.moveaxis(x, ax, 0)
        out = moved.reshape(micro, b // micro, *moved.shape[1:])
        return jnp.moveaxis(out, 1, ax + 1)

    return jax.tree_util.tree_map_with_path(f, batch)


def build_step(cfg, shape_name: str, microbatch: int = 1,
               moment_dtype=None):
    """Returns (step_fn, example_args (SDS pytree), donate) for the cell.

    microbatch > 1 runs gradient accumulation: the global batch is split into
    ``microbatch`` sequential micro-steps inside one jit -- activation
    checkpoints shrink by the same factor (the memory-term hillclimb lever
    for the big train cells; EXPERIMENTS.md §Perf)."""
    model = registry.build_model(cfg)
    seq, batch, kind = registry.SHAPES[shape_name]
    specs = registry.input_specs(cfg, shape_name)
    params_sds = registry.param_specs(cfg)

    if kind == "train":
        init_opt, update = adamw(
            lr=1e-4, weight_decay=0.1, max_grad_norm=1.0,
            moment_dtype=moment_dtype or jnp.float32)
        opt_sds = jax.eval_shape(init_opt, params_sds)

        if microbatch <= 1:
            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                params, opt_state = update(grads, opt_state, params)
                return params, opt_state, loss
        else:
            def train_step(params, opt_state, batch):
                micro = _split_micro(batch, microbatch)

                def body(carry, mb):
                    loss_acc, g_acc = carry
                    loss, g = jax.value_and_grad(model.loss)(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
                    return (loss_acc + loss, g_acc), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), zeros), micro)
                grads = jax.tree.map(lambda g: g / microbatch, grads)
                params, opt_state = update(grads, opt_state, params)
                return params, opt_state, loss / microbatch

        return train_step, (params_sds, opt_sds, specs["batch"]), (0, 1), kind

    # serving: bf16 weights (deployments quantize; halves HBM + any movement)
    params_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, jnp.bfloat16 if x.dtype == jnp.float32 else x.dtype),
        params_sds)

    if kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, max_len=seq)

        return prefill_step, (params_sds, specs["batch"]), (), kind

    # decode
    cache_sds = specs["cache"]
    if cfg.mrope_sections:
        def decode_step(params, cache, tokens, positions):
            return model.decode_step(params, cache, tokens, positions=positions)
        args = (params_sds, cache_sds, specs["tokens"], specs["positions"])
    else:
        def decode_step(params, cache, tokens):
            return model.decode_step(params, cache, tokens)
        args = (params_sds, cache_sds, specs["tokens"])
    return decode_step, args, (1,), kind


def shardings_for(cfg, mesh, args, kind, serve_2d: bool = True):
    """in_shardings matching build_step's argument order.  Serve cells use
    the stationary 2D-TP weight layout (see sharding.param_shardings)."""
    params_sh = sharding.param_shardings(
        cfg, args[0], mesh, serve_2d=serve_2d and kind != "train")
    if kind == "train":
        opt_sh = sharding.param_shardings(cfg, args[1], mesh)
        batch_sh = sharding.batch_shardings(cfg, args[2], mesh)
        return (params_sh, opt_sh, batch_sh)
    if kind == "prefill":
        return (params_sh, sharding.batch_shardings(cfg, args[1], mesh))
    cache_sh = sharding.cache_shardings(cfg, args[1], mesh)
    rest = tuple(sharding.batch_shardings(cfg, a, mesh) for a in args[2:])
    return (params_sh, cache_sh) + rest


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatch: int = 1, moment_dtype=None) -> dict:
    cfg = configs.get_config(arch)
    if not registry.supports(cfg, shape_name):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full-attention arch; long_500k needs sub-quadratic "
                          "attention (DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    step_fn, args, donate, kind = build_step(cfg, shape_name, microbatch,
                                             moment_dtype)
    in_sh = shardings_for(cfg, mesh, args, kind)

    dist_api.set_mesh(mesh)
    try:
        t0 = time.time()
        jitted = jax.jit(step_fn, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    finally:
        dist_api.set_mesh(None)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # older JAX returns a one-element list of dicts (one per device program)
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": int(n_chips),
        "status": "ok",
        "kind": kind,
        "microbatch": microbatch,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", 0.0)) if cost else None,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else None,
        "collective_bytes": coll,
        "collective_bytes_total": int(sum(coll.values())),
        "hlo_collective_ops": {k: hlo.count(f" {k}") for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute")},
    }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
    # raw per-chip roofline terms (seconds); the HLO quantities of the SPMD
    # module are already per-partition, and scan bodies are counted once --
    # benchmarks/roofline.py applies the scan-trip correction before these
    # feed §Roofline.
    if result.get("flops"):
        result["compute_term_s"] = result["flops"] / PEAK_FLOPS
    if result.get("bytes_accessed"):
        result["memory_term_s"] = result["bytes_accessed"] / HBM_BW
    result["collective_term_s"] = result["collective_bytes_total"] / ICI_BW
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(registry.SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    archs = configs.ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    shapes = list(registry.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[cell] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, shape_name, multi, args.microbatch)
                except Exception as e:  # noqa: BLE001 -- record and continue
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={res['compile_s']}s flops={res.get('flops'):.3e}"
                             f" coll={res['collective_bytes_total']:.3e}B")
                print(f"[done] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
