"""allocd: asyncio bandwidth-allocation daemon over the compiled market step.

The serving front end of ``fl.control_plane``: an event loop that

* drains an **asyncio request queue** (admit / retire / heartbeat) in batches
  between period ticks, so a burst of arrivals lands as one set of mask
  flips before the next compiled clear;
* runs each period's solve **off the event loop** (executor thread) and
  **degrades gracefully** when it misses its deadline: past
  ``solver_timeout_s`` the daemon serves the previous period's allocation
  rescaled to the live admission mask, counted in the ``stale_decisions``
  metric -- a stale decision is never served silently, and the in-flight
  solve still commits its carry before the next period launches;
* **checkpoints** the serving state through ``CheckpointManager``'s COMMIT
  protocol every ``save_every`` cleared periods and auto-restores the
  newest complete snapshot at startup.

Requests that arrive while a solve is in flight stay queued and apply at
the next idle drain -- the state the solver reads is never mutated
concurrently.

Degradation paths are bounded and counted (never silent): capacity-full
admissions retry with exponential period backoff before landing in
``rejections``; a stale streak longer than ``max_stale_streak`` degrades to
the O(1) equal-share decision; non-finite solver outputs are caught by the
plane and every such event lands in the end-of-run metrics line
(``solver_fallbacks`` / ``degraded_decisions`` / ...).  All of these paths
are exercised under seeded fault injection by ``repro.chaos`` (injector
catalogue, replay-from-seed instructions: EXPERIMENTS.md §Chaos drills).

Usage (synthetic Poisson workload, prints a serving summary + differential
replay check against ``simulator.run_scan``):

  PYTHONPATH=src python -m repro.launch.allocd --capacity 16 --periods 40 \
      --rate 0.5 --policy coop [--cold] [--check]
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time
from typing import Any

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import network
from repro.fl import control_plane
from repro.fl.control_plane import ControlPlane, ControlPlaneConfig, Decision


@dataclasses.dataclass(frozen=True)
class Admit:
    service_id: Any
    n_clients: int


@dataclasses.dataclass(frozen=True)
class Retire:
    service_id: Any


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    service_id: Any
    client: int | None = None


class AllocDaemon:
    """Event-loop wrapper around one ``ControlPlane``.

    ``submit`` enqueues requests from any coroutine; ``step_period`` serves
    exactly one decision (fresh or stale); ``serve`` runs the loop.  The
    ``served`` list is the wall-clock stream (may contain stale entries);
    ``plane.decisions`` is the fresh-solve stream the differential replay
    checks.
    """

    def __init__(self, cfg: ControlPlaneConfig,
                 net: network.NetworkConfig | None = None, *,
                 solver_timeout_s: float | None = None,
                 manager: CheckpointManager | None = None,
                 save_every: int = 10,
                 max_stale_streak: int = 8,
                 admit_max_retries: int = 3):
        self.plane = ControlPlane(cfg, net)
        self.solver_timeout_s = solver_timeout_s
        self.manager = manager
        self.save_every = max(int(save_every), 1)
        self.max_stale_streak = max(int(max_stale_streak), 1)
        self.admit_max_retries = max(int(admit_max_retries), 0)
        self.requests: asyncio.Queue = asyncio.Queue()
        self.served: list[Decision] = []
        self.rejections: list[tuple[Any, str]] = []
        # Capacity-rejected admits awaiting retry: (request, attempts,
        # not-before period) -- exponential backoff in periods.
        self._retry_queue: list[tuple[Admit, int, int]] = []
        self.stale_streak = 0
        self.resumed = bool(manager and self.plane.restore(manager))
        self._pending: asyncio.Future | None = None
        # Test hook: extra seconds of solver latency injected inside the
        # executor call, to exercise the timeout -> stale path.
        self._solver_delay_s = 0.0
        # Chaos hook: force the next step_period to skip awaiting the solve
        # and serve stale -- a *deterministic* deadline miss (wall-clock
        # timeouts are not replayable; src/repro/chaos drives this).
        self._force_stale_next = False

    def submit(self, request) -> None:
        self.requests.put_nowait(request)

    def _try_admit(self, req: Admit, attempts: int) -> None:
        """Admit with bounded retry: a capacity rejection (transient -- a
        slot may free up) re-queues the request with exponential period
        backoff (1, 2, 4, ... periods, ``admit_max_retries`` attempts);
        validation errors (duplicate id, bad n_clients) are permanent and
        land in ``rejections`` immediately."""
        try:
            self.plane.admit(req.service_id, req.n_clients)
        except RuntimeError as exc:
            if attempts < self.admit_max_retries:
                self.plane.metrics["admit_retries"] += 1
                self._retry_queue.append(
                    (req, attempts + 1, self.plane.period + 2 ** attempts))
            else:
                self.rejections.append(
                    (req.service_id,
                     f"RuntimeError: {exc} (gave up after {attempts} "
                     f"retries)"))
        except (ValueError, KeyError) as exc:
            self.rejections.append((req.service_id,
                                    f"{type(exc).__name__}: {exc}"))

    def _drain(self) -> None:
        """Apply every queued request; called only while no solve is in
        flight, so the compiled step never races a registry mutation."""
        period = self.plane.period
        due = [r for r in self._retry_queue if r[2] <= period]
        self._retry_queue = [r for r in self._retry_queue if r[2] > period]
        for req, attempts, _ in due:
            self._try_admit(req, attempts)
        while True:
            try:
                req = self.requests.get_nowait()
            except asyncio.QueueEmpty:
                return
            try:
                if isinstance(req, Admit):
                    self._try_admit(req, 0)
                elif isinstance(req, Retire):
                    self.plane.retire(req.service_id)
                elif isinstance(req, Heartbeat):
                    self.plane.heartbeat(req.service_id, req.client)
                else:
                    raise TypeError(f"unknown request {req!r}")
            except (RuntimeError, ValueError, KeyError) as exc:
                self.rejections.append((getattr(req, "service_id", None),
                                        f"{type(exc).__name__}: {exc}"))

    def _tick_blocking(self) -> Decision:
        if self._solver_delay_s:
            time.sleep(self._solver_delay_s)
        return self.plane.tick()

    async def step_period(self) -> Decision:
        """Serve one decision.  Launches a solve when idle; if the pending
        solve outruns ``solver_timeout_s`` (or a chaos-injected deadline
        miss fires), serves a stale decision instead and leaves the solve to
        commit in the background.  A stale streak is bounded: after
        ``max_stale_streak`` consecutive non-fresh periods the daemon stops
        rescaling an ever-older clear and degrades to the O(1) equal-share
        decision (counted in ``degraded_decisions``, flagged distinctly)."""
        decision = None
        if self._force_stale_next:
            # Deterministic deadline miss: the solve is not even launched
            # this period, so no background commit races the stale serve --
            # the whole trajectory stays replayable from the chaos seed.
            self._force_stale_next = False
        else:
            if self._pending is None:
                self._drain()
                loop = asyncio.get_running_loop()
                self._pending = loop.run_in_executor(
                    None, self._tick_blocking)
            try:
                decision = await asyncio.wait_for(
                    asyncio.shield(self._pending), self.solver_timeout_s)
                self._pending = None
                self.stale_streak = 0
                if self.manager and self.plane.period % self.save_every == 0:
                    self.plane.snapshot(self.manager)
            except asyncio.TimeoutError:
                pass
        if decision is None:
            self.stale_streak += 1
            if self.stale_streak >= self.max_stale_streak:
                decision = self.plane.degraded_decision()
            else:
                decision = self.plane.stale_decision()
        self.served.append(decision)
        return decision

    async def close(self) -> None:
        """Let any in-flight solve commit, then take a final checkpoint."""
        if self._pending is not None:
            await self._pending
            self._pending = None
        if self.manager:
            self.plane.snapshot(self.manager)

    async def serve(self, n_periods: int,
                    period_interval_s: float = 0.0) -> list[Decision]:
        for _ in range(n_periods):
            await self.step_period()
            if period_interval_s:
                await asyncio.sleep(period_interval_s)
        await self.close()
        return self.served


def poisson_admissions(rng: np.random.Generator, rate: float, n_periods: int,
                       k_max: int) -> dict[int, list[Admit]]:
    """Synthetic workload: per-period Poisson(rate) admissions with uniform
    cohort sizes, ids ``svc-<period>-<i>``."""
    out: dict[int, list[Admit]] = {}
    for p in range(n_periods):
        n_new = int(rng.poisson(rate))
        if n_new:
            out[p] = [
                Admit(f"svc-{p}-{i}", int(rng.integers(2, k_max + 1)))
                for i in range(n_new)
            ]
    return out


async def _run_workload(daemon: AllocDaemon,
                        workload: dict[int, list[Admit]],
                        n_periods: int) -> list[Decision]:
    for p in range(n_periods):
        for req in workload.get(p, ()):
            daemon.submit(req)
        await daemon.step_period()
    await daemon.close()
    return daemon.served


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--capacity", type=int, default=16)
    ap.add_argument("--periods", type=int, default=40)
    ap.add_argument("--policy", default="coop")
    ap.add_argument("--k-max", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=200,
                    help="rounds each service needs before departing")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean admissions per period (Poisson)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cold", action="store_true",
                    help="disable warm-started duals (cold solve each period)")
    ap.add_argument("--timeout", type=float, default=None,
                    help="solver deadline in seconds (stale fallback past it)")
    ap.add_argument("--heartbeat-timeout", type=int, default=None,
                    help="periods without a heartbeat before a client drops")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--check", action="store_true",
                    help="differential replay vs simulator.run_scan")
    return ap


def main(argv: list[str] | None = None) -> None:
    args = build_parser().parse_args(argv)
    cfg = ControlPlaneConfig(
        capacity=args.capacity, k_max=args.k_max, policy=args.policy,
        warm_start=not args.cold, rounds_required=args.rounds,
        seed=args.seed, heartbeat_timeout_periods=args.heartbeat_timeout,
    )
    manager = (CheckpointManager(args.checkpoint_dir)
               if args.checkpoint_dir else None)
    daemon = AllocDaemon(cfg, solver_timeout_s=args.timeout, manager=manager,
                         save_every=args.save_every)
    if daemon.resumed:
        print(f"[allocd] resumed at period {daemon.plane.period}")
    workload = poisson_admissions(np.random.default_rng(args.seed),
                                  args.rate, args.periods, args.k_max)
    t0 = time.perf_counter()
    served = asyncio.run(_run_workload(daemon, workload, args.periods))
    dt = time.perf_counter() - t0
    m = daemon.plane.metrics
    print(f"[allocd] served {len(served)} decisions in {dt:.2f}s "
          f"({len(served) / max(dt, 1e-9):.1f}/s)")
    print(f"[allocd] admitted={m['admitted']} retired={m['retired']} "
          f"rejected={m['rejected'] + len(daemon.rejections)} "
          f"stale_decisions={m['stale_decisions']} "
          f"heartbeat_drops={m['heartbeat_drops']}")
    # Degradation counters -- all zero on a healthy run, and none of them is
    # ever silent (ISSUE 8): solver fallbacks, equal-share degradations,
    # non-finite catches, carry repairs, skipped checkpoints, admit retries.
    print(f"[allocd] solver_fallbacks={m['solver_fallbacks']} "
          f"degraded_decisions={m['degraded_decisions']} "
          f"nonfinite_decisions={m['nonfinite_decisions']} "
          f"carry_repairs={m['carry_repairs']} "
          f"checkpoint_skips={m['checkpoint_skips']} "
          f"admit_retries={m['admit_retries']}")
    if args.check:
        if not daemon.plane.replayable:
            reasons = (daemon.plane.unreplayable_reasons
                       or ["slot reuse / forced retire"])
            print(f"[allocd] trace not replayable ({reasons})")
            return
        ref = daemon.plane.replay_reference()
        b_ref = np.asarray(ref["history"]["b"])
        b_live = np.stack([d.b for d in daemon.plane.decisions])
        n = min(len(b_live), len(b_ref))
        exact = bool(np.array_equal(b_live[:n], b_ref[:n]))
        print(f"[allocd] replay check over {n} periods: "
              f"{'bitwise equal' if exact else 'MISMATCH'}")


if __name__ == "__main__":
    main()
