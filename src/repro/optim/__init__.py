"""Pure-pytree optimizers (no optax in the image): AdamW, SGD+momentum,
cosine/linear LR schedules, global-norm clipping."""
from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    linear_warmup_cosine,
    sgd,
)
