"""Minimal pure-pytree optimizer library (the image has no optax).

Each optimizer is an (init, update) pair closed over hyperparameters;
``update(grads, state, params)`` returns (new_params, new_state).  All state
lives in a flat NamedTuple-of-pytrees so it shards/checkpoints like params.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Pytree          # first moment / momentum
    nu: Pytree | None   # second moment (None for SGD)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1.0 - final_frac) * cos)
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)
    def lr(step):
        warm = base_lr * (step + 1) / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return lr


def adamw(
    lr: float | Callable = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: float = 0.0,
    moment_dtype=jnp.float32,
):
    """moment_dtype=bfloat16 halves optimizer-state HBM (the update math still
    runs in fp32; only the stored moments round) -- the memory-fit lever for
    the 200B+ train cells (EXPERIMENTS.md §Perf)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params: Pytree) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=moment_dtype), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                        nu=jax.tree.map(jnp.copy, zeros))

    def update(grads: Pytree, state: OptState, params: Pytree):
        if max_grad_norm > 0.0:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
            nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = mu_n / c1
            vhat = nu_n / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                    mu_n.astype(moment_dtype), nu_n.astype(moment_dtype))

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step=step, mu=new_mu, nu=new_nu)

    return init, update


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0,
        max_grad_norm: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params: Pytree) -> OptState:
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params) \
            if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)

    def update(grads: Pytree, state: OptState, params: Pytree):
        if max_grad_norm > 0.0:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr_t = lr_fn(step)
        if momentum:
            new_mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads
            )
            new_params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
                params, new_mu,
            )
            return new_params, OptState(step=step, mu=new_mu, nu=None)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return new_params, OptState(step=step, mu=None, nu=None)

    return init, update
