"""Flash-decoding attention for serve_step: one query token per sequence
against a long KV cache.

Grid: (B, Hkv, S_blocks) -- the cache-length dimension innermost with
online-softmax scratch accumulators, so VMEM holds only one (BK, D) K/V tile
at a time regardless of context length (the 500k-decode cells depend on
this).  The G=Hq/Hkv query heads sharing a kv head are processed together:
the score matmul is (G, D) x (D, BK), which keeps the MXU busy even at G=4.

A dynamic ``valid_len`` masks the unwritten cache tail; blocks entirely past
valid_len are skipped (decode cost scales with the *filled* cache, not the
allocation).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BK = 512


def _decode_kernel(
    valid_ref,                       # SMEM (1,)
    q_ref, k_ref, v_ref,             # (1, 1, G, D), (1, BK, 1, D), (1, BK, 1, D)
    o_ref,                           # (1, 1, G, D)
    acc_ref, m_ref, l_ref,           # scratch (G, D), (G, 1), (G, 1)
    *,
    bk: int,
):
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)
    valid_len = valid_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    g = q_ref.shape[2]
    d = q_ref.shape[3]

    @pl.when(ki * bk < valid_len)
    def _compute():
        q = q_ref[...].astype(jnp.float32).reshape(g, d)
        k = k_ref[...].astype(jnp.float32).reshape(bk, d)
        v = v_ref[...].astype(jnp.float32).reshape(bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / math.sqrt(d)                                       # (G, BK)
        cols = jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1) + ki * bk
        s = jnp.where(cols < valid_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype).reshape(1, 1, g, d)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,          # (B, Hq, D) -- one token per sequence
    k: jax.Array,          # (B, S, Hkv, D) -- cache layout
    v: jax.Array,
    valid_len: jax.Array,  # () int32: filled cache length
    *,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bk = min(block_k, s)
    assert s % bk == 0, (s, bk)

    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, s // bk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, hi, ki: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.reshape(valid_len, (1,)).astype(jnp.int32), qg, k, v)
    return out.reshape(b, hq, d)
