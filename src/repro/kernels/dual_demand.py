"""Fused dual-demand evaluation as a Pallas TPU kernel -- one launch per
DISBA dual iteration.

Market clearing (cooperative DISBA, paper §IV) repeatedly evaluates the
aggregate demand D(lam) = sum_n b*_n(lam): each evaluation solves the Eq. 14
stationarity condition

    (1 + f) * sum_k alpha_k / (1 - t^C_k f)^2 = 1 / lam

for every service's frequency f, then maps f -> bandwidth via Eq. 7.  The
reference path materializes ~48 masked (N, K) array sweeps per evaluation; at
one evaluation per dual iteration of every period of every vmapped episode
this dominates the long-term simulation's allocation cost.

This kernel is the fused fast path: a (TILE_N, K) tile runs the whole
fixed-trip price->frequency bisection in VMEM/VREGs and emits BOTH the
per-service demand b_n(lam) and its closed-form slope db_n/dlam (Lemma 1 /
Eqns. 9-10 via psi(f) = f'/(1+f)) in a single launch, so a safeguarded-Newton
dual iteration (``disba.solve_lambda_newton_warm``) is one kernel call
instead of ~48 jnp sweeps.  Zero HBM traffic beyond the initial tile load --
compute-bound on the VPU like its sibling ``bisect_alloc``.

Tiling/padding conventions match ``bisect_alloc``: padded client slots carry
alpha = 0 (zero contribution to every sum), K is padded to the 128-lane
multiple, N to the tile.  Rows with sum(alpha) = 0 (inactive fixed-capacity
slots) and opted-out providers (lam >= p_max = 1/sum(alpha)) emit
b = slope = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 8
NEG_INF = -1e30
TINY = 1e-30
F_CEIL = 1.0 - 1e-6  # stay strictly inside the 1 - tC*f > 0 region (Eq. 14)


def demand_slope_tile(alpha, tcomp, lam, iters: int):
    """Per-row (demand, slope) for one (TN, K) tile at price(s) ``lam``.

    The in-VMEM home of the fused Eq. 14 price->frequency bisection plus the
    Lemma 1 / Eqns. 9-10 closed-form slope.  ``lam`` may be a (TN, 1) column
    (one price per row, the ``dual_demand`` launch shape) or a scalar (the
    ``market_clear`` megakernel broadcasts the current dual iterate over
    every tile).  Shared by both kernels so the per-row arithmetic is
    bitwise-identical between the per-evaluation and whole-solve launches.
    """
    valid = alpha > 0.0

    asum = jnp.sum(alpha, axis=1, keepdims=True)                 # (TN, 1)
    tcmax = jnp.max(jnp.where(valid, tcomp, NEG_INF), axis=1, keepdims=True)
    active = asum > 0.0
    # f_max = 1 / max_k t^C; inactive rows get a degenerate [0, 0] bracket.
    f_hi = jnp.where(active, F_CEIL / jnp.maximum(tcmax, TINY), 0.0)
    target = 1.0 / jnp.maximum(lam, TINY)

    def body(_, carry):
        lo, hi = carry
        f = 0.5 * (lo + hi)
        one_m = jnp.maximum(1.0 - tcomp * f, TINY)
        lhs = (1.0 + f) * jnp.sum(alpha / (one_m * one_m), axis=1,
                                  keepdims=True)
        go_right = (target - lhs) > 0.0          # LHS increasing in f
        return jnp.where(go_right, f, lo), jnp.where(go_right, hi, f)

    lo, hi = jax.lax.fori_loop(0, iters, body, (jnp.zeros_like(f_hi), f_hi))
    f = 0.5 * (lo + hi)

    # Providers opt out (demand 0) at/above p_max = f*'(0) = 1/sum(alpha).
    p_max = jnp.where(active, 1.0 / jnp.maximum(asum, TINY), 0.0)
    f = jnp.where(lam >= p_max, 0.0, f)

    one_m = jnp.maximum(1.0 - tcomp * f, TINY)
    s2 = jnp.sum(alpha / (one_m * one_m), axis=1, keepdims=True)
    s3 = jnp.sum(alpha * tcomp / (one_m * one_m * one_m), axis=1,
                 keepdims=True)
    b = jnp.sum(alpha * f / one_m, axis=1, keepdims=True)        # Eq. 7 in f

    # Closed-form slope: db/dlam = b'(f) / psi'(f) with b' = 1/f*' (Eq. 8),
    # psi(f) = f*'/(1+f) (Eq. 13), f*'/f*'' from Eqns. 9-10 and the chain
    # rule d(f*')/df = f*''/f*'.
    fp = 1.0 / jnp.maximum(s2, TINY)
    fpp = -2.0 * s3 / jnp.maximum(s2, TINY) ** 3
    psi_p = (fpp * (1.0 + f) / fp - fp) / (1.0 + f) ** 2
    slope = jnp.where(f > 0.0, (1.0 / fp) / psi_p, 0.0)
    return b, slope


def _dual_demand_kernel(alpha_ref, tcomp_ref, lam_ref, b_ref, slope_ref, *,
                        iters: int):
    b, slope = demand_slope_tile(alpha_ref[...], tcomp_ref[...], lam_ref[...],
                                 iters)
    b_ref[...] = b
    slope_ref[...] = slope


@functools.partial(jax.jit, static_argnames=("iters", "tile_n", "interpret"))
def dual_demand(
    alpha: jax.Array,    # (N, K) f32, 0 at padded client slots
    t_comp: jax.Array,   # (N, K) f32
    lam: jax.Array,      # scalar or (N,) f32 dual price
    *,
    iters: int = 48,
    tile_n: int = TILE_N,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (b (N,), db/dlam (N,)) -- per-service demand and slope."""
    n, k = alpha.shape
    lam = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (n,))
    # pad N to the tile and K to the lane width
    k_pad = (k + 127) // 128 * 128
    n_pad = (n + tile_n - 1) // tile_n * tile_n
    if (n_pad, k_pad) != (n, k):
        alpha = jnp.pad(alpha, ((0, n_pad - n), (0, k_pad - k)))
        t_comp = jnp.pad(t_comp, ((0, n_pad - n), (0, k_pad - k)))
        lam = jnp.pad(lam, (0, n_pad - n), constant_values=1.0)

    grid = (n_pad // tile_n,)
    b, slope = pl.pallas_call(
        functools.partial(_dual_demand_kernel, iters=iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha.astype(jnp.float32), t_comp.astype(jnp.float32),
      lam.astype(jnp.float32)[:, None])
    return b[:n, 0], slope[:n, 0]
