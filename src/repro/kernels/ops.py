"""Jit'd public wrappers for the Pallas kernels with automatic backend
dispatch: compiled Pallas on TPU, interpret mode when explicitly requested
(tests), pure-jnp reference otherwise (CPU dry-run lowering uses the refs so
the HLO stays portable).

Backend selection is centralized in ``_resolve_backend``: every op shares
one gate instead of repeating the ``interpret or not _on_tpu()`` dance.
Setting ``REPRO_FORCE_PALLAS=1`` in the environment forces the Pallas path
everywhere (interpret mode off-TPU), so CPU CI can exercise every kernel's
interpret lowering deterministically without touching call sites.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.bisect_alloc import bisect_alloc
from repro.kernels.decode_attention import decode_attention
from repro.kernels.dual_demand import dual_demand as dual_demand_pallas
from repro.kernels.flash_attention import flash_attention
from repro.kernels.market_clear import market_clear as market_clear_pallas
from repro.kernels.market_clear import mbdf_demand as mbdf_demand_pallas
from repro.kernels.mlstm_chunk import mlstm_chunk

FORCE_PALLAS_ENV = "REPRO_FORCE_PALLAS"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_pallas() -> bool:
    return os.environ.get(FORCE_PALLAS_ENV, "").strip() not in ("", "0")


def _resolve_backend(use_pallas: bool | None, interpret: bool) -> tuple[bool, bool]:
    """The single home of the dispatch rule -> (use_kernel, interpret).

    * ``use_pallas=None`` (auto): kernel on TPU, reference elsewhere --
      unless ``REPRO_FORCE_PALLAS`` is set, which forces the kernel path.
    * ``use_pallas=True/False``: explicit caller override.
    * Off-TPU the kernel always runs in interpret mode (there is no Mosaic
      lowering to run), regardless of the ``interpret`` argument.
    """
    if use_pallas is None:
        use = _on_tpu() or _force_pallas()
    else:
        use = use_pallas
    return use, interpret or not _on_tpu()


def attention(q, k, v, *, causal=True, window=0, use_pallas=None, interpret=False):
    use, interpret = _resolve_backend(use_pallas, interpret)
    if use:
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def attention_decode(q, k, v, valid_len, *, use_pallas=None, interpret=False):
    use, interpret = _resolve_backend(use_pallas, interpret)
    if use:
        return decode_attention(q, k, v, valid_len, interpret=interpret)
    return ref.decode_attention_ref(q, k, v, valid_len)


def intra_allocate(alpha, t_comp, b, *, use_pallas=None, interpret=False, iters=48):
    use, interpret = _resolve_backend(use_pallas, interpret)
    if use:
        return bisect_alloc(alpha, t_comp, b, iters=iters, interpret=interpret)
    return ref.bisect_alloc_ref(alpha, t_comp, b, iters=iters)


def dual_demand(alpha, t_comp, lam, *, use_pallas=None, interpret=False, iters=48):
    """Per-service demand b_n(lam) and closed-form slope db_n/dlam in one
    fused evaluation -- the inner op of a warm-started DISBA dual iteration."""
    use, interpret = _resolve_backend(use_pallas, interpret)
    if use:
        return dual_demand_pallas(alpha, t_comp, lam, iters=iters,
                                  interpret=interpret)
    return ref.dual_demand_ref(alpha, t_comp, lam, iters=iters)


def market_clear(alpha, t_comp, b_total, lam_prev, *, use_pallas=None,
                 interpret=False, iters=6, inner_iters=48,
                 newton_inner_iters=24):
    """The whole safeguarded-Newton market clear in ONE launch -> (b, f, lam).

    The kernel keeps the (N, K) service tensors resident in VMEM across the
    entire fixed-trip dual iteration (see kernels/market_clear.py); the
    fallback delegates to the reference ``disba.solve_lambda_newton_warm``
    itself, so ``use_pallas=False`` is bitwise the reference solver."""
    use, interpret = _resolve_backend(use_pallas, interpret)
    if use:
        return market_clear_pallas(alpha, t_comp, b_total, lam_prev,
                                   iters=iters, inner_iters=inner_iters,
                                   newton_inner_iters=newton_inner_iters,
                                   interpret=interpret)
    return ref.market_clear_ref(alpha, t_comp, b_total, lam_prev, iters=iters,
                                inner_iters=inner_iters,
                                newton_inner_iters=newton_inner_iters)


def mbdf_demand(alpha, t_comp, prices, alpha_fair, *, use_pallas=None,
                interpret=False, iters=48):
    """Auction joint (N, M) modified-BDF demand grid on the market tiling."""
    use, interpret = _resolve_backend(use_pallas, interpret)
    if use:
        return mbdf_demand_pallas(alpha, t_comp, prices, alpha_fair,
                                  iters=iters, interpret=interpret)
    return ref.mbdf_demand_ref(alpha, t_comp, prices, alpha_fair, iters=iters)


def mlstm(q, k, v, i_gate, f_gate, *, chunk=128, use_pallas=None, interpret=False):
    use, interpret = _resolve_backend(use_pallas, interpret)
    if use:
        return mlstm_chunk(q, k, v, i_gate, f_gate, chunk=chunk,
                           interpret=interpret)
    return ref.mlstm_chunk_ref(q, k, v, i_gate, f_gate)
