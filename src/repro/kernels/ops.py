"""Jit'd public wrappers for the Pallas kernels with automatic backend
dispatch: compiled Pallas on TPU, interpret mode when explicitly requested
(tests), pure-jnp reference otherwise (CPU dry-run lowering uses the refs so
the HLO stays portable)."""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.bisect_alloc import bisect_alloc
from repro.kernels.decode_attention import decode_attention
from repro.kernels.dual_demand import dual_demand as dual_demand_pallas
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=0, use_pallas=None, interpret=False):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret or not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def attention_decode(q, k, v, valid_len, *, use_pallas=None, interpret=False):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return decode_attention(q, k, v, valid_len,
                                interpret=interpret or not _on_tpu())
    return ref.decode_attention_ref(q, k, v, valid_len)


def intra_allocate(alpha, t_comp, b, *, use_pallas=None, interpret=False, iters=48):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return bisect_alloc(alpha, t_comp, b, iters=iters,
                            interpret=interpret or not _on_tpu())
    return ref.bisect_alloc_ref(alpha, t_comp, b, iters=iters)


def dual_demand(alpha, t_comp, lam, *, use_pallas=None, interpret=False, iters=48):
    """Per-service demand b_n(lam) and closed-form slope db_n/dlam in one
    fused evaluation -- the inner op of a warm-started DISBA dual iteration."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return dual_demand_pallas(alpha, t_comp, lam, iters=iters,
                                  interpret=interpret or not _on_tpu())
    return ref.dual_demand_ref(alpha, t_comp, lam, iters=iters)


def mlstm(q, k, v, i_gate, f_gate, *, chunk=128, use_pallas=None, interpret=False):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return mlstm_chunk(q, k, v, i_gate, f_gate, chunk=chunk,
                           interpret=interpret or not _on_tpu())
    return ref.mlstm_chunk_ref(q, k, v, i_gate, f_gate)
