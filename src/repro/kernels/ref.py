"""Pure-jnp oracles for every Pallas kernel (the `ref.py` of each kernel).

These are deliberately naive/direct implementations used only for
correctness testing via assert_allclose in interpret mode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q (B,Hq,S,D), k/v (B,Hkv,S,D) -> (B,Hq,S,D).  Materialized softmax."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k).astype(jnp.float32) / math.sqrt(d)
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= rows >= cols
    if window > 0:
        mask &= rows - cols < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(b, hq, sq, d)


def decode_attention_ref(q, k, v, valid_len):
    """q (B,Hq,D); k/v (B,S,Hkv,D); valid_len () or (B,) -> (B,Hq,D)."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k).astype(jnp.float32) / math.sqrt(d)
    valid = jnp.arange(s)[None, :] < jnp.reshape(valid_len, (-1, 1))
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v)
    return out.reshape(b, hq, d)


def bisect_alloc_ref(alpha, t_comp, b, iters: int = 48):
    """Oracle for the intra-service allocation kernel: delegates to the core
    solver (itself pure jnp, property-tested against KKT conditions)."""
    from repro.core import intra
    from repro.core.types import ServiceSet

    mask = alpha > 0
    svc = ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask)
    t_star = intra.solve_round_time(svc, b, iters)
    b_alloc = intra.client_allocation(svc, b, iters)
    return t_star, b_alloc


def dual_demand_ref(alpha, t_comp, lam, iters: int = 48):
    """Oracle for the fused dual-demand kernel: the Eq. 14 price->frequency
    solve plus closed-form demand slope, delegated to the core solver so the
    slope formula has exactly one jnp home (``disba.demand_slope_values``)."""
    from repro.core import disba
    from repro.core.types import ServiceSet

    mask = alpha > 0
    svc = ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask)
    return disba.demand_slope_values(svc, lam, iters)


def market_clear_ref(alpha, t_comp, b_total, lam_prev, iters: int = 6,
                     inner_iters: int = 48, newton_inner_iters: int = 24):
    """Oracle for the whole-market megakernel: delegates to the reference
    ``disba.solve_lambda_newton_warm`` itself, so the CPU fallback of
    ``ops.market_clear`` is *bitwise* the reference solver (the kernel path
    is exact-to-dtype against this)."""
    from repro.core import disba
    from repro.core.types import ServiceSet

    mask = alpha > 0
    svc = ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask)
    res = disba.solve_lambda_newton_warm(
        svc, b_total, lam_prev, iters=iters, inner_iters=inner_iters,
        newton_inner_iters=newton_inner_iters, backend="reference")
    return res.b, res.f, res.lam


def mbdf_demand_ref(alpha, t_comp, prices, alpha_fair, iters: int = 48):
    """Oracle for the (N, M) mbdf grid kernel: delegates to the core joint
    bisection (``fairness.mbdf_grid``, itself bitwise-equal to the vmap of
    per-column solves)."""
    from repro.core import fairness
    from repro.core.types import ServiceSet

    mask = alpha > 0
    svc = ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask)
    return fairness.mbdf_grid(svc, prices, alpha_fair, iters)


def mlstm_chunk_ref(q, k, v, i_gate, f_gate, chunk=None):
    """Oracle for the chunked mLSTM kernel: the fully-parallel stabilized
    form (exact for any chunking)."""
    from repro.models import ssm

    y, _, _ = ssm.mlstm_parallel(q, k, v, i_gate, f_gate)
    return y
