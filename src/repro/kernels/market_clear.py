"""Whole-market clearing as ONE Pallas launch: the complete safeguarded-
Newton dual iteration of ``disba.solve_lambda_newton_warm`` fused end to end.

PR 3's ``dual_demand`` kernel fused one dual *evaluation*: the solver still
launched once per Newton trip (<= 6 warm / ~12 cold), round-tripping the
(N, K) service tensors through HBM between trips.  At the 1024-8192-service
markets the ROADMAP targets those re-loads dominate: each trip re-streams
N*K*8 bytes to recompute a pair of scalars.  This kernel runs the *entire*
solve in one launch -- the (N, K) alpha/t_comp tensors are loaded into VMEM
once (8192 x 128 f32 pairs = 8 MB, inside the ~16 MB/core budget) and an
internal ``fori_loop`` over row tiles performs, per Newton trip:

  1. per-service demand b_n(lam) + closed-form slope db_n/dlam
     (``demand_slope_tile`` -- the same in-VMEM tile function the
     ``dual_demand`` kernel launches, so per-row arithmetic is shared);
  2. the aggregate reduction D(lam) = sum_n b_n, D'(lam) (scalar accumulators
     across tiles);
  3. the dual update with bisection safeguard -- bit-for-bit the reference
     solver's step: bracket fold, Newton step, midpoint fallback.

A final pass re-evaluates demand at the full ``inner_iters`` trip count,
projects onto sum b = B, and solves the Eq. 7 round time per service so the
launch emits the complete ``(b, f, lam)`` clearing result.  HBM traffic is
one load of the service tensors plus the (N,) outputs -- independent of the
trip count -- versus one full reload *per trip* for the launch-per-iteration
path.

Aggregate sums accumulate tile-sequentially, so final lam/b/f match the
reference solver exact-to-dtype (PR 3's convention; see
tests/test_market_clear.py), not bitwise; the bitwise fallback is
``ops.market_clear(use_pallas=False)`` -> ``ref.market_clear_ref`` which
delegates to the reference solver itself.

``mbdf_demand`` moves the auction's joint (N, M) ``fairness.mbdf_grid``
bisection onto the same tiling conventions: grid (n_tiles, M), each launch
step solving one (TILE_N, 1) price column against its (TILE_N, K) service
tile (the tile is re-used across the M consecutive grid steps, so services
stream from HBM once, not M times).

Padding conventions match ``bisect_alloc``/``dual_demand``: padded client
slots carry alpha = 0, K pads to the 128-lane multiple, N to the tile.
Inactive rows (sum alpha = 0) demand nothing at any price and emit
b = f = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dual_demand import (
    F_CEIL, NEG_INF, TINY, demand_slope_tile,
)

TILE_N = 128      # row tile of the megakernel's internal loop
TILE_N_MBDF = 8   # row tile of the (N, M) mbdf grid kernel


def _freq_tile(alpha, tcomp, b, iters: int):
    """Eq. 7 round time -> frequency for one (TN, K) tile at bandwidth b.

    Mirrors ``intra.solve_round_time``'s arithmetic exactly (bisection on
    u = t - max_k t^C with the hoisted gap masking) so the megakernel's final
    f matches the reference solver's ``intra.freq`` to dtype.
    """
    valid = alpha > 0.0
    asum = jnp.sum(alpha, axis=1, keepdims=True)                 # (TN, 1)
    tcmax = jnp.max(jnp.where(valid, tcomp, NEG_INF), axis=1, keepdims=True)
    u_hi = asum / jnp.maximum(b, TINY)
    gap = jnp.where(valid, tcmax - tcomp, 1.0)                   # (TN, K)

    def body(_, carry):
        lo, hi = carry
        u = 0.5 * (lo + hi)
        val = jnp.sum(alpha / (u + gap), axis=1, keepdims=True) - b
        go_right = val > 0.0
        return jnp.where(go_right, u, lo), jnp.where(go_right, hi, u)

    lo, hi = jax.lax.fori_loop(0, iters, body, (jnp.zeros_like(u_hi), u_hi))
    t_star = tcmax + 0.5 * (lo + hi)
    return jnp.where(b > 0.0, 1.0 / t_star, 0.0)


def _market_clear_kernel(alpha_ref, tcomp_ref, btot_ref, lamprev_ref,
                         b_ref, f_ref, lam_ref, *,
                         iters: int, inner_iters: int,
                         newton_inner_iters: int, tile_n: int, n_tiles: int):
    b_total = btot_ref[0, 0]
    lam_prev = lamprev_ref[0, 0]

    def rows(j):
        return pl.ds(j * tile_n, tile_n)

    # --- bracket top: lam_hi0 = max_n p_max (exact: max is associative) ----
    def pmax_tile(j, acc):
        asum = jnp.sum(alpha_ref[rows(j), :], axis=1)
        p = jnp.where(asum > 0.0, 1.0 / jnp.maximum(asum, TINY), 0.0)
        return jnp.maximum(acc, jnp.max(p))

    lam_hi0 = jax.lax.fori_loop(0, n_tiles, pmax_tile, jnp.float32(0.0))

    # --- warm seed (identical to solve_lambda_newton_warm) -----------------
    warm_ok = jnp.logical_and(lam_prev > 0.0, lam_prev < lam_hi0)
    lam0 = jnp.where(warm_ok, lam_prev, 0.5 * lam_hi0)

    # --- the fixed-trip safeguarded-Newton loop, entirely in VMEM ----------
    def newton(_, state):
        lam, lo, hi = state

        def dtile(j, acc):
            d_acc, s_acc = acc
            b_t, s_t = demand_slope_tile(
                alpha_ref[rows(j), :], tcomp_ref[rows(j), :], lam,
                newton_inner_iters)
            return d_acc + jnp.sum(b_t), s_acc + jnp.sum(s_t)

        d, slope = jax.lax.fori_loop(
            0, n_tiles, dtile, (jnp.float32(0.0), jnp.float32(0.0)))
        resid = d - b_total
        lo = jnp.where(resid > 0, lam, lo)   # demand too high -> raise price
        hi = jnp.where(resid > 0, hi, lam)
        step = resid / jnp.where(jnp.abs(slope) > TINY, slope, -TINY)
        lam_newton = lam - step
        # Non-strict bounds, matching the reference: a converged iterate
        # reproduces itself instead of bouncing to the midpoint.
        in_bracket = jnp.logical_and(lam_newton >= lo, lam_newton <= hi)
        lam_next = jnp.where(in_bracket, lam_newton, 0.5 * (lo + hi))
        return lam_next, lo, hi

    lam, _, _ = jax.lax.fori_loop(
        0, iters, newton, (lam0, jnp.float32(0.0), lam_hi0))

    # --- final demand at the full inner trip count + aggregate -------------
    def demand_tile(j, total):
        b_t, _ = demand_slope_tile(
            alpha_ref[rows(j), :], tcomp_ref[rows(j), :], lam, inner_iters)
        b_ref[rows(j), :] = b_t
        return total + jnp.sum(b_t)

    total = jax.lax.fori_loop(0, n_tiles, demand_tile, jnp.float32(0.0))

    # --- project onto sum b = B, then Eq. 7 round time -> f ----------------
    scale = b_total / jnp.maximum(total, TINY)

    def finish_tile(j, carry):
        b_t = b_ref[rows(j), :] * scale
        b_ref[rows(j), :] = b_t
        f_ref[rows(j), :] = _freq_tile(
            alpha_ref[rows(j), :], tcomp_ref[rows(j), :], b_t, inner_iters)
        return carry

    jax.lax.fori_loop(0, n_tiles, finish_tile, jnp.float32(0.0))
    lam_ref[0, 0] = lam


@functools.partial(jax.jit, static_argnames=("iters", "inner_iters",
                                             "newton_inner_iters", "tile_n",
                                             "interpret"))
def market_clear(
    alpha: jax.Array,     # (N, K) f32, 0 at padded client slots
    t_comp: jax.Array,    # (N, K) f32
    b_total: jax.Array,   # () f32 bandwidth budget B
    lam_prev: jax.Array,  # () f32 previous dual price (<= 0: cold seed)
    *,
    iters: int = 6,
    inner_iters: int = 48,
    newton_inner_iters: int = 24,
    tile_n: int = TILE_N,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused launch of the whole market clear.  Returns (b (N,), f (N,),
    lam ())."""
    n, k = alpha.shape
    k_pad = (k + 127) // 128 * 128
    n_pad = (n + tile_n - 1) // tile_n * tile_n
    if (n_pad, k_pad) != (n, k):
        alpha = jnp.pad(alpha, ((0, n_pad - n), (0, k_pad - k)))
        t_comp = jnp.pad(t_comp, ((0, n_pad - n), (0, k_pad - k)))
    n_tiles = n_pad // tile_n

    kernel = functools.partial(
        _market_clear_kernel, iters=iters, inner_iters=inner_iters,
        newton_inner_iters=newton_inner_iters, tile_n=tile_n, n_tiles=n_tiles)
    b, f, lam = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha.astype(jnp.float32), t_comp.astype(jnp.float32),
      jnp.asarray(b_total, jnp.float32).reshape(1, 1),
      jnp.asarray(lam_prev, jnp.float32).reshape(1, 1))
    return b[:n, 0], f[:n, 0], lam[0, 0]


# ---------------------------------------------------------------------------
# Auction (N, M) joint mbdf bisection on the same tiling conventions.
# ---------------------------------------------------------------------------

def _mbdf_kernel(alpha_ref, tcomp_ref, price_ref, b_ref, *,
                 alpha_fair: float, iters: int):
    alpha = alpha_ref[...]                       # (TN, K)
    tcomp = tcomp_ref[...]                       # (TN, K)
    price = price_ref[...]                       # (TN, 1)
    valid = alpha > 0.0

    asum = jnp.sum(alpha, axis=1, keepdims=True)
    tcmax = jnp.max(jnp.where(valid, tcomp, NEG_INF), axis=1, keepdims=True)
    active = asum > 0.0
    f_hi = jnp.where(active, F_CEIL / jnp.maximum(tcmax, TINY), 0.0)

    def body(_, carry):
        lo, hi = carry
        f = 0.5 * (lo + hi)
        one_m = jnp.maximum(1.0 - tcomp * f, TINY)
        s = jnp.sum(alpha / (one_m * one_m), axis=1, keepdims=True)
        # q(f) = g'(b) at f: [(1-a) + a/(1+f)] * f*'(b)  (Eq. 21 derivative)
        q = ((1.0 - alpha_fair) + alpha_fair / (1.0 + f)) \
            * (1.0 / jnp.maximum(s, TINY))
        go_right = (q - price) > 0.0             # q decreasing in f
        return jnp.where(go_right, f, lo), jnp.where(go_right, hi, f)

    lo, hi = jax.lax.fori_loop(0, iters, body, (jnp.zeros_like(f_hi), f_hi))
    f = 0.5 * (lo + hi)

    p_max = jnp.where(active, 1.0 / jnp.maximum(asum, TINY), 0.0)
    f = jnp.where(price >= p_max, 0.0, f)
    one_m = jnp.maximum(1.0 - tcomp * f, TINY)
    b_ref[...] = jnp.sum(alpha * f / one_m, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("alpha_fair", "iters", "tile_n",
                                             "interpret"))
def mbdf_demand(
    alpha: jax.Array,    # (N, K) f32, 0 at padded client slots
    t_comp: jax.Array,   # (N, K) f32
    prices: jax.Array,   # (N, M) f32 ascending price grid
    alpha_fair: float,
    *,
    iters: int = 48,
    tile_n: int = TILE_N_MBDF,
    interpret: bool = False,
) -> jax.Array:
    """Modified bandwidth demand d_n(p_m) at the whole (N, M) grid -> (N, M).

    Grid (n_tiles, M): the service tile's index map is constant across the M
    consecutive price columns, so each (TILE_N, K) tile streams from HBM once
    for all M joint bisections.
    """
    n, k = alpha.shape
    m = prices.shape[1]
    k_pad = (k + 127) // 128 * 128
    n_pad = (n + tile_n - 1) // tile_n * tile_n
    if (n_pad, k_pad) != (n, k):
        alpha = jnp.pad(alpha, ((0, n_pad - n), (0, k_pad - k)))
        t_comp = jnp.pad(t_comp, ((0, n_pad - n), (0, k_pad - k)))
        prices = jnp.pad(prices, ((0, n_pad - n), (0, 0)), constant_values=1.0)

    out = pl.pallas_call(
        functools.partial(_mbdf_kernel, alpha_fair=alpha_fair, iters=iters),
        grid=(n_pad // tile_n, m),
        in_specs=[
            pl.BlockSpec((tile_n, k_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, k_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile_n, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, m), jnp.float32),
        interpret=interpret,
    )(alpha.astype(jnp.float32), t_comp.astype(jnp.float32),
      prices.astype(jnp.float32))
    return out[:n, :]
