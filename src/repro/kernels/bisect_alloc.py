"""Batched intra-service bandwidth allocation as a Pallas TPU kernel -- the
paper's computational hot-spot at fleet scale.

One launch solves Eq. 7 (sum_k alpha_k/(t - t^C_k) = b_n) for a whole tile of
services via fixed-trip bisection and emits both the optimal round time t*_n
and the per-client water-filling split b_{n,k}.  At production scale the
operator re-solves this for every active service each period (and inside
every DISBA dual iteration), so N reaches 1e5-1e6 service-solves per second
fleet-wide: a (TILE_N, K) tile keeps all 48 bisection trips in VMEM/VREGs
with zero HBM traffic beyond the initial load -- the kernel is compute-bound
on the VPU by design (roofline analysis in EXPERIMENTS.md §Perf).

Padding convention: padded client slots carry alpha = 0 (they contribute 0 to
every sum and -inf to the t^C max).  K is padded to a lane multiple (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 8
NEG_INF = -1e30
TINY = 1e-30


def _bisect_kernel(alpha_ref, tcomp_ref, b_ref, tstar_ref, balloc_ref, *, iters: int):
    alpha = alpha_ref[...]                       # (TN, K)
    tcomp = tcomp_ref[...]                       # (TN, K)
    b = b_ref[...]                               # (TN, 1)
    valid = alpha > 0.0

    tcmax = jnp.max(jnp.where(valid, tcomp, NEG_INF), axis=1, keepdims=True)  # (TN,1)
    asum = jnp.sum(alpha, axis=1, keepdims=True)
    safe_b = jnp.maximum(b, TINY)
    gap = jnp.where(valid, tcmax - tcomp, 0.0)   # >= 0; padded -> 0 but alpha=0

    u_hi = asum / safe_b
    u_lo = jnp.zeros_like(u_hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        denom = mid + gap
        h = jnp.sum(
            jnp.where(valid, alpha / jnp.maximum(denom, TINY), 0.0),
            axis=1, keepdims=True,
        ) - b
        go_right = h > 0.0
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    u_lo, u_hi = jax.lax.fori_loop(0, iters, body, (u_lo, u_hi))
    u = 0.5 * (u_lo + u_hi)
    t_star = tcmax + u

    raw = jnp.where(valid, alpha / jnp.maximum(u + gap, TINY), 0.0)
    total = jnp.maximum(jnp.sum(raw, axis=1, keepdims=True), TINY)
    balloc_ref[...] = raw * (b / total)
    tstar_ref[...] = jnp.where(b > 0.0, t_star, jnp.full_like(t_star, 1.0 / TINY))


@functools.partial(jax.jit, static_argnames=("iters", "tile_n", "interpret"))
def bisect_alloc(
    alpha: jax.Array,    # (N, K) f32, 0 at padded client slots
    t_comp: jax.Array,   # (N, K) f32
    b: jax.Array,        # (N,) f32 per-service bandwidth budget
    *,
    iters: int = 48,
    tile_n: int = TILE_N,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (t_star (N,), b_alloc (N, K))."""
    n, k = alpha.shape
    # pad N to the tile and K to the lane width
    k_pad = (k + 127) // 128 * 128
    n_pad = (n + tile_n - 1) // tile_n * tile_n
    if (n_pad, k_pad) != (n, k):
        alpha = jnp.pad(alpha, ((0, n_pad - n), (0, k_pad - k)))
        t_comp = jnp.pad(t_comp, ((0, n_pad - n), (0, k_pad - k)))
        b = jnp.pad(b, (0, n_pad - n), constant_values=1.0)

    grid = (n_pad // tile_n,)
    t_star, b_alloc = pl.pallas_call(
        functools.partial(_bisect_kernel, iters=iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        ],
        interpret=interpret,
    )(alpha.astype(jnp.float32), t_comp.astype(jnp.float32),
      b.astype(jnp.float32)[:, None])
    return t_star[:n, 0], b_alloc[:n, :k]
