"""Flash attention (causal / sliding-window) as a Pallas TPU kernel.

Grid: (batch*kv_heads, q_blocks, kv_blocks) with the kv dimension innermost;
online-softmax accumulators live in VMEM scratch and persist across kv
iterations (initialized at kv==start, flushed at kv==end).  Causal and
sliding-window structure prunes the kv range per q block: the kernel only
visits blocks intersecting [q_lo - window + 1, q_hi], which is what makes the
sliding-window archs (gemma3, hymba) O(S*W) instead of O(S^2).

GQA layout: q is (B, Hkv, G, S, D) -- G query heads share one kv head; the
kernel computes all G at once per kv head, amortizing the k/v loads (the MXU
matmul is (G*BQ, D) x (D, BK), hardware-aligned for D in {64, 128, 256}).

VMEM budget per step (f32): q (G*BQ*D) + k,v (2*BK*D) + acc (G*BQ*D)
+ scores (G*BQ*BK); with BQ=BK=128, G<=8, D<=256 that is ~1.5 MB -- far under
the ~16 MB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # (1, G, BQ, D), (1, BK, D), (1, BK, D)
    o_ref,                          # (1, G, BQ, D)
    acc_ref, m_ref, l_ref,          # scratch: (G*BQ, D), (G*BQ, 1), (G*BQ, 1)
    *,
    scale: float,
    causal: bool,
    window: int,
    bq: int,
    bk: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    g = q_ref.shape[1]
    d = q_ref.shape[3]
    g_bq = g * bq
    # absolute positions: row r of the flattened (G, BQ) block is query
    # qi*bq + (r % bq); columns are ki*bk + arange(bk)
    rows = jax.lax.broadcasted_iota(jnp.int32, (g_bq, bk), 0) % bq + qi * bq
    cols = jax.lax.broadcasted_iota(jnp.int32, (g_bq, bk), 1) + ki * bk

    mask = cols < kv_len
    if causal:
        mask &= rows >= cols
    if window > 0:
        mask &= rows - cols < window

    def _compute():
        q = q_ref[...].astype(jnp.float32).reshape(g_bq, d)
        k = k_ref[...].astype(jnp.float32).reshape(bk, d)
        v = v_ref[...].astype(jnp.float32).reshape(bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                             # (G*BQ, BK)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal or window > 0:
        # prune fully-masked blocks: need ki*bk <= q_hi and (window)
        # ki*bk + bk - 1 >= q_lo - window + 1
        q_lo = qi * bq
        q_hi = qi * bq + bq - 1
        live = (ki * bk) <= q_hi
        if window > 0:
            live &= (ki * bk + bk - 1) >= (q_lo - window + 1)
        live_ = live

        @pl.when(live_)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype).reshape(1, g, bq, d)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,        # (B, Hq, Sq, D)
    k: jax.Array,        # (B, Hkv, Skv, D)
    v: jax.Array,        # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    scale = 1.0 / math.sqrt(d)

    # (B*Hkv, G, Sq, D) -> blocks flattened to (G*BQ, D)
    qg = q.reshape(b, hkv, g, sq, d).reshape(b * hkv, g, sq, d)
    kg = k.reshape(b * hkv, skv, d)
    vg = v.reshape(b * hkv, skv, d)

    grid = (b * hkv, sq // bq, skv // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, kv_len=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, bq, d), lambda bh, qi, ki: (bh, 0, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, bq, d), lambda bh, qi, ki: (bh, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, d), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        qg.reshape(b * hkv, g, sq, d),
        kg, vg,
    )
    return out.reshape(b, hkv, g, sq, d).reshape(b, hq, sq, d)
