"""Chunkwise-parallel mLSTM cell as a Pallas TPU kernel (xLSTM's matrix
memory; also the SSD-style pattern Hymba's recurrent heads follow).

Grid: (B*H, n_chunks) with the chunk dimension innermost; the recurrent state
(C: (Dh, Dh), n: (Dh,), m: ()) lives in VMEM scratch and carries across chunk
iterations -- the kernel is a sequential scan over chunks with O(L^2 + L*Dh)
parallel work per chunk, matching ``repro.models.ssm.mlstm_chunkwise`` (its
pure-jnp oracle) exactly.

Numerics: all gate math in fp32; the decay matrix uses the running-max
stabilizer from the xLSTM paper so exp() never overflows even for long
sequences with saturated forget gates.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(
    q_ref, k_ref, v_ref, i_ref, f_ref,   # (1, L, Dh) x3, (1, L) x2
    y_ref,                               # (1, L, Dh)
    c_ref, n_ref, m_ref,                 # scratch (Dh, Dh), (1, Dh), (1, 1)
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    l = q_ref.shape[1]
    dh = q_ref.shape[2]
    q = q_ref[...].astype(jnp.float32).reshape(l, dh) / math.sqrt(dh)
    k = k_ref[...].astype(jnp.float32).reshape(l, dh)
    v = v_ref[...].astype(jnp.float32).reshape(l, dh)
    ig = i_ref[...].astype(jnp.float32).reshape(1, l)
    fg = f_ref[...].astype(jnp.float32).reshape(1, l)

    logf = jax.nn.log_sigmoid(fg)
    bcum = jnp.cumsum(logf, axis=1)                    # (1, L)
    m_prev = m_ref[0, 0]
    C_prev = c_ref[...]
    n_prev = n_ref[...]

    rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    tri = rows >= cols
    intra_arg = bcum.reshape(l, 1) - bcum.reshape(1, l) + ig.reshape(1, l)
    intra_arg = jnp.where(tri, intra_arg, NEG_INF)
    m_intra = jnp.max(intra_arg, axis=1)               # (L,)
    m_inter = bcum.reshape(l) + m_prev
    m_t = jnp.maximum(jnp.maximum(m_inter, m_intra), NEG_INF)

    g_inter = jnp.exp(m_inter - m_t).reshape(l, 1)
    y_inter = jax.lax.dot_general(
        q, C_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) * g_inter
    n_inter = jax.lax.dot_general(
        q, n_prev.reshape(dh, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * g_inter                                        # (L, 1)

    dexp = jnp.exp(intra_arg - m_t.reshape(l, 1))
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    w = scores * dexp
    y_intra = jax.lax.dot_general(
        w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_intra = jnp.sum(w, axis=1, keepdims=True)
    denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t).reshape(l, 1))
    y_ref[...] = ((y_inter + y_intra) / denom).astype(y_ref.dtype).reshape(1, l, dh)

    # state to end of chunk
    b_last = bcum[0, l - 1]
    m_new = jnp.maximum(b_last + m_prev,
                        jnp.max(b_last - bcum.reshape(l) + ig.reshape(l)))
    scale_old = jnp.exp(b_last + m_prev - m_new)
    kv_w = jnp.exp(b_last - bcum.reshape(l) + ig.reshape(l) - m_new)  # (L,)
    kw = k * kv_w.reshape(l, 1)
    c_ref[...] = scale_old * C_prev + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_ref[...] = scale_old * n_prev + jnp.sum(kw, axis=0, keepdims=True)
    m_ref[...] = jnp.full_like(m_ref, m_new)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(
    q: jax.Array,       # (B, H, S, Dh)
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,  # (B, H, S)
    f_gate: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, dh = q.shape
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    bh = b * h
    resh3 = lambda x: x.reshape(bh, s, dh)
    resh2 = lambda x: x.reshape(bh, s)
    grid = (bh, s // l)
    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, dh), lambda bh_, ci: (bh_, ci, 0)),
            pl.BlockSpec((1, l, dh), lambda bh_, ci: (bh_, ci, 0)),
            pl.BlockSpec((1, l, dh), lambda bh_, ci: (bh_, ci, 0)),
            pl.BlockSpec((1, l), lambda bh_, ci: (bh_, ci)),
            pl.BlockSpec((1, l), lambda bh_, ci: (bh_, ci)),
        ],
        out_specs=pl.BlockSpec((1, l, dh), lambda bh_, ci: (bh_, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(resh3(q), resh3(k), resh3(v), resh2(i_gate), resh2(f_gate))
    return out.reshape(b, h, s, dh)
