"""Pallas TPU kernels for the framework's compute hot-spots:

  * bisect_alloc     -- batched intra-service water-filling (the paper's
                        fleet-scale hot loop)
  * dual_demand      -- fused price->demand(+slope) evaluation, one launch
                        per warm-started DISBA dual iteration
  * flash_attention  -- causal / sliding-window attention (train + prefill)
  * decode_attention -- flash-decoding vs long KV caches (serve_step)
  * mlstm_chunk      -- chunkwise-parallel mLSTM cell (xlstm / hybrid)

Each kernel has a pure-jnp oracle in ref.py and a dispatching wrapper in
ops.py (compiled on TPU, interpret-mode in tests, ref fallback on CPU so the
512-device dry-run lowers portably).
"""
from repro.kernels import ops, ref  # noqa: F401
