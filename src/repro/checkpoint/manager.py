"""Pytree checkpointing without orbax.

Layout:  <dir>/step_<N>/
            meta.json          # tree structure + shapes + dtypes + checksums
            shard_<i>.npz      # flat leaves, chunked to ~512MB per shard
            COMMIT             # written LAST -> presence marks completeness

Crash-safety: a checkpoint is valid iff COMMIT exists AND every shard matches
the sha256 recorded in ``meta.json`` (silent media corruption of a committed
step is detected, not trusted).  Writes go to a temp dir that is fsynced
(shards, meta, COMMIT, then the directory) and renamed into place, so a
half-written step never shadows an older complete one; a re-save of an
existing step swaps atomically instead of leaving a window with no
checkpoint.  ``restore_latest`` walks newest -> oldest and *skips past* any
step that fails verification (recorded in ``last_skipped``), so one corrupted
checkpoint degrades recovery by ``save_every`` steps instead of crashing the
restart loop.  ``keep`` bounds retention (oldest complete checkpoints pruned
after a new COMMIT).  This is the restart path the FL simulator, the training
driver, and the allocation control plane use for fault tolerance.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

_COMMIT = "COMMIT"
_SHARD_BYTES = 512 * 1024 * 1024


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        # Steps restore_latest had to skip (unverifiable committed
        # checkpoints), refreshed on every restore_latest call.
        self.last_skipped: list[tuple[int, str]] = []
        # A crash mid-save leaves an orphaned temp dir; sweep them so a
        # restart storm cannot accumulate garbage.
        for name in os.listdir(self.directory):
            if name.startswith(".tmp_"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.directory, name, _COMMIT)
            ):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        named = _flatten_with_names(tree)
        treedef = jax.tree.structure(tree)
        final_dir = self._step_dir(step)
        tmp_dir = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
        try:
            shards: list[list[tuple[str, np.ndarray]]] = [[]]
            size = 0
            for name, leaf in named:
                arr = np.asarray(leaf)
                if size + arr.nbytes > _SHARD_BYTES and shards[-1]:
                    shards.append([])
                    size = 0
                shards[-1].append((name, arr))
                size += arr.nbytes
            index = {}
            checksums = {}
            for i, shard in enumerate(shards):
                fname = f"shard_{i:04d}.npz"
                fpath = os.path.join(tmp_dir, fname)
                np.savez(fpath, **{n: a for n, a in shard})
                _fsync_path(fpath)
                checksums[fname] = _sha256(fpath)
                for n, _ in shard:
                    index[n] = fname
            meta = {
                "step": step,
                "treedef": str(treedef),
                "leaf_names": [n for n, _ in named],
                "index": index,
                "shard_checksums": checksums,
                "extra": extra or {},
            }
            meta_path = os.path.join(tmp_dir, "meta.json")
            with open(meta_path, "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            # Commit marker written last inside tmp; fsync it and the tmp
            # directory so the marker is durable before the rename makes the
            # step visible.
            commit_path = os.path.join(tmp_dir, _COMMIT)
            with open(commit_path, "w") as f:
                f.write("ok")
                f.flush()
                os.fsync(f.fileno())
            _fsync_path(tmp_dir)
            if os.path.exists(final_dir):
                # Idempotent re-save of an existing step (restart replaying
                # its last period): swap atomically -- rename the old step
                # aside, the new one in, then drop the old.  rmtree-first
                # would leave a window with no checkpoint at this step.
                aside = final_dir + ".old"
                shutil.rmtree(aside, ignore_errors=True)
                os.rename(final_dir, aside)
                os.rename(tmp_dir, final_dir)
                shutil.rmtree(aside, ignore_errors=True)
            else:
                os.rename(tmp_dir, final_dir)
            _fsync_path(self.directory)
        except BaseException:
            shutil.rmtree(tmp_dir, ignore_errors=True)
            raise
        self._prune()
        return final_dir

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def verify_step(self, step: int) -> tuple[bool, str]:
        """Is the committed checkpoint at ``step`` actually loadable?

        COMMIT present, meta.json parseable, every indexed shard present and
        matching its recorded sha256.  Pre-checksum checkpoints (no
        ``shard_checksums`` in meta) fall back to a load check: each shard
        must at least decompress and contain its indexed leaves.
        """
        step_dir = self._step_dir(step)
        if not os.path.exists(os.path.join(step_dir, _COMMIT)):
            return False, "no COMMIT marker"
        try:
            with open(os.path.join(step_dir, "meta.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError) as exc:
            return False, f"unreadable meta.json ({exc})"
        checksums = meta.get("shard_checksums")
        for fname in sorted(set(meta.get("index", {}).values())):
            fpath = os.path.join(step_dir, fname)
            if not os.path.exists(fpath):
                return False, f"missing shard {fname}"
            if checksums is not None:
                if _sha256(fpath) != checksums.get(fname):
                    return False, f"checksum mismatch on {fname}"
            else:
                try:
                    with np.load(fpath) as payload:
                        names = set(payload.files)
                    for leaf, shard in meta["index"].items():
                        if shard == fname and leaf not in names:
                            return False, f"shard {fname} missing leaf {leaf}"
                except Exception as exc:
                    return False, f"unloadable shard {fname} ({exc})"
        return True, "ok"

    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  Raises on a committed-but-corrupted step."""
        step_dir = self._step_dir(step)
        if not os.path.exists(os.path.join(step_dir, _COMMIT)):
            raise FileNotFoundError(f"no complete checkpoint at step {step}")
        ok, reason = self.verify_step(step)
        if not ok:
            raise IOError(f"checkpoint at step {step} is corrupted: {reason}")
        with open(os.path.join(step_dir, "meta.json")) as f:
            meta = json.load(f)
        cache: dict[str, Any] = {}

        def load(name: str) -> np.ndarray:
            fname = meta["index"][name]
            if fname not in cache:
                cache[fname] = np.load(os.path.join(step_dir, fname))
            return cache[fname][name]

        named_like = _flatten_with_names(like)
        leaves = [load(name) for name, _ in named_like]
        treedef = jax.tree.structure(like)
        restored = jax.tree.unflatten(treedef, leaves)
        return jax.tree.map(
            lambda ref, arr: np.asarray(arr).astype(
                ref.dtype if hasattr(ref, "dtype") else arr.dtype
            ),
            like, restored,
        ), meta["extra"]

    def restore_latest(self, like):
        """(step, tree, extra) from the newest VERIFIABLE checkpoint, or
        (None, like, {}) when none survives -- the auto-resume entry point.

        A committed-but-corrupted newest step (torn shard, bit rot, truncated
        payload behind an intact COMMIT) is skipped, recorded in
        ``last_skipped`` as ``(step, reason)``, and the walk continues to the
        next-older step: one bad checkpoint costs ``save_every`` steps of
        recovery, never the whole job.
        """
        self.last_skipped = []
        for step in reversed(self.all_steps()):
            ok, reason = self.verify_step(step)
            if not ok:
                self.last_skipped.append((step, reason))
                continue
            tree, extra = self.restore(step, like)
            return step, tree, extra
        return None, like, {}
