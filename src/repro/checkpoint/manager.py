"""Pytree checkpointing without orbax.

Layout:  <dir>/step_<N>/
            meta.json          # tree structure + shapes + dtypes + user info
            shard_<i>.npz      # flat leaves, chunked to ~512MB per shard
            COMMIT             # written LAST -> presence marks completeness

Crash-safety: a checkpoint is valid iff COMMIT exists; ``restore_latest``
skips incomplete step dirs (a mid-write crash leaves no COMMIT).  Writes go to
a temp dir renamed into place, so a half-written step never shadows an older
complete one.  ``keep`` bounds retention (oldest complete checkpoints pruned
after a new COMMIT).  This is the restart path the FL simulator and the
training driver use for fault tolerance.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

_COMMIT = "COMMIT"
_SHARD_BYTES = 512 * 1024 * 1024


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.directory, name, _COMMIT)
            ):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        named = _flatten_with_names(tree)
        treedef = jax.tree.structure(tree)
        final_dir = self._step_dir(step)
        tmp_dir = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
        try:
            shards: list[list[tuple[str, np.ndarray]]] = [[]]
            size = 0
            for name, leaf in named:
                arr = np.asarray(leaf)
                if size + arr.nbytes > _SHARD_BYTES and shards[-1]:
                    shards.append([])
                    size = 0
                shards[-1].append((name, arr))
                size += arr.nbytes
            index = {}
            for i, shard in enumerate(shards):
                fname = f"shard_{i:04d}.npz"
                np.savez(os.path.join(tmp_dir, fname),
                         **{n: a for n, a in shard})
                for n, _ in shard:
                    index[n] = fname
            meta = {
                "step": step,
                "treedef": str(treedef),
                "leaf_names": [n for n, _ in named],
                "index": index,
                "extra": extra or {},
            }
            with open(os.path.join(tmp_dir, "meta.json"), "w") as f:
                json.dump(meta, f)
            # commit marker written last inside tmp, then atomic rename
            with open(os.path.join(tmp_dir, _COMMIT), "w") as f:
                f.write("ok")
            if os.path.exists(final_dir):
                shutil.rmtree(final_dir)
            os.rename(tmp_dir, final_dir)
        except BaseException:
            shutil.rmtree(tmp_dir, ignore_errors=True)
            raise
        self._prune()
        return final_dir

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs)."""
        step_dir = self._step_dir(step)
        if not os.path.exists(os.path.join(step_dir, _COMMIT)):
            raise FileNotFoundError(f"no complete checkpoint at step {step}")
        with open(os.path.join(step_dir, "meta.json")) as f:
            meta = json.load(f)
        cache: dict[str, Any] = {}

        def load(name: str) -> np.ndarray:
            fname = meta["index"][name]
            if fname not in cache:
                cache[fname] = np.load(os.path.join(step_dir, fname))
            return cache[fname][name]

        named_like = _flatten_with_names(like)
        leaves = [load(name) for name, _ in named_like]
        treedef = jax.tree.structure(like)
        restored = jax.tree.unflatten(treedef, leaves)
        return jax.tree.map(
            lambda ref, arr: np.asarray(arr).astype(
                ref.dtype if hasattr(ref, "dtype") else arr.dtype
            ),
            like, restored,
        ), meta["extra"]

    def restore_latest(self, like):
        """(step, tree, extra) from the newest COMPLETE checkpoint, or
        (None, like, {}) when none exists -- the auto-resume entry point."""
        steps = self.all_steps()
        if not steps:
            return None, like, {}
        step = steps[-1]
        tree, extra = self.restore(step, like)
        return step, tree, extra
