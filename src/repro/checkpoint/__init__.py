"""Fault-tolerant checkpointing: step-atomic npz shards + JSON metadata,
auto-resume from the latest complete checkpoint, bounded retention."""
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
