"""Safety invariants every chaos storm must preserve.

``verify`` checks the served decision stream (fresh, stale, and degraded
alike) and the surviving plane against the properties no injected fault is
allowed to break:

* **budget**       -- no served allocation exceeds the provider's bandwidth
                      budget (beyond float32 tolerance);
* **finite**       -- no non-finite bandwidth or frequency is ever served
                      (the nonfinite catch must have degraded instead);
* **inactive_zero**-- slots flagged inactive in a decision receive nothing;
* **occupancy**    -- bandwidth only ever goes to slots that were occupied
                      in the registry when the decision was served (retired
                      slots are never allocated);
* **replay**       -- when the plane still claims ``replayable``, its
                      fresh-solve stream matches ``simulator.run_scan`` on
                      the recorded trace **bitwise** (decisions aligned by
                      period, so a post-restart partial stream still
                      checks).

Each entry of the returned dict is ``{"ok": bool, ...detail}``;
``assert_invariants`` raises on the first violation with the full report.
"""
from __future__ import annotations

import numpy as np

# Absolute/relative slack for float32 budget sums.
_BUDGET_RTOL = 1e-5
_BUDGET_ATOL = 1e-6


def verify(served, plane, occupancy: list[list[int]] | None = None) -> dict:
    """Check every invariant; never raises (use ``assert_invariants`` for
    that).  ``occupancy`` is the engine's per-wall-period record of occupied
    slots, indexed like ``served``."""
    out: dict[str, dict] = {}
    budget = plane.net.total_bandwidth_mhz
    bound = budget * (1.0 + _BUDGET_RTOL) + _BUDGET_ATOL

    bad_budget = []
    bad_finite = []
    bad_inactive = []
    for i, d in enumerate(served):
        b = np.asarray(d.b, np.float64)
        f = np.asarray(d.f, np.float64)
        active = np.asarray(d.active, bool)
        if float(b.sum()) > bound:
            bad_budget.append({"index": i, "period": int(d.period),
                               "sum_mhz": float(b.sum())})
        if not (np.all(np.isfinite(b)) and np.all(np.isfinite(f))):
            bad_finite.append({"index": i, "period": int(d.period)})
        if np.any(b[~active] != 0.0) or np.any(f[~active] != 0.0):
            bad_inactive.append({"index": i, "period": int(d.period)})
    out["budget"] = {"ok": not bad_budget, "budget_mhz": float(budget),
                     "violations": bad_budget[:5]}
    out["finite"] = {"ok": not bad_finite, "violations": bad_finite[:5]}
    out["inactive_zero"] = {"ok": not bad_inactive,
                            "violations": bad_inactive[:5]}

    if occupancy is not None:
        bad_occ = []
        for i, d in enumerate(served):
            if i >= len(occupancy):
                break
            allowed = set(occupancy[i])
            getting = set(int(s) for s in np.flatnonzero(
                np.asarray(d.b, np.float64) > 0.0))
            stray = sorted(getting - allowed)
            if stray:
                bad_occ.append({"index": i, "period": int(d.period),
                                "slots": stray})
        out["occupancy"] = {"ok": not bad_occ, "violations": bad_occ[:5]}

    out["replay"] = _check_replay(plane)
    return out


def _check_replay(plane) -> dict:
    """Bitwise differential replay of the plane's fresh-solve stream.  Only
    meaningful while the plane claims ``replayable``: every injected fault
    falsifies that flag with a recorded reason, which is itself part of the
    contract -- so a non-replayable plane passes this check iff it has at
    least one recorded reason."""
    if not plane.replayable:
        reasons = list(plane.unreplayable_reasons)
        return {"ok": bool(reasons), "skipped": True, "reasons": reasons}
    if not plane.decisions:
        return {"ok": True, "skipped": True, "reasons": ["no fresh decision"]}
    ref = plane.replay_reference()
    b_ref = np.asarray(ref["history"]["b"])
    f_ref = np.asarray(ref["history"]["f"])
    mismatches = []
    checked = 0
    for d in plane.decisions:
        if d.period >= b_ref.shape[0]:
            continue
        checked += 1
        if not (np.array_equal(np.asarray(d.b), b_ref[d.period])
                and np.array_equal(np.asarray(d.f), f_ref[d.period])):
            mismatches.append(int(d.period))
    return {"ok": not mismatches, "skipped": False, "checked": checked,
            "mismatch_periods": mismatches[:10]}


def assert_invariants(served, plane,
                      occupancy: list[list[int]] | None = None) -> dict:
    """``verify`` + raise AssertionError naming every violated invariant."""
    report = verify(served, plane, occupancy=occupancy)
    bad = [name for name, res in report.items() if not res["ok"]]
    if bad:
        raise AssertionError(
            f"chaos invariants violated: {bad}; report={report}")
    return report


# ---------------------------------------------------------------------------
# Robustness gates: what an *adversarial-participant* storm must preserve.
# ---------------------------------------------------------------------------

# Max final-accuracy drop a robust aggregator may concede to a byz_frac<=0.2
# sign-flip/scaled-delta cohort on the bigram task (absolute, on [0, 1]).
ROBUST_ACC_DROP = 0.15


def accuracy_bounded(clean_acc: float, attacked_acc: float,
                     max_drop: float = ROBUST_ACC_DROP) -> dict:
    """Bounded breakdown: under f Byzantine clients a *robust* aggregator's
    final accuracy must stay within ``max_drop`` of the clean run's."""
    drop = float(clean_acc) - float(attacked_acc)
    return {"ok": bool(np.isfinite(attacked_acc) and drop <= max_drop),
            "clean_acc": float(clean_acc),
            "attacked_acc": float(attacked_acc),
            "drop": drop, "max_drop": float(max_drop)}


def params_finite(params) -> dict:
    """Unconditional: no aggregator run may ever serve non-finite model
    parameters -- a NaN/Inf update must be masked, trimmed, or out-scored,
    never averaged in."""
    import jax

    leaves = jax.tree.leaves(params)
    bad = [i for i, leaf in enumerate(leaves)
           if not bool(np.all(np.isfinite(np.asarray(leaf))))]
    return {"ok": not bad, "nonfinite_leaves": bad[:5]}


def regret_bounded(rows: list[dict], tol: float = 1e-3) -> dict:
    """Prop. 5 gate: no audited bid deviation may gain more than the Eq. 31
    truthfulness gap (``auction.delta_bound``) plus float tolerance."""
    bad = [r for r in rows
           if r["gain"] > r["delta_bound"] + tol
           or not np.isfinite(r["gain"])]
    worst = max((r["gain"] - r["delta_bound"] for r in rows), default=0.0)
    return {"ok": not bad, "n_audited": len(rows),
            "worst_excess": float(worst),
            "violations": [{k: v for k, v in r.items()
                            if k in ("trial", "provider", "deviation",
                                     "factor", "gain", "delta_bound")}
                           for r in bad[:5]]}


def assert_robust(report: dict) -> dict:
    """Raise on the first failed robustness gate (same shape contract as
    ``assert_invariants``: a dict of ``{"ok": bool, ...}`` entries)."""
    bad = [name for name, res in report.items() if not res["ok"]]
    if bad:
        raise AssertionError(
            f"robustness gates violated: {bad}; report={report}")
    return report
