"""The chaos engine: drive an AllocDaemon through a seeded fault storm.

``run_storm`` is the one-call entry point: it builds a daemon (optionally
checkpointing into ``checkpoint_dir``), runs ``n_periods`` wall-clock
periods with the given injectors firing from a ``ChaosSchedule``, and
returns a JSON-able report -- trajectory, degradation metrics, recovery
statistics, invariant results, and a sha256 digest over the trajectory plus
every served allocation.  Two storms with the same ``(config, seed)``
produce the same digest; a divergence means nondeterminism leaked into a
degradation path.

Wall-clock periods vs plane periods: the engine counts every serve (the
loop index ``t``), while ``plane.period`` advances only on fresh solves --
the gap between the two is exactly the storm's stale/degraded serves plus
any work lost to restarts (``decisions_lost`` in the report).
"""
from __future__ import annotations

import asyncio
import hashlib
import json
from typing import Callable

import numpy as np

from repro.chaos import invariants as invariants_mod
from repro.chaos.injectors import (AdmissionChaos, CheckpointChaos,
                                   HeartbeatChaos, Injector, SolverChaos)
from repro.chaos.schedule import ChaosSchedule
from repro.checkpoint import CheckpointManager
from repro.core import network
from repro.fl.control_plane import ControlPlaneConfig
from repro.launch import allocd


def default_injectors(k_max: int, *,
                      with_checkpoint: bool = True) -> list[Injector]:
    """The full catalogue at default rates.  AdmissionChaos doubles as the
    storm's workload generator, so it is always included."""
    out: list[Injector] = [HeartbeatChaos(), SolverChaos()]
    if with_checkpoint:
        out.append(CheckpointChaos())
    out.append(AdmissionChaos(k_max))
    return out


class ChaosEngine:
    """Run one storm: per wall-clock period, fire every injector's ``pre``
    hook, deliver healthy heartbeats for non-suppressed services, serve one
    decision, then fire ``post`` hooks (which may kill and restart the
    daemon)."""

    def __init__(self, factory: Callable[[], allocd.AllocDaemon],
                 injectors: list[Injector], seed: int):
        self.factory = factory
        self.injectors = injectors
        self.schedule = ChaosSchedule(seed)
        self.daemon = factory()
        self.trajectory: list[dict] = []
        self.served: list = []
        # Per wall-clock period: sorted slots occupied just before the
        # serve, for the retired-slots-never-allocated invariant.
        self.occupancy: list[list[int]] = []
        self.restarts = 0
        self.suppress_hb: set = set()

    def restart_daemon(self) -> None:
        """Crash semantics: the old daemon is abandoned without ``close`` --
        no final checkpoint, queued requests lost -- and the replacement
        auto-restores from the newest checkpoint that still verifies."""
        self.restarts += 1
        self.daemon = self.factory()

    async def run_async(self, n_periods: int) -> None:
        try:
            for t in range(n_periods):
                self.suppress_hb.clear()
                events: list[dict] = []
                for inj in self.injectors:
                    for ev in inj.pre(self, t):
                        events.append({"period": t, "injector": inj.name,
                                       **ev})
                plane = self.daemon.plane
                if plane.cfg.heartbeat_timeout_periods is not None:
                    for sid in list(plane.services):
                        if sid not in self.suppress_hb:
                            self.daemon.submit(allocd.Heartbeat(sid))
                pre_occ = {r.slot for r in plane.services.values()}
                n_retired = len(plane.retired)
                decision = await self.daemon.step_period()
                self.served.append(decision)
                # Slots legitimately allocatable this period: occupied before
                # the serve, admitted by requests drained inside it (active
                # from the very tick that drains them), or retired during it
                # (a service can be admitted, cleared, and complete within
                # one tick -- it was occupied while the allocation ran).
                post_occ = {r.slot for r in plane.services.values()}
                mid_occ = {r.slot for r in plane.retired[n_retired:]}
                self.occupancy.append(sorted(pre_occ | post_occ | mid_occ))
                for inj in self.injectors:
                    for ev in inj.post(self, t, decision):
                        events.append({"period": t, "injector": inj.name,
                                       **ev})
                self.trajectory.extend(events)
        finally:
            await self.daemon.close()

    def run(self, n_periods: int) -> None:
        asyncio.run(self.run_async(n_periods))

    def digest(self) -> str:
        """sha256 over the event trajectory and every served allocation --
        the storm's replayability fingerprint."""
        h = hashlib.sha256()
        h.update(json.dumps(self.trajectory, sort_keys=True).encode())
        for d in self.served:
            h.update(f"{d.period}|{int(d.stale)}|{int(d.degraded)}|".encode())
            h.update(np.asarray(d.b, np.float32).tobytes())
            h.update(np.asarray(d.f, np.float32).tobytes())
            h.update(np.asarray(d.active, bool).tobytes())
        return h.hexdigest()


def _recovery_runs(served) -> list[int]:
    """Lengths of maximal consecutive non-fresh (stale or degraded) runs --
    each is one outage's recovery time in periods."""
    runs, cur = [], 0
    for d in served:
        if d.stale or d.degraded:
            cur += 1
        elif cur:
            runs.append(cur)
            cur = 0
    if cur:
        runs.append(cur)
    return runs


def run_storm(cfg: ControlPlaneConfig, *, seed: int, n_periods: int,
              injectors: list[Injector] | None = None,
              net: network.NetworkConfig | None = None,
              checkpoint_dir: str | None = None, save_every: int = 5,
              max_stale_streak: int = 4, admit_max_retries: int = 3,
              check_invariants: bool = True) -> dict:
    """Run one seeded storm and report.  Same ``(cfg, seed, n_periods,
    injectors)`` -> identical ``digest``."""

    def factory() -> allocd.AllocDaemon:
        manager = (CheckpointManager(checkpoint_dir)
                   if checkpoint_dir else None)
        return allocd.AllocDaemon(
            cfg, net, manager=manager, save_every=save_every,
            max_stale_streak=max_stale_streak,
            admit_max_retries=admit_max_retries)

    if injectors is None:
        injectors = default_injectors(
            cfg.k_max, with_checkpoint=checkpoint_dir is not None)
    engine = ChaosEngine(factory, injectors, seed)
    engine.run(n_periods)

    plane = engine.daemon.plane
    served = engine.served
    n_fresh = sum(1 for d in served if not d.stale)
    n_stale = sum(1 for d in served if d.stale and not d.degraded)
    n_degraded = sum(1 for d in served if d.degraded)
    runs = _recovery_runs(served)
    report = {
        "seed": int(seed),
        "n_periods": int(n_periods),
        "restarts": int(engine.restarts),
        "events": engine.trajectory,
        "n_events": len(engine.trajectory),
        "metrics": {k: int(v) for k, v in plane.metrics.items()},
        "rejections": len(engine.daemon.rejections),
        "served": {"fresh": n_fresh, "stale": n_stale,
                   "degraded": n_degraded},
        # Fresh serves the surviving daemon no longer remembers: work
        # replayed (and thus lost) because a restart restored an older
        # checkpoint.  0 when no restart fired.
        "decisions_lost": max(0, n_fresh - plane.period),
        "recovery": {
            "outages": len(runs),
            "max_periods": max(runs) if runs else 0,
            "mean_periods": float(np.mean(runs)) if runs else 0.0,
        },
        "digest": engine.digest(),
    }
    if check_invariants:
        report["invariants"] = invariants_mod.verify(
            served, plane, occupancy=engine.occupancy)
    return report
