"""Fault injectors over a live AllocDaemon.

Each injector exposes ``pre(engine, period)`` (before the period's serve)
and ``post(engine, period, decision)`` (after it), returning a list of
JSON-able event dicts that the engine appends to the storm trajectory.
All randomness comes from ``engine.schedule.rng(period, channel)`` with an
injector-owned channel name, so storms are bitwise replayable from the seed
and injectors never perturb each other's draws.

Injectors hold per-storm mutable state (e.g. flap down-counters): build a
fresh instance per storm (``engine.default_injectors`` does).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as policy_mod
from repro.launch import allocd


class Injector:
    """Base injector: no-op hooks plus the trajectory-channel name."""

    name = "injector"

    def pre(self, engine, period: int) -> list[dict]:
        return []

    def post(self, engine, period: int, decision) -> list[dict]:
        return []


# ---------------------------------------------------------------------------
# Poison helpers (used by SolverChaos and unit tests directly).
# ---------------------------------------------------------------------------

def poison_channel_state(plane, rng: np.random.Generator) -> dict | None:
    """Write one NaN/Inf into a float leaf of the plane's channel-state
    carry (the fault the warm solver's sanitize + the plane's carry repair
    must absorb).  Returns an event dict, or None when the channel process
    carries no float state (e.g. ``iid``)."""
    carry = list(plane._carry)
    leaves, treedef = jax.tree.flatten(carry[2])
    float_idx = [i for i, leaf in enumerate(leaves)
                 if np.issubdtype(np.asarray(leaf).dtype, np.floating)
                 and np.asarray(leaf).size > 0]
    if not float_idx:
        return None
    i = float_idx[int(rng.integers(len(float_idx)))]
    arr = np.array(np.asarray(leaves[i]), copy=True)
    j = int(rng.integers(arr.size))
    value = float(rng.choice([np.nan, np.inf, -np.inf]))
    arr.reshape(-1)[j] = value
    leaves[i] = jnp.asarray(arr)
    carry[2] = jax.tree.unflatten(treedef, leaves)
    plane._carry = tuple(carry)
    return {"action": "poison_channel", "leaf": int(i), "index": int(j),
            "value": repr(value)}


def poison_warm_seed(plane, rng: np.random.Generator,
                     value: float | None = None) -> dict | None:
    """Corrupt the warm dual seed: NaN/Inf (must trigger the counted
    cold-bisection fallback) or a badly-stale finite price (the safeguarded
    bracket must absorb it).  None when the policy carries no warm state."""
    pol_state = plane._carry[4]
    if not isinstance(pol_state, policy_mod.WarmDualState):
        return None
    if value is None:
        value = float(rng.choice([np.nan, np.inf, 1e7]))
    carry = list(plane._carry)
    carry[4] = pol_state._replace(lam=jnp.float32(value))
    plane._carry = tuple(carry)
    return {"action": "poison_warm_seed", "value": repr(float(value))}


# ---------------------------------------------------------------------------
# The injector families.
# ---------------------------------------------------------------------------

class HeartbeatChaos(Injector):
    """Heartbeat faults: drop / delay / duplicate / flap.

    The engine sends a healthy heartbeat for every registered service each
    period unless the service id is in ``engine.suppress_hb``; this injector
    fills that set.  A flap takes a service down for ``1 + Geometric`` whole
    periods; a drop/delay silences exactly one period (a delayed heartbeat
    is indistinguishable from dropping it for the period it missed);
    duplicates submit extra Heartbeat requests (idempotence check).
    """

    name = "heartbeat"

    def __init__(self, p_drop: float = 0.08, p_delay: float = 0.05,
                 p_dup: float = 0.05, p_flap: float = 0.03,
                 flap_mean: float = 2.0):
        self.p_drop = p_drop
        self.p_delay = p_delay
        self.p_dup = p_dup
        self.p_flap = p_flap
        self.flap_mean = max(float(flap_mean), 1.0)
        self._down: dict[Any, int] = {}

    def pre(self, engine, period: int) -> list[dict]:
        events = []
        plane = engine.daemon.plane
        for sid in list(plane.services):
            rng = engine.schedule.rng(period, f"hb/{sid}")
            down = self._down.get(sid, 0)
            if down > 0:
                self._down[sid] = down - 1
                engine.suppress_hb.add(sid)
                events.append({"action": "flap_down", "service": str(sid)})
                continue
            u = rng.random(4)
            if u[0] < self.p_flap:
                n = int(1 + rng.geometric(1.0 / self.flap_mean))
                self._down[sid] = n - 1
                engine.suppress_hb.add(sid)
                events.append({"action": "flap_start", "service": str(sid),
                               "periods": n})
            elif u[1] < self.p_drop:
                engine.suppress_hb.add(sid)
                events.append({"action": "drop", "service": str(sid)})
            elif u[2] < self.p_delay:
                engine.suppress_hb.add(sid)
                events.append({"action": "delay", "service": str(sid)})
            elif u[3] < self.p_dup:
                engine.daemon.submit(allocd.Heartbeat(sid))
                engine.daemon.submit(allocd.Heartbeat(sid))
                events.append({"action": "duplicate", "service": str(sid)})
        return events


class SolverChaos(Injector):
    """Solver faults: deterministic deadline misses (forced stale serve),
    NaN/Inf-poisoned channel state, corrupted warm dual seeds."""

    name = "solver"

    def __init__(self, p_deadline: float = 0.1, p_poison_chan: float = 0.05,
                 p_poison_seed: float = 0.04):
        self.p_deadline = p_deadline
        self.p_poison_chan = p_poison_chan
        self.p_poison_seed = p_poison_seed

    def pre(self, engine, period: int) -> list[dict]:
        events = []
        rng = engine.schedule.rng(period, "solver")
        u = rng.random(3)
        if u[0] < self.p_deadline:
            engine.daemon._force_stale_next = True
            events.append({"action": "deadline_miss"})
        if u[1] < self.p_poison_chan:
            ev = poison_channel_state(engine.daemon.plane, rng)
            if ev:
                events.append(ev)
        if u[2] < self.p_poison_seed:
            ev = poison_warm_seed(engine.daemon.plane, rng)
            if ev:
                events.append(ev)
        return events


class CheckpointChaos(Injector):
    """Checkpoint faults against the daemon's manager directory: torn writes
    (COMMIT removed), corrupted npz payloads and truncated shards *behind an
    intact COMMIT* (checksum verification must catch them), and restart
    storms (the engine rebuilds the daemon, which auto-restores from the
    newest checkpoint that still verifies)."""

    name = "checkpoint"

    def __init__(self, p_torn: float = 0.04, p_truncate: float = 0.04,
                 p_corrupt: float = 0.04, p_restart: float = 0.06):
        self.p_torn = p_torn
        self.p_truncate = p_truncate
        self.p_corrupt = p_corrupt
        self.p_restart = p_restart

    @staticmethod
    def _newest_shard(mgr, step: int) -> str:
        return os.path.join(mgr._step_dir(step), "shard_0000.npz")

    def post(self, engine, period: int, decision) -> list[dict]:
        mgr = engine.daemon.manager
        if mgr is None:
            return []
        events = []
        rng = engine.schedule.rng(period, "checkpoint")
        u = rng.random(4)
        steps = mgr.all_steps()
        if steps and u[0] < self.p_torn:
            step = steps[-1]
            commit = os.path.join(mgr._step_dir(step), "COMMIT")
            if os.path.exists(commit):
                os.remove(commit)
                events.append({"action": "torn_commit", "step": int(step)})
        steps = mgr.all_steps()
        if steps and u[1] < self.p_truncate:
            step = steps[-1]
            shard = self._newest_shard(mgr, step)
            if os.path.exists(shard):
                size = os.path.getsize(shard)
                with open(shard, "r+b") as f:
                    f.truncate(max(size // 2, 1))
                events.append({"action": "truncate_shard", "step": int(step)})
        steps = mgr.all_steps()
        if steps and u[2] < self.p_corrupt:
            step = steps[-1]
            shard = self._newest_shard(mgr, step)
            if os.path.exists(shard):
                size = os.path.getsize(shard)
                with open(shard, "r+b") as f:
                    f.seek(size // 2)
                    byte = f.read(1)
                    f.seek(size // 2)
                    f.write(bytes([byte[0] ^ 0xFF]) if byte else b"\xff")
                events.append({"action": "corrupt_shard", "step": int(step)})
        if u[3] < self.p_restart:
            engine.restart_daemon()
            events.append({
                "action": "restart",
                "restored_period": int(engine.daemon.plane.period),
                "skipped": [int(s) for s, _ in
                            getattr(engine.daemon.manager, "last_skipped",
                                    [])],
            })
        return events


class AdmissionChaos(Injector):
    """Admission faults AND the storm's base workload: a steady trickle of
    admissions, bursts that overshoot capacity (exercising the daemon's
    bounded retry), duplicate admits of a live id, retires of unknown ids.
    Every malformed request must land as a recorded rejection -- never a
    crash, never a silent drop."""

    name = "admission"

    def __init__(self, k_max: int, p_admit: float = 0.35,
                 p_burst: float = 0.08, burst_max: int = 4,
                 p_dup: float = 0.06, p_retire_unknown: float = 0.05):
        self.k_max = int(k_max)
        self.p_admit = p_admit
        self.p_burst = p_burst
        self.burst_max = max(int(burst_max), 2)
        self.p_dup = p_dup
        self.p_retire_unknown = p_retire_unknown

    def _admit(self, engine, period: int, i: int,
               rng: np.random.Generator) -> dict:
        sid = f"svc-{period}-{i}"
        k = int(rng.integers(2, self.k_max + 1))
        engine.daemon.submit(allocd.Admit(sid, k))
        return {"action": "admit", "service": sid, "n_clients": k}

    def pre(self, engine, period: int) -> list[dict]:
        events = []
        rng = engine.schedule.rng(period, "admission")
        u = rng.random(4)
        if u[0] < self.p_admit:
            events.append(self._admit(engine, period, 0, rng))
        if u[1] < self.p_burst:
            n = int(rng.integers(2, self.burst_max + 1))
            for i in range(1, n + 1):
                events.append(self._admit(engine, period, i, rng))
            events.append({"action": "burst", "n": n})
        plane = engine.daemon.plane
        if u[2] < self.p_dup and plane.services:
            sids = list(plane.services)
            sid = sids[int(rng.integers(len(sids)))]
            engine.daemon.submit(
                allocd.Admit(sid, int(rng.integers(2, self.k_max + 1))))
            events.append({"action": "duplicate_admit", "service": str(sid)})
        if u[3] < self.p_retire_unknown:
            engine.daemon.submit(allocd.Retire(f"ghost-{period}"))
            events.append({"action": "retire_unknown",
                           "service": f"ghost-{period}"})
        return events
