"""Strategic-bidder chaos: seeded unilateral deviations against the
fairness-adjusted multi-bid auction (``core.auction``).

The paper's Prop. 5 claims truthful bidding is an ex-post Delta-Nash
equilibrium: no provider can gain more than ``auction.delta_bound`` (Eq. 31)
by deviating from its truthful book.  ``BidChaos`` attacks that claim
empirically -- seeded draws on the PR 8 ``(salt, seed, period,
crc32(channel))`` scheme pick a provider, a deviation, and a magnitude,
replace that provider's row of the truthful ``MultiBid``, re-clear the
market, and report the *empirical regret* (utility gained over bidding
truthfully) against the theoretical bound.

Deviation catalogue:

* ``overbid``   -- demands scaled by ``factor > 1``: claim more bandwidth at
                   every announced price (demand exaggeration).
* ``shade``     -- demands scaled by ``factor < 1``: understate demand to
                   duck the exclusion-compensation charge.
* ``free_ride`` -- demand only at the lowest announced price (the
                   non-increasing-in-m limit of shading): try to collect the
                   cheap surplus split without competing at high prices.

Charges for deviated books use ``method="rerun"`` -- the closed-form prefix
charges are only guaranteed exact for truthful-shaped books, and the whole
point here is to leave that set.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.chaos.schedule import ChaosSchedule
from repro.core import auction, intra
from repro.core.types import ServiceSet

DEVIATIONS = ("overbid", "shade", "free_ride")


def deviate_bid(bid: auction.MultiBid, n: int, kind: str,
                factor: float) -> auction.MultiBid:
    """Provider ``n``'s unilateral deviation from the (truthful) book.
    Prices are operator-announced and stay fixed; only n's demand row moves.
    Every deviation preserves the non-increasing-in-m demand shape the
    clearing assumes."""
    demands = np.asarray(bid.demands).copy()
    if kind in ("overbid", "shade"):
        demands[n] = demands[n] * factor
    elif kind == "free_ride":
        row = np.zeros_like(demands[n])
        row[0] = demands[n][0]
        demands[n] = row
    else:
        raise ValueError(
            f"unknown bid deviation {kind!r}; known: {DEVIATIONS}")
    return auction.MultiBid(prices=bid.prices,
                            demands=jnp.asarray(demands))


def _utility(svc: ServiceSet, bid: auction.MultiBid, total_bandwidth: float,
             alpha_fair: float, p_reserve: float = 0.0) -> np.ndarray:
    """(N,) realized utilities f - c under this book (Eq. 28), with the
    leave-one-out rerun charges (exact for arbitrary books)."""
    b, _ = auction.allocate(bid, total_bandwidth, p_reserve)
    c = auction.charges(svc, bid, b, total_bandwidth, alpha_fair, p_reserve,
                        method="rerun")
    f = intra.freq(svc, b)
    return np.asarray(f - c, np.float64)


def audit_deviation(svc: ServiceSet, total_bandwidth: float, n: int,
                    kind: str, factor: float, *, n_bids: int = 5,
                    alpha_fair: float = 0.5,
                    p_reserve: float = 0.0) -> dict:
    """One unilateral deviation, measured: provider ``n``'s utility under
    the truthful book vs after the deviation, the empirical gain, and the
    Eq. 31 truthfulness gap it must stay under."""
    truthful = auction.uniform_truthful_bids(svc, n_bids, alpha_fair,
                                             p_reserve)
    u_truth = _utility(svc, truthful, total_bandwidth, alpha_fair, p_reserve)
    dev = deviate_bid(truthful, n, kind, factor)
    u_dev = _utility(svc, dev, total_bandwidth, alpha_fair, p_reserve)
    delta = float(np.asarray(
        auction.delta_bound(svc, truthful, alpha_fair, p_reserve))[n])
    gain = float(u_dev[n] - u_truth[n])
    return {
        "provider": int(n), "deviation": kind, "factor": float(factor),
        "u_truthful": float(u_truth[n]), "u_deviated": float(u_dev[n]),
        "gain": gain, "regret": max(0.0, gain), "delta_bound": delta,
    }


class BidChaos:
    """Seeded sweep of unilateral deviations: every trial's (provider,
    deviation, magnitude) draw comes off the dedicated ``bid`` channel of a
    ``ChaosSchedule``, so a manipulation campaign replays exactly from its
    seed."""

    name = "bids"

    def __init__(self, seed: int, deviations: tuple[str, ...] = DEVIATIONS):
        self.schedule = ChaosSchedule(seed)
        self.deviations = tuple(deviations)

    def draw(self, trial: int, n_providers: int) -> tuple[int, str, float]:
        rng = self.schedule.rng(trial, "bid")
        n = int(rng.integers(n_providers))
        kind = self.deviations[int(rng.integers(len(self.deviations)))]
        if kind == "overbid":
            factor = float(1.0 + 3.0 * rng.random())      # 1x .. 4x
        elif kind == "shade":
            factor = float(0.2 + 0.7 * rng.random())      # 0.2 .. 0.9
        else:
            factor = 0.0                                   # free_ride: unused
        return n, kind, factor

    def run(self, svc: ServiceSet, total_bandwidth: float, n_trials: int, *,
            n_bids: int = 5, alpha_fair: float = 0.5,
            p_reserve: float = 0.0) -> list[dict]:
        n_providers = int(svc.alpha.shape[0])
        rows = []
        for t in range(n_trials):
            n, kind, factor = self.draw(t, n_providers)
            row = audit_deviation(
                svc, total_bandwidth, n, kind, factor, n_bids=n_bids,
                alpha_fair=alpha_fair, p_reserve=p_reserve)
            row["trial"] = t
            rows.append(row)
        return rows
