"""Adversarial-participant chaos: seeded Byzantine clients for the cotrain
loop.

PR 8's injectors attacked the *infrastructure* (heartbeats, solvers,
checkpoints); ``ClientChaos`` attacks the *participants*: a seeded fraction
of client slots per service turns Byzantine and manipulates what it uploads
to the FedAvg server.  Membership draws ride the same
``(ROOT_SALT, seed, period, crc32(channel))`` scheme as every other chaos
channel (``schedule.ChaosSchedule``, channel ``byz/<service>``), so an
attacked training trajectory replays bitwise from ``AttackSpec.seed`` alone
-- and, because the channels are disjoint from the simulator's salted
streams, the attack cannot perturb the allocation side of the episode.

The catalogue (Fang et al. 2020 / Blanchard et al. 2017 standards):

* ``sign_flip``      -- Byzantine deltas become ``-scale * delta`` (scaled
                        gradient reversal; at 20% clients this drives the
                        plain FedAvg mean *away* from the optimum).
* ``scaled_delta``   -- deltas become ``scale * delta`` (model-boosting /
                        divergence amplification).
* ``same_value``     -- collusion: every Byzantine client uploads the
                        identical constant-``scale`` vector, steering the
                        mean toward a common crafted point.
* ``nan``            -- a single NaN upload; poisons any unmasked reduction.
* ``inflate_weight`` -- honest-looking delta, weight multiplied by
                        ``scale`` (dominates an uncapped weighted mean;
                        see ``server.sanitize_weights``).

``AttackSpec`` is a frozen (hashable) dataclass, so it rides the cotrain
jit statics: one trace per attack config, vmap/fleet-safe.  The actual
per-round transformation ``attack_fn`` is pure jnp on a (C,) Byzantine mask
the episode threads through its scan.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.chaos.schedule import ChaosSchedule

ATTACKS = ("sign_flip", "scaled_delta", "same_value", "nan", "inflate_weight")


@dataclasses.dataclass(frozen=True)
class AttackSpec:
    """Hashable (jit-static) description of an adversarial client cohort."""

    attack: str = "sign_flip"
    byz_frac: float = 0.2    # per-slot Bernoulli membership probability
    scale: float = 8.0       # attack magnitude (see the catalogue above)
    seed: int = 0            # ChaosSchedule storm seed for membership draws

    def __post_init__(self):
        if self.attack not in ATTACKS:
            raise ValueError(
                f"unknown client attack {self.attack!r}; known: {ATTACKS}")
        if not 0.0 <= self.byz_frac <= 1.0:
            raise ValueError(
                f"byz_frac must be in [0, 1], got {self.byz_frac}")


class ClientChaos:
    """Deterministic Byzantine-membership planner for one attacked episode."""

    name = "clients"

    def __init__(self, spec: AttackSpec):
        self.spec = spec
        self.schedule = ChaosSchedule(spec.seed)

    def plan(self, n_periods: int, n_services: int, k_max: int) -> np.ndarray:
        """(T, N, K) bool Byzantine membership: per period and service, each
        client slot flips Byzantine with prob ``byz_frac`` on the dedicated
        ``byz/<service>`` channel -- independent of every other chaos
        channel and replayable from the spec's seed."""
        out = np.zeros((n_periods, n_services, k_max), dtype=bool)
        for t in range(n_periods):
            for s in range(n_services):
                draws = self.schedule.rng(t, f"byz/{s}").random(k_max)
                out[t, s] = draws < self.spec.byz_frac
        return out


def attack_fn(spec: AttackSpec):
    """Pure jnp transformation ``(deltas, weights, byz) -> (deltas, weights)``
    applied between the client vmap and the aggregator: ``byz`` is the (C,)
    bool membership mask for this round.  Honest clients pass through
    bitwise."""

    def mask(byz, leaf):
        return byz.reshape((-1,) + (1,) * (leaf.ndim - 1))

    def apply(deltas, weights, byz):
        s = spec.scale
        if spec.attack == "sign_flip":
            deltas = jax.tree.map(
                lambda d: jnp.where(mask(byz, d), -s * d, d), deltas)
        elif spec.attack == "scaled_delta":
            deltas = jax.tree.map(
                lambda d: jnp.where(mask(byz, d), s * d, d), deltas)
        elif spec.attack == "same_value":
            deltas = jax.tree.map(
                lambda d: jnp.where(mask(byz, d),
                                    jnp.full_like(d, s), d), deltas)
        elif spec.attack == "nan":
            deltas = jax.tree.map(
                lambda d: jnp.where(mask(byz, d),
                                    jnp.full_like(d, jnp.nan), d), deltas)
        elif spec.attack == "inflate_weight":
            weights = jnp.where(
                jnp.logical_and(byz, weights > 0),
                weights * jnp.asarray(s, weights.dtype), weights)
        return deltas, weights

    return apply
