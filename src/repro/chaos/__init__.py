"""Deterministic, seeded chaos engineering for the allocation stack.

Composable fault injectors over the live control plane + daemon
(``fl.control_plane`` / ``launch.allocd``), every draw keyed on
``(seed, period, channel)`` so any failure trajectory is exactly replayable
from its seed (``schedule.ChaosSchedule``).  The injector catalogue
(``injectors``): heartbeat faults (drop / delay / duplicate / flap), solver
faults (deterministic deadline misses, NaN/Inf-poisoned channel state,
badly-stale or non-finite warm dual seeds), checkpoint faults (torn COMMIT,
corrupted / truncated shards behind an intact COMMIT, restart storms), and
admission faults (bursts, duplicate admits, retire-of-unknown).

``engine.run_storm`` drives a storm and returns a JSON-able report with a
trajectory digest (same seed -> identical digest); ``invariants.verify``
checks the safety net under every schedule: budget conservation, no
non-finite value ever served, retired slots never allocated, and the
recorded trace replaying bitwise through ``simulator.run_scan``.

Adversarial *participants* ride the same channels: ``clients.ClientChaos``
turns a seeded fraction of FL clients Byzantine (sign-flip / scaled /
colluding / NaN / weight-inflating uploads, channel ``byz/<service>``)
against the ``fl.aggregation`` robust-aggregator registry, and
``bids.BidChaos`` plays seeded unilateral deviations against the auction's
Prop. 5 truthfulness gap (channel ``bid``).  ``invariants`` gains the
matching robustness gates (``accuracy_bounded`` / ``params_finite`` /
``regret_bounded`` / ``assert_robust``).

See EXPERIMENTS.md §Chaos drills and §Adversarial robustness for the
catalogues and replay instructions.
"""
from repro.chaos.schedule import ChaosSchedule
from repro.chaos.injectors import (AdmissionChaos, CheckpointChaos,
                                   HeartbeatChaos, Injector, SolverChaos,
                                   poison_channel_state, poison_warm_seed)
from repro.chaos.clients import AttackSpec, ClientChaos
from repro.chaos.bids import BidChaos
from repro.chaos.engine import ChaosEngine, default_injectors, run_storm
from repro.chaos import invariants

__all__ = [
    "ChaosSchedule", "Injector", "HeartbeatChaos", "SolverChaos",
    "CheckpointChaos", "AdmissionChaos", "poison_channel_state",
    "poison_warm_seed", "AttackSpec", "ClientChaos", "BidChaos",
    "ChaosEngine", "default_injectors", "run_storm", "invariants",
]
