"""(seed, period, channel)-keyed randomness for replayable fault injection.

Every injector draw comes from a PCG64 stream seeded with the tuple
``(ROOT_SALT, storm seed, period, crc32(channel))`` -- no global RNG state,
no draw-order coupling between injectors, no platform-dependent hashing
(``zlib.crc32``, unlike ``hash``, is stable across processes and Python's
per-process hash randomization).  Two storms with the same seed therefore
make identical draws at every (period, channel) regardless of which other
injectors ran, which is what makes a recorded failure trajectory exactly
replayable from its seed alone.
"""
from __future__ import annotations

import zlib

import numpy as np

ROOT_SALT = 0xC4A05EED


class ChaosSchedule:
    """Deterministic per-(period, channel) RNG factory for one storm."""

    def __init__(self, seed: int):
        self.seed = int(seed) & 0xFFFFFFFF

    def rng(self, period: int, channel: str) -> np.random.Generator:
        """A fresh generator for this (period, channel) -- independent of
        every other channel and of how many draws anyone else made."""
        return np.random.default_rng(
            [ROOT_SALT, self.seed, int(period) & 0xFFFFFFFF,
             zlib.crc32(channel.encode("utf-8"))])

    def fires(self, period: int, channel: str, p: float) -> bool:
        """One Bernoulli(p) draw on the channel's dedicated stream."""
        return bool(self.rng(period, channel).random() < p)
