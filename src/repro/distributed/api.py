"""Mesh context for in-model sharding constraints.

Model code is mesh-agnostic; launchers register the active mesh here and
models pin critical intermediates with ``constrain(x, "batch", None,
"vocab")`` using logical axis names.  With no mesh registered (unit tests,
single-device runs) ``constrain`` is a no-op.

Why this exists: GSPMD propagation alone picks a catastrophic strategy for
the tied-embedding logits matmul's transpose -- it all-gathers the full-batch
fp32 logits over the data axis (67 GB x2 per step on gemma-2b train_4k)
instead of partial-summing the embed-sized gradient.  One constraint on the
logits fixes the strategy (EXPERIMENTS.md §Perf, iteration 1).
"""
from __future__ import annotations

import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# logical name -> mesh axes (resolved against the registered mesh's names)
_LOGICAL = {
    "batch": ("pod", "data"),
    "seq": ("data",),       # sequence-parallel residual stream
    "model": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "expert": ("model",),
    "ffn_shard": ("pod", "data"),  # serve-2D: expert/mlp hidden dim over data
}


def set_mesh(mesh: Mesh | None) -> None:
    _STATE.mesh = mesh


def get_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


def _resolve(name, mesh) -> tuple | None:
    if name is None:
        return None
    axes = tuple(ax for ax in _LOGICAL[name] if ax in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x: jax.Array, *names: str | None) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh.
    Dims whose size doesn't divide the axis product are left unconstrained."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, name in enumerate(names):
        axes = _resolve(name, mesh)
        if axes is None:
            spec.append(None)
            continue
        n = 1
        for ax in (axes if isinstance(axes, tuple) else (axes,)):
            n *= mesh.shape[ax]
        spec.append(axes if x.shape[dim] % n == 0 and x.shape[dim] >= n else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
