"""Elastic scaling.

Two elasticity mechanisms mirror each other across the stack:

  * FL layer (the paper's own): the period structure re-solves the bandwidth
    allocation whenever the active service set changes -- services join/leave
    without disturbing survivors (repro.fl.simulator).
  * Device layer: when nodes fail or join, ``remesh`` re-factors the
    surviving device count into a (data, model) mesh (shrinking model
    parallelism only when forced, since TP reshard moves more bytes than DP),
    and ``reshard`` moves a checkpointed pytree onto the new mesh via
    jax.device_put with freshly derived shardings.  Combined with
    deterministic data and step-atomic checkpoints, an elastic restart is a
    pure function of (checkpoint, new device count).
"""
from __future__ import annotations

from typing import Any

import jax

from repro.distributed import sharding
from repro.launch.mesh import make_elastic_mesh


def remesh(n_devices: int, prefer_model_parallel: int = 16):
    return make_elastic_mesh(n_devices, prefer_model_parallel)


def reshard(cfg, params: Any, new_mesh) -> Any:
    """Place an unsharded/checkpointed param pytree onto a new mesh using the
    arch's sharding rules."""
    sh = sharding.param_shardings(cfg, params, new_mesh)
    return jax.device_put(params, sh)


def _factor(n_devices: int, model_parallel: int) -> dict:
    while model_parallel > 1 and n_devices % model_parallel != 0:
        model_parallel //= 2
    return {"data": n_devices // model_parallel, "model": model_parallel}


def plan_service_remesh(n_devices_before: int, n_devices_after: int,
                        model_parallel: int = 16) -> dict:
    """Report of what an elastic transition changes (used by ops tooling and
    tests): mesh shapes and which parallelism axis absorbs the change.
    Pure arithmetic -- safe to call without the devices actually present."""
    before = _factor(n_devices_before, model_parallel)
    after = _factor(n_devices_after, model_parallel)
    return {
        "before": before,
        "after": after,
        "model_parallel_changed": before["model"] != after["model"],
    }
