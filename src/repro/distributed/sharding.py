"""Per-architecture parameter / activation / cache sharding rules.

Strategy (DESIGN.md §4):
  * ``model`` axis (16): tensor parallelism -- attention QKV/output and MLP
    up/down projections column/row split; MoE experts split across the axis
    (EP); vocab + embedding sharded on the vocab dim.
  * ``data`` axis (16): batch data parallelism + FSDP: parameters and
    optimizer moments additionally sharded on their largest remaining dim
    when divisible (ZeRO-3 style; GSPMD inserts the all-gathers).
  * ``pod`` axis (2, multi-pod only): outer data parallelism -- gradient
    all-reduce is the only cross-pod collective in steady state.

Rules are name+shape driven: ``param_shardings`` walks the pytree and matches
leaf path suffixes, checking divisibility before sharding any dim (falls back
to replication, never mis-shards oddly-sized layers such as hymba's 25 heads
or xlstm's 4).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# (path regex, candidate spec builder) -- first match wins.  Specs name the
# *logical* roles; dims that don't divide are dropped to None at apply time.
_COL = "col"   # shard last dim on model axis
_ROW = "row"   # shard first (or matmul-in) dim on model axis
_VOCAB = "vocab"        # (V, d): shard V on model; NEVER FSDP the d dim --
_VOCAB_OUT = "vocab_out"  # sharding d over data makes the logits matmul
#                           contraction-sharded and XLA all-reduces the FULL
#                           (B,S,V/16) logits across data (measured 67 GB/op
#                           on gemma-2b train_4k; see EXPERIMENTS.md §Perf).
_EXPERT = "expert"
_REPL = "repl"

_RULES: list[tuple[str, str]] = [
    # expert rule must precede the generic w_gate/w_up/w_down rules
    (r"\['routed'\]\['w_\w+'\]$", _EXPERT),
    (r"\['embed'\]$", _VOCAB),
    (r"\['unembed'\]$", _VOCAB_OUT),
    (r"\['w[qkv]'\]$", _COL),
    (r"\['wq_[ab]'\]$", _COL),
    (r"\['wkv_a'\]$", _COL),
    (r"\['wkv_b'\]$", _COL),
    (r"\['wo'\]$", _ROW),
    (r"\['w_gate'\]$", _COL),
    (r"\['w_up'\]$", _COL),
    (r"\['w_down'\]$", _ROW),
    (r"\['w_in'\]$", _COL),
    (r"\['w_bc'\]$", _COL),
    (r"\['w_dt'\]$", _COL),
    (r"\['w_out'\]$", _ROW),
    (r"\['w_mix_out'\]$", _ROW),
    (r"\['w_qkv'\]$", _COL),
    (r"\['w_if'\]$", _COL),
    (r"\['w_zifo'\]$", _COL),
    (r"\['router'\]$", _REPL),
]


def _divides(dim: int | None, n: int) -> bool:
    return dim is not None and n > 1 and dim % n == 0 and dim >= n


def _spec_for(role: str, shape: tuple[int, ...], mesh: Mesh,
              data_axes: tuple[str, ...], fsdp: bool, serve_2d: bool) -> P:
    model_n = mesh.shape["model"]
    data_n = 1
    for ax in data_axes:
        data_n *= mesh.shape[ax]
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    daxis = data_axes if len(data_axes) > 1 else data_axes[0]

    def try_set(dim_idx: int, axis, axis_n: int) -> bool:
        if spec[dim_idx] is None and _divides(shape[dim_idx], axis_n):
            spec[dim_idx] = axis
            return True
        return False

    # stacked layer params carry 1-2 leading scan dims; the matmul dims are
    # the trailing ones.
    last, first_mat = ndim - 1, max(ndim - 2, 0)
    if role == _VOCAB:
        try_set(first_mat, "model", model_n)      # (V, d): shard vocab
        fsdp = False
    elif role == _VOCAB_OUT:
        try_set(last, "model", model_n)           # (d, V): shard vocab
        fsdp = False
    elif role == _COL:
        try_set(last, "model", model_n)
        if serve_2d:
            # serving: weights stationary on BOTH axes -- the decode-sized
            # activation psum is ~30x cheaper than per-layer FSDP weight
            # all-gathers (EXPERIMENTS.md §Perf cell 1)
            try_set(first_mat, daxis, data_n)
            fsdp = False
    elif role == _ROW:
        try_set(first_mat, "model", model_n)
        if serve_2d:
            try_set(last, daxis, data_n)
            fsdp = False
    elif role == _EXPERT:
        # (L?, E, d, f): expert dim = ndim-3
        if ndim >= 3:
            try_set(ndim - 3, "model", model_n)
        if serve_2d:
            try_set(last, daxis, data_n)
            fsdp = False
    # FSDP: shard one remaining (preferably large) dim over the data axes
    if fsdp and data_n > 1:
        order = sorted(range(ndim), key=lambda i: -shape[i])
        for i in order:
            if try_set(i, daxis, data_n):
                break
    return P(*spec)


def param_shardings(
    cfg: ModelConfig,
    params_tree: Any,
    mesh: Mesh,
    *,
    fsdp: bool | None = None,
    serve_2d: bool = False,
) -> Any:
    """NamedSharding pytree matching ``params_tree`` (arrays or SDS).

    serve_2d=True applies the serving layout: matmul weights sharded on both
    (model, data) axes and never gathered (inference has no optimizer state,
    and decode activations are tiny, so the 2D-TP partial-sum beats FSDP
    gathers by the weight/activation size ratio)."""
    import math as _math
    data_axes = tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
    if fsdp is None:
        total = sum(_math.prod(x.shape) for x in jax.tree.leaves(params_tree))
        fsdp = total > 2_000_000_000 and not serve_2d

    # TP-hostile archs (xlstm: 4 heads, head-blocked cells) gather activation-
    # sized tensors on every layer under COL/ROW model sharding (measured
    # 5.4 GB/chip/layer); pure-FSDP over (data x model) replaces that with
    # weight gathers (~0.35 GB/layer) -- §Perf bonus cell 2.
    fsdp_only = cfg.family == "ssm" and cfg.n_heads < mesh.shape.get("model", 1)
    fsdp_axes = data_axes + ("model",) if fsdp_only else data_axes

    def leaf_spec(path, leaf):
        pstr = jax.tree_util.keystr(path)
        role = _REPL
        for pattern, r in _RULES:
            if re.search(pattern, pstr):
                role = r
                break
        if fsdp_only and role in (_COL, _ROW):
            role = _REPL
        spec = _spec_for(role, tuple(leaf.shape), mesh, fsdp_axes,
                         fsdp or fsdp_only, serve_2d)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def batch_shardings(cfg: ModelConfig, batch_tree: Any, mesh: Mesh) -> Any:
    """Batch dim over (pod, data); M-RoPE position streams have batch at
    index 1; everything else follows its leading dim.

    TP-hostile archs (see param_shardings' fsdp_only) extend the batch onto
    the otherwise-idle model axis -- pure 256-way DP + ZeRO; without this the
    model-axis devices duplicate the full forward (measured 16x per-chip
    FLOPs on xlstm train_4k)."""
    data_axes = tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
    if cfg.family == "ssm" and cfg.n_heads < mesh.shape.get("model", 1):
        # extend the batch onto the idle model axis ONLY when the global
        # batch still divides (multi-pod: 256 % 512 != 0 -> keep (pod,data);
        # replicating the batch would be far worse than idle model devices)
        ext = data_axes + ("model",)
        n_ext = 1
        for ax in ext:
            n_ext *= mesh.shape[ax]
        sizes = {leaf.shape[0] for leaf in jax.tree.leaves(batch_tree)
                 if leaf.ndim >= 1}
        if sizes and all(s % n_ext == 0 and s >= n_ext for s in sizes):
            data_axes = ext
    axes = data_axes if len(data_axes) > 1 else data_axes[0]
    data_n = 1
    for ax in data_axes:
        data_n *= mesh.shape[ax]

    def leaf_spec(path, leaf):
        pstr = jax.tree_util.keystr(path)
        batch_dim = 1 if "positions" in pstr and len(leaf.shape) == 3 else 0
        spec: list[Any] = [None] * len(leaf.shape)
        if _divides(leaf.shape[batch_dim], data_n):
            spec[batch_dim] = axes
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_tree)


def cache_shardings(cfg: ModelConfig, cache_tree: Any, mesh: Mesh) -> Any:
    """KV/state caches: batch over (pod,data) when divisible, else the
    sequence dim over (pod,data) (long-context batch=1 cells); kv-head or
    latent dims over model when divisible."""
    data_axes = tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
    axes = data_axes if len(data_axes) > 1 else data_axes[0]
    data_n = 1
    for ax in data_axes:
        data_n *= mesh.shape[ax]
    model_n = mesh.shape["model"]

    def leaf_spec(path, leaf):
        pstr = jax.tree_util.keystr(path)
        shape = leaf.shape
        spec: list[Any] = [None] * len(shape)
        if not shape:  # the scalar "len"
            return NamedSharding(mesh, P())
        if ("'k'" in pstr or "'v'" in pstr or "xk" in pstr or "xv" in pstr
                or "k_scale" in pstr or "v_scale" in pstr):
            # (L, B, S, Hkv, Dh) or int8-scale (L, B, S, Hkv)
            if _divides(shape[1], data_n):
                spec[1] = axes
            elif _divides(shape[2], data_n):
                spec[2] = axes          # sequence-parallel cache (batch=1)
            if len(shape) >= 4 and _divides(shape[3], model_n):
                spec[3] = "model"
            elif spec[2] is None and _divides(shape[2], model_n):
                # kv heads don't divide the model axis (e.g. 8 heads / 16):
                # split-KV decode -- shard the sequence dim over model; the
                # attention softmax reduces over it with a psum (flash-
                # decoding split-K, GSPMD edition).  Without this the cache
                # replicates over model (21 GB/chip on command-r decode_32k).
                spec[2] = "model"
        elif "ckv" in pstr or "krope" in pstr:
            # (L, B, S, R)
            if _divides(shape[1], data_n):
                spec[1] = axes
            elif _divides(shape[2], data_n):
                spec[2] = axes
        else:
            # recurrent states: (..., B, ...): find the batch dim by size
            for i, s in enumerate(shape):
                if _divides(s, data_n):
                    spec[i] = axes
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
