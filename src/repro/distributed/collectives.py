"""Collective-communication utilities for the (pod, data, model) mesh.

``hierarchical_psum`` decomposes a flat all-reduce into
reduce-scatter(intra-pod) -> all-reduce(cross-pod) -> all-gather(intra-pod):
at 2 pods x 256 chips it moves 1/256th of the gradient across the DCI instead
of the whole tensor -- the standard multi-pod gradient schedule.

``compressed_psum_int8`` int8-quantizes shards before the cross-pod hop
(error feedback handled by the caller via the returned residual): a 4x wire
reduction on the slowest link, used by the optional low-bandwidth training
mode (EXPERIMENTS.md §Perf discusses when it pays off).

Both run under shard_map and are unit-tested on 8 host devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def hierarchical_psum(x: jax.Array, intra_axis: str, inter_axis: str) -> jax.Array:
    """psum over (intra, inter) via RS -> AR -> AG.  Must run inside
    shard_map with both axes present.  x's leading dim must divide the intra
    axis size."""
    # reduce-scatter intra-pod: each intra-rank owns one shard of the sum
    scattered = jax.lax.psum_scatter(x, intra_axis, scatter_dimension=0,
                                     tiled=True)
    # cross-pod all-reduce on the small shard only
    reduced = jax.lax.psum(scattered, inter_axis)
    # all-gather intra-pod to rebuild the full tensor
    return jax.lax.all_gather(reduced, intra_axis, axis=0, tiled=True)


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_int8(x: jax.Array, intra_axis: str, inter_axis: str):
    """Hierarchical psum with int8 cross-pod hop.  Returns (approx_sum,
    residual) -- the caller accumulates residual into the next step's input
    (error feedback).  Intra-pod stays full precision."""
    scattered = jax.lax.psum_scatter(x, intra_axis, scatter_dimension=0, tiled=True)
    q, scale = _quantize_int8(scattered)
    deq = q.astype(jnp.float32) * scale
    residual_local = scattered - deq
    reduced = jax.lax.psum(deq, inter_axis)
    full = jax.lax.all_gather(reduced, intra_axis, axis=0, tiled=True)
    residual = jax.lax.all_gather(residual_local, intra_axis, axis=0, tiled=True)
    return full, residual


def make_hierarchical_allreduce(mesh: Mesh, intra_axis: str = "data",
                                inter_axis: str = "pod"):
    """jit-able f(x sharded over intra) -> psum over both axes, hierarchical."""
    def fn(x):
        return hierarchical_psum(x, intra_axis, inter_axis)

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=P(intra_axis),
        out_specs=P(intra_axis),
    ))
