"""Distribution layer: per-architecture sharding rules (DP/FSDP/TP/EP/SP),
hierarchical + compressed collectives, fault tolerance, elastic re-meshing."""
from repro.distributed.sharding import (  # noqa: F401
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.distributed import collectives, elastic, fault  # noqa: F401
