"""Fault tolerance: checkpoint/restart policy + failure-injection helpers.

Layers of defense at 1000+ nodes:
  1. step-atomic checkpoints (repro.checkpoint) every ``save_every`` steps;
     COMMIT-marker protocol tolerates mid-write crashes;
  2. ``resumable_loop`` wraps any step function with auto-resume from the
     newest complete checkpoint -- a restarted job replays nothing and loses
     at most ``save_every - 1`` steps;
  3. deterministic data (batch = f(seed, step)) makes the replayed trajectory
     bit-identical, so a post-failure run converges identically (tested);
  4. straggler mitigation lives at the FL layer (deadline drop,
     repro.fl.server) and at the allocator layer (periodic re-solve);
  5. device loss triggers elastic re-meshing (repro.distributed.elastic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class RestartPolicy:
    save_every: int = 50
    keep: int = 3


def resumable_loop(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    manager: CheckpointManager,
    policy: RestartPolicy | None = None,
    fail_at: int | None = None,
    fail_phase: str = "pre_step",
):
    """Run ``state = step_fn(state, t)`` for t in [0, n_steps), checkpointing
    every ``policy.save_every`` steps and auto-resuming from the newest
    complete checkpoint.

    ``fail_at`` injects a crash (tests).  ``fail_phase`` picks where in the
    step it lands: ``"pre_step"`` before ``step_fn`` runs, ``"post_step"``
    after the step but before any ``manager.save`` -- the torn-write window
    the COMMIT protocol closes (the completed step's state dies with the
    process, so resume replays it from the last checkpoint; the loss bound
    is still at most ``save_every`` steps of work).
    """
    # In-body default: `policy=RestartPolicy()` in the signature is evaluated
    # once at def time, so every default caller would share (and could
    # mutate) ONE instance (tests/test_fault.py audits src/repro for this).
    if policy is None:
        policy = RestartPolicy()
    if fail_phase not in ("pre_step", "post_step"):
        raise ValueError(f"unknown fail_phase {fail_phase!r}")
    start_step, state, _ = manager.restore_latest(init_state)
    t0 = 0 if start_step is None else start_step
    state = init_state if start_step is None else state
    for t in range(t0, n_steps):
        if fail_at is not None and t == fail_at and fail_phase == "pre_step":
            raise RuntimeError(f"injected failure at step {t}")
        state = step_fn(state, t)
        if fail_at is not None and t == fail_at and fail_phase == "post_step":
            raise RuntimeError(
                f"injected failure after step {t} (pre-commit)")
        if (t + 1) % policy.save_every == 0 or t + 1 == n_steps:
            manager.save(t + 1, state)
    return state
