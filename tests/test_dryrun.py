"""Dry-run harness test (deliverable e): one representative cell must lower,
compile, and report analyses on the 512-placeholder-device production mesh.
Runs in a subprocess so the XLA device-count flag never leaks into this
process (smoke tests must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import json
    from repro.launch import dryrun  # sets XLA_FLAGS before any jax import

    res = dryrun.run_cell("gemma3-1b", "decode_32k", multi_pod=True)
    assert res["status"] == "ok", res
    assert res["n_chips"] == 512
    for key in ("flops", "bytes_accessed", "collective_bytes_total",
                "compile_s", "temp_size_in_bytes"):
        assert key in res, key
    # skip semantics
    skip = dryrun.run_cell("gemma-7b", "long_500k", multi_pod=False)
    assert skip["status"] == "skipped"
    print("DRYRUN-OK", json.dumps({k: res[k] for k in ("n_chips", "status")}))
    """
)


def test_dryrun_cell_multi_pod():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=540,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DRYRUN-OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])


def test_dryrun_artifacts_complete():
    """All 40 cells x 2 meshes have artifacts: 66 ok + 14 by-design skips."""
    art = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                       "artifacts", "dryrun")
    if not os.path.isdir(art):
        import pytest
        pytest.skip("dry-run artifacts not generated in this checkout")
    cells = []
    for name in os.listdir(art):
        if name.endswith(".json"):
            with open(os.path.join(art, name)) as f:
                cells.append(json.load(f))
    assert len(cells) == 80, len(cells)
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    assert len(ok) == 66 and len(skipped) == 14, (len(ok), len(skipped))
    assert not [c for c in cells if c["status"] == "error"]
