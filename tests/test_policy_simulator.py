"""AllocationPolicy registry + fixed-capacity scan simulator + Pallas intra
backend: registry completeness, mask-flip inactivity vs subset solves,
kernel-vs-reference parity on padded ServiceSets, scan-vs-legacy regression,
single-trace compilation, and the vmap-over-seeds batch entry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import intra, network, policy
from repro.core.types import ServiceSet, mask_inactive
from repro.fl import simulator
from repro.kernels.bisect_alloc import bisect_alloc

B = network.B_TOTAL_MHZ


def _random_padded_service(seed, n=7, k=33):
    """Random ServiceSet with ragged client counts AND some all-inactive rows."""
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.01, 0.3, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.01, 0.06, size=(n, k)).astype(np.float32)
    mask = np.zeros((n, k), dtype=bool)
    for i in range(n):
        mask[i, : rng.integers(2, k + 1)] = True
    mask[rng.integers(0, n)] = False          # one fully-inactive slot
    alpha = np.where(mask, alpha, 0.0)
    t_comp = np.where(mask, t_comp, 0.0)
    return ServiceSet(alpha=jnp.asarray(alpha), t_comp=jnp.asarray(t_comp),
                      mask=jnp.asarray(mask))


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

def test_registry_covers_all_paper_policies():
    assert set(simulator.POLICIES) <= set(policy.available())


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown policy"):
        policy.get_policy("nope")
    with pytest.raises(ValueError, match="intra backend"):
        policy.freq_fn("nope")


def test_unknown_policy_option_raises():
    """A typo'd option must raise, not silently fall back to the default."""
    svc = _random_padded_service(0)
    with pytest.raises(ValueError, match=r"alpha_fiar.*known options"):
        policy.get_policy("selfish", alpha_fiar=0.7)
    with pytest.raises(ValueError, match="unknown option"):
        policy.allocate("coop", svc, B, iterz=12)
    # every advertised option is still accepted
    b, f = policy.allocate("selfish", svc, B, n_bids=4, alpha_fair=0.7,
                           intra_backend="reference", iters=32)
    assert np.isfinite(np.asarray(b)).all()


@pytest.mark.parametrize("name", simulator.POLICIES)
@pytest.mark.parametrize("seed", [0, 1])
def test_permutation_equivariance(name, seed):
    """Permuting service rows permutes the allocation (deterministic spot
    check of the hypothesis property in tests/test_policy_properties.py)."""
    svc = _random_padded_service(seed)
    b, f = policy.allocate(name, svc, B)
    perm = np.random.default_rng(seed + 50).permutation(svc.n_services)
    svc_p = ServiceSet(alpha=svc.alpha[perm], t_comp=svc.t_comp[perm],
                       mask=svc.mask[perm])
    b_p, f_p = policy.allocate(name, svc_p, B)
    np.testing.assert_allclose(np.asarray(b_p), np.asarray(b)[perm],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f)[perm],
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", simulator.POLICIES)
def test_all_inactive_set_allocates_nothing(name):
    svc = _random_padded_service(4)
    none_active = jnp.zeros((svc.n_services,), dtype=bool)
    b, f = policy.allocate(name, mask_inactive(svc, none_active), B)
    assert float(jnp.sum(jnp.abs(b))) == 0.0
    assert float(jnp.sum(jnp.abs(f))) == 0.0


@pytest.mark.parametrize("name", simulator.POLICIES)
def test_policies_feasible_and_zero_on_inactive(name):
    svc = _random_padded_service(0)
    b, f = policy.allocate(name, svc, B)
    active = np.asarray(svc.service_active())
    np.testing.assert_allclose(float(jnp.sum(b)), B, rtol=1e-5)
    assert np.all(np.asarray(b)[~active] == 0.0)
    assert np.all(np.asarray(f)[~active] == 0.0)
    assert np.all(np.asarray(f) >= 0.0)


@pytest.mark.parametrize("name", simulator.POLICIES)
def test_mask_flip_matches_subset_solve(name):
    """Deactivating rows of a fixed-capacity set must equal solving the
    dense subset: the core invariant behind the scan simulator."""
    svc, _ = network.sample_services(jax.random.key(2), 6, k_max=28)
    active = jnp.array([True, False, True, True, False, True])
    idx = np.where(np.asarray(active))[0]
    sub = ServiceSet(alpha=svc.alpha[idx], t_comp=svc.t_comp[idx],
                     mask=svc.mask[idx])
    b_m, f_m = policy.allocate(name, mask_inactive(svc, active), B)
    b_s, f_s = policy.allocate(name, sub, B)
    np.testing.assert_allclose(np.asarray(b_m)[idx], np.asarray(b_s),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f_m)[idx], np.asarray(f_s),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Pallas kernel as intra backend (interpret mode on CPU).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bisect_alloc_kernel_matches_reference_on_padded_sets(seed):
    svc = _random_padded_service(seed)
    rng = np.random.default_rng(seed + 100)
    b = jnp.asarray(
        np.where(np.asarray(svc.service_active()),
                 rng.uniform(0.3, 3.0, size=svc.n_services), 0.0),
        jnp.float32,
    )
    t_k, balloc_k = bisect_alloc(svc.alpha, svc.t_comp, b, interpret=True)
    t_ref = intra.solve_round_time(svc, b)
    balloc_ref = intra.client_allocation(svc, b)
    act = np.asarray(svc.service_active()) & (np.asarray(b) > 0)
    np.testing.assert_allclose(np.asarray(t_k)[act], np.asarray(t_ref)[act],
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(balloc_k)[act],
                               np.asarray(balloc_ref)[act],
                               rtol=1e-3, atol=1e-5)


def test_pallas_intra_backend_matches_reference_freq():
    svc = _random_padded_service(3)
    b = jnp.where(svc.service_active(), B / svc.n_services, 0.0)
    f_ref = policy.freq_fn("reference")(svc, b)
    f_pal = policy.freq_fn("pallas")(svc, b)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_ref),
                               rtol=1e-4, atol=1e-6)
    s_ref = policy.client_split_fn("reference")(svc, b)
    s_pal = policy.client_split_fn("pallas")(svc, b)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=1e-3, atol=1e-5)


def test_simulator_runs_with_pallas_backend():
    cfg = simulator.SimConfig(policy="coop", n_services_total=2,
                              rounds_required=80, p_arrive=1.0, seed=0,
                              max_periods=40, intra_backend="pallas")
    ref = simulator.run_scan(dataclasses.replace(cfg, intra_backend="reference"))
    out = simulator.run_scan(cfg)
    assert out["finished"]
    assert out["durations"] == ref["durations"]


# ---------------------------------------------------------------------------
# Scan engine: regression vs legacy loop, single trace, batch entry.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", simulator.POLICIES)
def test_scan_reproduces_legacy_loop(name):
    cfg = simulator.SimConfig(policy=name, n_services_total=3,
                              rounds_required=150, p_arrive=2.0, seed=1,
                              max_periods=120)
    legacy = simulator.run(cfg)
    scan = simulator.run_scan(cfg)
    assert legacy["finished"] and scan["finished"]
    assert scan["durations"] == legacy["durations"]
    assert scan["avg_duration"] == legacy["avg_duration"]
    assert scan["periods"] == legacy["periods"]


def test_scan_single_trace_for_full_episode():
    """Acceptance bar: a capacity-10 episode compiles the allocation step
    exactly once -- arrivals/departures are mask flips, never retraces."""
    cfg = simulator.SimConfig(policy="coop", n_services_total=10,
                              rounds_required=60, p_arrive=3.0, seed=0,
                              max_periods=100)
    simulator.reset_trace_count()
    out = simulator.run_scan(cfg)
    assert out["finished"]
    assert simulator.trace_count() == 1
    # a second episode of the same shape reuses the compiled step entirely
    simulator.run_scan(dataclasses.replace(cfg, seed=0))
    assert simulator.trace_count() == 1


def test_batch_matches_single_seed_runs():
    base = simulator.SimConfig(policy="es", n_services_total=3,
                               rounds_required=100, p_arrive=2.0,
                               max_periods=100, k_max=32)
    seeds = [0, 1, 2]
    batch = simulator.run_batch(base, seeds)
    for i, s in enumerate(seeds):
        single = simulator.run_scan(dataclasses.replace(base, seed=s))
        assert list(batch["durations"][i]) == single["durations"]
        assert batch["avg_duration"][i] == single["avg_duration"]


def test_batch_episode_bitwise_identical_regardless_of_composition():
    """The documented claim of EXPERIMENTS.md: every episode of a run_batch
    sweep is *bitwise* identical to its own single-seed run_scan, no matter
    which other seeds share the batch -- durations AND the float per-period
    history, not just summary statistics."""
    base = simulator.SimConfig(policy="es", n_services_total=3,
                               rounds_required=100, p_arrive=2.0,
                               max_periods=100, k_max=32)
    b012 = simulator.run_batch(base, [0, 1, 2])
    b1 = simulator.run_batch(base, [1])
    b21 = simulator.run_batch(base, [2, 1])
    single = simulator.run_scan(dataclasses.replace(base, seed=1))

    for out, i in ((b012, 1), (b1, 0), (b21, 1)):
        assert list(out["durations"][i]) == single["durations"]
    # full-length float histories agree bitwise across batch compositions
    for key in ("freq_sum", "objective", "n_active", "n_clients"):
        np.testing.assert_array_equal(b012["history"][key][1],
                                      b1["history"][key][0])
        np.testing.assert_array_equal(b012["history"][key][1],
                                      b21["history"][key][1])
        # ... and match the single-seed scan over its reported periods
        p = single["periods"]
        np.testing.assert_array_equal(b012["history"][key][1][:p],
                                      single["history"][key])
