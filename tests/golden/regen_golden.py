"""Regenerate the golden run_batch summary pinned by
tests/test_golden_regression.py.

    PYTHONPATH=src python tests/golden/regen_golden.py

Only rerun this when a change is *supposed* to move the simulated
trajectories (e.g. a deliberate model change) -- never to paper over an
allocator refactor that drifted.  The config lives here and is copied into
the JSON so the test replays exactly what was pinned.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.fl import simulator

# Small enough for CI wall-clock, large enough that every policy sees
# arrivals, departures, and contention (the Fig. 11-15 regime in miniature).
CONFIG = dict(
    n_services_total=3,
    rounds_required=600,
    p_arrive=3.0,
    max_periods=150,
    mean_clients=12.0,
    var_clients=9.0,
    k_max=28,
    seed=0,
)
SEEDS = [0, 1, 2]
POLICIES = list(simulator.POLICIES)

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "longterm_summary.json")


def build() -> dict:
    golden: dict = {"config": CONFIG, "seeds": SEEDS, "policies": {}}
    for pol in POLICIES:
        cfg = simulator.SimConfig(policy=pol, **CONFIG)
        out = simulator.run_batch(cfg, SEEDS)
        mean_freq = out["history"]["freq_sum"].mean(axis=1)
        golden["policies"][pol] = {
            "durations": np.asarray(out["durations"]).astype(int).tolist(),
            "avg_duration": [float(x) for x in out["avg_duration"]],
            "finished": [bool(x) for x in out["finished"]],
            "mean_freq_sum": [float(x) for x in mean_freq],
        }
    return golden


if __name__ == "__main__":
    with open(OUT, "w") as fp:
        json.dump(build(), fp, indent=1, sort_keys=True)
        fp.write("\n")
    print(f"wrote {OUT}")
