"""Regenerate the golden co-training summary pinned by
tests/test_cotrain.py::test_golden_cotrain_summary.

    PYTHONPATH=src python tests/golden/regen_cotrain.py

Only rerun this when a change is *supposed* to move the co-trained
trajectories (a deliberate change to the training task, the straggler
model, or the simulated environment) -- never to paper over an allocator or
coupling refactor that drifted: durations are separately pinned bitwise
against the duration engine, and training losses/accuracies are pinned here.
The config lives in this file and is copied into the JSON so the test
replays exactly what was pinned.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import network
from repro.fl import cotrain, simulator

# Mirrors the BASE/TRAIN/NET fixtures of tests/test_cotrain.py so the golden
# replay shares the same compiled episodes as the rest of the suite.
CONFIG = dict(n_services_total=3, rounds_required=30, p_arrive=2.0,
              max_periods=50, k_max=12, mean_clients=5.0, var_clients=2.0,
              seed=0)
NET = dict(period_s=1.0, mean_clients=5.0, var_clients=2.0)
TRAIN = dict(vocab=16, seq_len=6, batch_size=2, eval_batch=8, rounds_cap=2)
SEEDS = [0, 1, 2]
POLICIES = ["coop", "selfish", "es"]

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "cotrain_summary.json")


def build() -> dict:
    golden: dict = {"config": CONFIG, "net": NET, "train": TRAIN,
                    "seeds": SEEDS, "policies": {}}
    train = cotrain.TrainSpec(**TRAIN)
    net = network.NetworkConfig(**NET)
    for pol in POLICIES:
        cfg = simulator.SimConfig(policy=pol, **CONFIG)
        out = cotrain.run_cotrain_batch(cfg, train, SEEDS, net)
        periods = np.asarray(out["periods"])
        golden["policies"][pol] = {
            "durations": np.asarray(out["durations"]).astype(int).tolist(),
            "trained_rounds":
                np.asarray(out["trained_rounds"]).astype(int).tolist(),
            "periods": periods.astype(int).tolist(),
            "final_loss": [
                np.asarray(out["history"]["loss"][i, p - 1],
                           dtype=float).tolist()
                for i, p in enumerate(periods)],
            "final_acc": [
                np.asarray(out["history"]["acc"][i, p - 1],
                           dtype=float).tolist()
                for i, p in enumerate(periods)],
        }
    return golden


if __name__ == "__main__":
    with open(OUT, "w") as fp:
        json.dump(build(), fp, indent=1, sort_keys=True)
        fp.write("\n")
    print(f"wrote {OUT}")
