"""Property-based tests (hypothesis) for the FedAvg aggregation in
``repro.fl.server``: the weighted average is permutation-invariant in the
client axis, dropped clients (weight 0) never contribute -- not even
non-finite deltas from diverged runs -- and the all-straggler round is the
exact identity on params instead of leaning on the 1e-12 denominator clamp.
Deterministic spot-checks of the same invariants run without hypothesis in
tests/test_fl_runtime.py / tests/test_cotrain.py (the co-simulation's
all-straggler episode), so the properties are exercised even where
hypothesis is absent; CI installs hypothesis and fails the build if these
would silently skip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
import hypothesis.strategies as st  # noqa: E402

from repro.fl import compression, server  # noqa: E402


def _deltas(rng, n_clients: int):
    """Random two-leaf pytree of per-client deltas (C, ...)."""
    return {
        "w": jnp.asarray(rng.normal(size=(n_clients, 3, 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_clients, 4)).astype(np.float32)),
    }


def _weights(rng, n_clients: int, p_drop: float):
    w = rng.uniform(0.1, 2.0, size=n_clients)
    w[rng.uniform(size=n_clients) < p_drop] = 0.0
    return jnp.asarray(w.astype(np.float32))


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_clients=st.integers(1, 12),
       p_drop=st.floats(0.0, 0.9))
def test_fedavg_round_permutation_invariant(seed, n_clients, p_drop):
    """Client order is an artifact of batching, never of the average."""
    rng = np.random.default_rng(seed)
    deltas = _deltas(rng, n_clients)
    weights = _weights(rng, n_clients, p_drop)
    perm = jnp.asarray(rng.permutation(n_clients))
    base = server.fedavg_round(deltas, weights)
    permuted = server.fedavg_round(
        jax.tree.map(lambda d: d[perm], deltas), weights[perm])
    for k in base:
        np.testing.assert_allclose(base[k], permuted[k],
                                   rtol=1e-5, atol=1e-6)


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_clients=st.integers(2, 12))
def test_dropped_clients_never_contribute(seed, n_clients):
    """Replacing every weight-0 client's delta with garbage -- huge values,
    inf, NaN -- must not move the aggregate AT ALL (the numerator masks on
    w > 0 instead of trusting 0 * delta, so a diverged straggler cannot
    poison the average)."""
    rng = np.random.default_rng(seed)
    deltas = _deltas(rng, n_clients)
    weights = _weights(rng, n_clients, p_drop=0.5)
    dropped = np.asarray(weights) == 0.0
    poison = jax.tree.map(
        lambda d: jnp.where(
            jnp.asarray(dropped).reshape((-1,) + (1,) * (d.ndim - 1)),
            jnp.float32(np.nan), d),
        deltas)
    base = server.fedavg_round(deltas, weights)
    poisoned = server.fedavg_round(poison, weights)
    for k in base:
        np.testing.assert_array_equal(base[k], poisoned[k])
        assert np.all(np.isfinite(np.asarray(poisoned[k])))


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_clients=st.integers(1, 12))
def test_all_straggler_round_is_identity_on_params(seed, n_clients):
    """Zero participants: the aggregated delta is exactly zero (even with
    non-finite per-client deltas) and a full round step returns params
    unchanged with loss reported as 0 -- not sum/1e-12."""
    rng = np.random.default_rng(seed)
    deltas = jax.tree.map(
        lambda d: d.at[0].set(jnp.inf) if n_clients > 0 else d,
        _deltas(rng, n_clients))
    zeros = jnp.zeros((n_clients,), jnp.float32)
    agg = server.fedavg_round(deltas, zeros)
    for k in agg:
        np.testing.assert_array_equal(np.asarray(agg[k]), 0.0)

    # end-to-end: a real round step with every client past the deadline
    def loss_fn(p, batch):
        return jnp.mean((p["w"] * batch["x"] - batch["y"]) ** 2)

    step = server.make_fl_round_step(loss_fn, local_steps=2, client_lr=0.3)
    params = {"w": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}
    batches = {
        "x": jnp.asarray(rng.normal(
            size=(n_clients, 2, 3)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(
            size=(n_clients, 2, 3)).astype(np.float32)),
    }
    new_params, metrics = step(params, batches, zeros)
    np.testing.assert_array_equal(np.asarray(new_params["w"]),
                                  np.asarray(params["w"]))
    assert float(metrics["loss"]) == 0.0
    assert int(metrics["participating"]) == 0


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_rounds=st.integers(1, 12),
       k_frac=st.floats(0.05, 0.9))
def test_error_feedback_telescopes_exactly(seed, n_rounds, k_frac):
    """Error feedback is lossless in aggregate: over any horizon the sum of
    transmitted sparse updates plus the final residual equals the sum of the
    raw per-round deltas (the residual carries exactly what was withheld,
    never invents or drops mass)."""
    rng = np.random.default_rng(seed)
    deltas = [
        {"w": jnp.asarray(rng.normal(size=(17,)).astype(np.float32))}
        for _ in range(n_rounds)
    ]
    residual = None
    sent = jnp.zeros((17,))
    for d in deltas:
        sparse, residual = compression.topk_sparsify(d, k_frac, residual)
        sent = sent + sparse["w"]
    raw = sum(np.asarray(d["w"], np.float64) for d in deltas)
    np.testing.assert_allclose(
        np.asarray(sent, np.float64) + np.asarray(residual["w"], np.float64),
        raw, rtol=1e-4, atol=1e-4)


@hypothesis.settings(deadline=None, max_examples=10)
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_residual_dtype_preserved(seed):
    """The client-held residual must keep each leaf's dtype round over round
    (a silent fp32 upcast of a bf16 leaf would double client memory and
    change the re-injected values)."""
    rng = np.random.default_rng(seed)
    delta = {
        "hi": jnp.asarray(rng.normal(size=(12,)).astype(np.float32)),
        "lo": jnp.asarray(rng.normal(size=(12,)),
                          dtype=jnp.bfloat16),
    }
    for fn in (lambda d, r: compression.topk_sparsify(d, 0.25, r),
               compression.int8_quantize):
        residual = None
        for _ in range(3):
            out, residual = fn(delta, residual)
            assert residual["hi"].dtype == jnp.float32
            assert residual["lo"].dtype == jnp.bfloat16
            assert out["lo"].dtype == jnp.bfloat16


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), size=st.integers(1, 200),
       spread=st.floats(1e-3, 1e3))
def test_int8_roundtrip_error_at_most_half_scale(seed, size, spread):
    """Symmetric int8 quantization: every element's round-trip error is at
    most scale/2 (round-to-nearest onto a 1/127-of-max grid), for any leaf
    magnitude."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((spread * rng.normal(size=(size,))).astype(np.float32))
    deq, res = compression.int8_quantize({"w": x})
    scale = max(float(jnp.max(jnp.abs(x))), 1e-12) / 127.0
    assert float(jnp.max(jnp.abs(res["w"]))) <= scale * 0.5 + 1e-6 * scale
    np.testing.assert_allclose(np.asarray(deq["w"] + res["w"]), np.asarray(x),
                               rtol=1e-6, atol=1e-7)


def test_weighted_mean_matches_manual_reference():
    """Deterministic spot-check: with positive weights the masked-numerator
    form is the plain weighted mean, bit-for-bit in float64 reference."""
    rng = np.random.default_rng(0)
    deltas = _deltas(rng, 5)
    weights = jnp.asarray([1.0, 0.0, 2.0, 0.5, 0.0], jnp.float32)
    out = server.fedavg_round(deltas, weights)
    w = np.asarray(weights)
    for k, d in deltas.items():
        d = np.asarray(d)
        ref = np.tensordot(w, d, axes=(0, 0)) / w.sum()
        np.testing.assert_allclose(out[k], ref, rtol=1e-6)
