"""Property-based tests (hypothesis) for the FedAvg aggregation in
``repro.fl.server``: the weighted average is permutation-invariant in the
client axis, dropped clients (weight 0) never contribute -- not even
non-finite deltas from diverged runs -- and the all-straggler round is the
exact identity on params instead of leaning on the 1e-12 denominator clamp.
Deterministic spot-checks of the same invariants run without hypothesis in
tests/test_fl_runtime.py / tests/test_cotrain.py (the co-simulation's
all-straggler episode), so the properties are exercised even where
hypothesis is absent; CI installs hypothesis and fails the build if these
would silently skip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
import hypothesis.strategies as st  # noqa: E402

from repro.fl import server  # noqa: E402


def _deltas(rng, n_clients: int):
    """Random two-leaf pytree of per-client deltas (C, ...)."""
    return {
        "w": jnp.asarray(rng.normal(size=(n_clients, 3, 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_clients, 4)).astype(np.float32)),
    }


def _weights(rng, n_clients: int, p_drop: float):
    w = rng.uniform(0.1, 2.0, size=n_clients)
    w[rng.uniform(size=n_clients) < p_drop] = 0.0
    return jnp.asarray(w.astype(np.float32))


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_clients=st.integers(1, 12),
       p_drop=st.floats(0.0, 0.9))
def test_fedavg_round_permutation_invariant(seed, n_clients, p_drop):
    """Client order is an artifact of batching, never of the average."""
    rng = np.random.default_rng(seed)
    deltas = _deltas(rng, n_clients)
    weights = _weights(rng, n_clients, p_drop)
    perm = jnp.asarray(rng.permutation(n_clients))
    base = server.fedavg_round(deltas, weights)
    permuted = server.fedavg_round(
        jax.tree.map(lambda d: d[perm], deltas), weights[perm])
    for k in base:
        np.testing.assert_allclose(base[k], permuted[k],
                                   rtol=1e-5, atol=1e-6)


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_clients=st.integers(2, 12))
def test_dropped_clients_never_contribute(seed, n_clients):
    """Replacing every weight-0 client's delta with garbage -- huge values,
    inf, NaN -- must not move the aggregate AT ALL (the numerator masks on
    w > 0 instead of trusting 0 * delta, so a diverged straggler cannot
    poison the average)."""
    rng = np.random.default_rng(seed)
    deltas = _deltas(rng, n_clients)
    weights = _weights(rng, n_clients, p_drop=0.5)
    dropped = np.asarray(weights) == 0.0
    poison = jax.tree.map(
        lambda d: jnp.where(
            jnp.asarray(dropped).reshape((-1,) + (1,) * (d.ndim - 1)),
            jnp.float32(np.nan), d),
        deltas)
    base = server.fedavg_round(deltas, weights)
    poisoned = server.fedavg_round(poison, weights)
    for k in base:
        np.testing.assert_array_equal(base[k], poisoned[k])
        assert np.all(np.isfinite(np.asarray(poisoned[k])))


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_clients=st.integers(1, 12))
def test_all_straggler_round_is_identity_on_params(seed, n_clients):
    """Zero participants: the aggregated delta is exactly zero (even with
    non-finite per-client deltas) and a full round step returns params
    unchanged with loss reported as 0 -- not sum/1e-12."""
    rng = np.random.default_rng(seed)
    deltas = jax.tree.map(
        lambda d: d.at[0].set(jnp.inf) if n_clients > 0 else d,
        _deltas(rng, n_clients))
    zeros = jnp.zeros((n_clients,), jnp.float32)
    agg = server.fedavg_round(deltas, zeros)
    for k in agg:
        np.testing.assert_array_equal(np.asarray(agg[k]), 0.0)

    # end-to-end: a real round step with every client past the deadline
    def loss_fn(p, batch):
        return jnp.mean((p["w"] * batch["x"] - batch["y"]) ** 2)

    step = server.make_fl_round_step(loss_fn, local_steps=2, client_lr=0.3)
    params = {"w": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}
    batches = {
        "x": jnp.asarray(rng.normal(
            size=(n_clients, 2, 3)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(
            size=(n_clients, 2, 3)).astype(np.float32)),
    }
    new_params, metrics = step(params, batches, zeros)
    np.testing.assert_array_equal(np.asarray(new_params["w"]),
                                  np.asarray(params["w"]))
    assert float(metrics["loss"]) == 0.0
    assert int(metrics["participating"]) == 0


def test_weighted_mean_matches_manual_reference():
    """Deterministic spot-check: with positive weights the masked-numerator
    form is the plain weighted mean, bit-for-bit in float64 reference."""
    rng = np.random.default_rng(0)
    deltas = _deltas(rng, 5)
    weights = jnp.asarray([1.0, 0.0, 2.0, 0.5, 0.0], jnp.float32)
    out = server.fedavg_round(deltas, weights)
    w = np.asarray(weights)
    for k, d in deltas.items():
        d = np.asarray(d)
        ref = np.tensordot(w, d, axes=(0, 0)) / w.sum()
        np.testing.assert_allclose(out[k], ref, rtol=1e-6)
