"""Per-kernel correctness: interpret-mode Pallas vs the pure-jnp oracle in
ref.py, swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import intra, network
from repro.kernels import ref
from repro.kernels.bisect_alloc import bisect_alloc
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 2, 256, 64),
    (1, 8, 1, 512, 128),   # MQA
    (2, 2, 2, 128, 256),   # MHA, gemma head_dim
    (1, 4, 4, 384, 64),    # non-pow2 seq (3 blocks of 128)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [32, 128, 1024])
def test_flash_attention_sliding_window(window):
    b, hq, hkv, s, d = 1, 4, 1, 512, 64
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=128, block_k=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_flash_attention_non_causal():
    b, hq, hkv, s, d = 2, 2, 2, 256, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    out = flash_attention(q, k, v, causal=False, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d,valid", [
    (2, 8, 2, 512, 64, 512),
    (2, 8, 2, 512, 64, 317),   # partial cache
    (1, 4, 1, 2048, 128, 1500),
    (4, 4, 4, 256, 256, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, hq, hkv, s, d, valid, dtype):
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = decode_attention(q, k, v, jnp.int32(valid), block_k=256, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, jnp.int32(valid))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# bisect_alloc (the paper's kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(5, 18), (16, 25), (64, 40), (3, 130)])
def test_bisect_alloc_matches_core_solver(n, k):
    svc, _ = network.sample_services(jax.random.key(4), n, k_max=k)
    b = jax.random.uniform(jax.random.key(5), (n,), minval=0.2, maxval=4.0)
    t_star, b_alloc = bisect_alloc(svc.alpha, svc.t_comp, b, interpret=True)
    t_ref, b_ref = ref.bisect_alloc_ref(svc.alpha, svc.t_comp, b)
    np.testing.assert_allclose(np.asarray(t_star), np.asarray(t_ref), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(b_alloc), np.asarray(b_ref), rtol=1e-3, atol=1e-5)


def test_bisect_alloc_budget_and_equalization():
    svc, _ = network.sample_services(jax.random.key(6), 12, k_max=30)
    b = jnp.full((12,), 1.5)
    t_star, b_alloc = bisect_alloc(svc.alpha, svc.t_comp, b, interpret=True)
    np.testing.assert_allclose(np.asarray(b_alloc.sum(-1)), 1.5, rtol=1e-5)
    finish = svc.t_comp + svc.alpha / jnp.maximum(b_alloc, 1e-30)
    finish = jnp.where(svc.mask, finish, t_star[:, None])
    np.testing.assert_allclose(
        np.asarray(finish), np.asarray(t_star)[:, None] * np.ones_like(finish),
        rtol=1e-3,
    )


# ---------------------------------------------------------------------------
# mlstm_chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,dh,chunk", [
    (2, 2, 256, 64, 128),
    (1, 4, 512, 128, 128),
    (2, 1, 256, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunk_matches_parallel_oracle(b, h, s, dh, chunk, dtype):
    ks = jax.random.split(jax.random.key(7), 5)
    q = jax.random.normal(ks[0], (b, h, s, dh), dtype)
    k = jax.random.normal(ks[1], (b, h, s, dh), dtype) / jnp.sqrt(dh).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, s, dh), dtype)
    ig = (jax.random.normal(ks[3], (b, h, s)) * 0.5).astype(dtype)
    fg = (jax.random.normal(ks[4], (b, h, s)) * 0.5 + 2.0).astype(dtype)
    out = mlstm_chunk(q, k, v, ig, fg, chunk=chunk, interpret=True)
    expect = ref.mlstm_chunk_ref(q, k, v, ig, fg)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol
    )
