"""Unit tests for model building blocks: MoE dispatch vs dense oracle, mLSTM
chunkwise vs fully-parallel vs sequential, SSM scan vs naive recurrence,
masks, RoPE/M-RoPE, chunked attention vs plain attention."""
import dataclasses

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, moe, ssm
from repro.models.config import ModelConfig


def _moe_cfg(e=8, k=2, cap=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=0, vocab_size=64, n_experts=e, n_experts_per_token=k,
        d_ff_expert=48, capacity_factor=cap, dtype="float32",
    )


def test_moe_dispatch_matches_dense_oracle():
    cfg = _moe_cfg()
    p = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (3, 10, cfg.d_model))
    out, aux = moe.apply_moe(p, x, cfg)
    ref = moe.apply_moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
    assert float(aux) > 0.0


def test_moe_capacity_drop_reduces_output_only():
    """With a tight capacity some tokens are dropped (output -> shared-expert
    only); dispatch must stay finite and shaped."""
    cfg = _moe_cfg(cap=0.25)
    p = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out, _ = moe.apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_shared_expert_included():
    cfg = dataclasses.replace(_moe_cfg(), n_shared_experts=1)
    p = moe.init_moe(jax.random.key(0), cfg)
    assert "shared" in p
    x = jax.random.normal(jax.random.key(1), (2, 6, cfg.d_model))
    out, _ = moe.apply_moe(p, x, cfg)
    ref = moe.apply_moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# mLSTM forms agree.
# ---------------------------------------------------------------------------

def _mlstm_inputs(key, b=2, h=2, s=64, dh=16):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, s, dh))
    k = jax.random.normal(ks[1], (b, h, s, dh)) / jnp.sqrt(dh)
    v = jax.random.normal(ks[2], (b, h, s, dh))
    i = jax.random.normal(ks[3], (b, h, s)) * 0.5
    f = jax.random.normal(ks[4], (b, h, s)) * 0.5 + 2.0
    return q, k, v, i, f


def test_mlstm_chunkwise_matches_parallel():
    q, k, v, i, f = _mlstm_inputs(jax.random.key(0))
    y_par, _, _ = ssm.mlstm_parallel(q, k, v, i, f)
    for chunk in (8, 16, 64):
        y_chunk, _ = ssm.mlstm_chunkwise(q, k, v, i, f, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(y_chunk), np.asarray(y_par), rtol=2e-4, atol=2e-4,
            err_msg=f"chunk={chunk}",
        )


def test_mlstm_sequential_matches_parallel():
    q, k, v, i, f = _mlstm_inputs(jax.random.key(1), s=16)
    y_par, _, _ = ssm.mlstm_parallel(q, k, v, i, f)
    b, h, s, dh = q.shape
    C = jnp.zeros((b, h, dh, dh))
    n = jnp.zeros((b, h, dh))
    m = jnp.full((b, h), -1e30)
    ys = []
    for t in range(s):
        y, C, n, m = ssm.mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                    i[:, :, t], f[:, :, t], C, n, m)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=2)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par), rtol=2e-4, atol=2e-4)


def test_mlstm_chunkwise_state_carry():
    """Splitting a sequence across two chunked calls equals one call."""
    q, k, v, i, f = _mlstm_inputs(jax.random.key(2), s=64)
    y_full, st_full = ssm.mlstm_chunkwise(q, k, v, i, f, chunk=16)
    half = 32
    y1, st1 = ssm.mlstm_chunkwise(q[:, :, :half], k[:, :, :half], v[:, :, :half],
                                  i[:, :, :half], f[:, :, :half], chunk=16)
    y2, st2 = ssm.mlstm_chunkwise(q[:, :, half:], k[:, :, half:], v[:, :, half:],
                                  i[:, :, half:], f[:, :, half:], state=st1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=2)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    for a, b_ in zip(st_full, st2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Selective SSM scan.
# ---------------------------------------------------------------------------

def test_ssm_scan_matches_naive_recurrence():
    b, s, d, n = 2, 24, 4, 3
    key = jax.random.key(0)
    a = jax.random.uniform(key, (b, s, d, n), minval=0.5, maxval=0.99)
    bx = jax.random.normal(jax.random.key(1), (b, s, d, n))
    h = ssm._ssm_scan(a, bx)
    h_ref = np.zeros((b, d, n))
    outs = []
    for t in range(s):
        h_ref = np.asarray(a[:, t]) * h_ref + np.asarray(bx[:, t])
        outs.append(h_ref.copy())
    np.testing.assert_allclose(np.asarray(h), np.stack(outs, 1), rtol=1e-5, atol=1e-5)


def test_causal_depthwise_conv_state_carry():
    x = jax.random.normal(jax.random.key(0), (2, 20, 6))
    w = jax.random.normal(jax.random.key(1), (4, 6))
    y_full, _ = ssm.causal_depthwise_conv(x, w)
    y1, st = ssm.causal_depthwise_conv(x[:, :12], w)
    y2, _ = ssm.causal_depthwise_conv(x[:, 12:], w, st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Attention plumbing.
# ---------------------------------------------------------------------------

def test_chunked_attention_matches_plain():
    b, s, h, d = 2, 64, 4, 16
    q = jax.random.normal(jax.random.key(0), (b, s, h, d))
    k = jax.random.normal(jax.random.key(1), (b, s, h, d))
    v = jax.random.normal(jax.random.key(2), (b, s, h, d))
    ref = layers.chunked_attention(q, k, v, causal=True, chunk_size=s)
    for chunk in (8, 16, 32):
        out = layers.chunked_attention(q, k, v, causal=True, chunk_size=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_sliding_window_mask():
    m = layers.make_attention_mask(8, 8, causal=True, window=3)
    m = np.asarray(m)
    assert m[5, 5] and m[5, 4] and m[5, 3]
    assert not m[5, 2] and not m[5, 6]
    # traced window_active=False disables the window
    m2 = np.asarray(layers.make_attention_mask(
        8, 8, causal=True, window=3, window_active=jnp.bool_(False)))
    assert m2[5, 0]


def test_gqa_matches_repeated_mha():
    b, s, hq, hkv, d = 2, 10, 8, 2, 16
    q = jax.random.normal(jax.random.key(0), (b, s, hq, d))
    k = jax.random.normal(jax.random.key(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.key(2), (b, s, hkv, d))
    mask = layers.make_attention_mask(s, s)
    out = layers.attention(q, k, v, mask)
    k_rep = jnp.repeat(k, hq // hkv, axis=2)
    v_rep = jnp.repeat(v, hq // hkv, axis=2)
    # repeat layout: head h of q maps to kv head h // (hq//hkv); jnp.repeat
    # produces exactly that grouping
    ref = layers.attention(q, k_rep, v_rep, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative position: shifting q and k
    positions together leaves q.k inner products unchanged."""
    b, s, h, d = 1, 6, 2, 32
    q = jax.random.normal(jax.random.key(0), (b, s, h, d))
    k = jax.random.normal(jax.random.key(1), (b, s, h, d))
    pos = jnp.arange(s)[None, :]
    q1 = layers.apply_rope(q, pos)
    k1 = layers.apply_rope(k, pos)
    q2 = layers.apply_rope(q, pos + 17)
    k2 = layers.apply_rope(k, pos + 17)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
    s2 = jnp.einsum("bqhd,bkhd->bhqk", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_mrope_reduces_to_rope_when_streams_equal():
    b, s, h, d = 1, 8, 2, 32
    x = jax.random.normal(jax.random.key(0), (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    pos3 = jnp.broadcast_to(pos[None], (3, b, s))
    ref = layers.apply_rope(x, pos)
    out = layers.apply_mrope(x, pos3, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    window=st.integers(1, 8),
    s=st.integers(2, 24),
)
def test_property_window_mask_bandwidth(seed, window, s):
    m = np.asarray(layers.make_attention_mask(s, s, causal=True, window=window))
    q_idx, k_idx = np.nonzero(m)
    assert np.all(q_idx - k_idx >= 0)
    assert np.all(q_idx - k_idx < window)


def test_cross_entropy_matches_numpy():
    logits = jax.random.normal(jax.random.key(0), (2, 5, 11))
    labels = jax.random.randint(jax.random.key(1), (2, 5), 0, 11)
    got = float(layers.softmax_cross_entropy(logits, labels))
    l = np.asarray(logits, dtype=np.float64)
    p = np.exp(l - l.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = -np.mean(np.log(np.take_along_axis(p, np.asarray(labels)[..., None], -1)))
    np.testing.assert_allclose(got, ref, rtol=1e-5)


def test_int8_kv_cache_decode_accuracy():
    """int8 KV cache (per-token/head scales) halves decode memory at <1% logit
    error -- the §Perf decode hillclimb lever."""
    import dataclasses
    from repro import configs
    from repro.models import registry

    cfg = configs.get_smoke_config("command-r-35b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m = registry.build_model(cfg)
    m8 = registry.build_model(cfg8)
    params = m.init(jax.random.key(0))
    b, s = 2, 32
    tok = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    new = jax.random.randint(jax.random.key(2), (b, 1), 0, cfg.vocab_size)
    _, c = m.prefill(params, {"tokens": tok}, max_len=s + 4)
    ld, _ = m.decode_step(params, c, new)
    _, c8 = m8.prefill(params, {"tokens": tok}, max_len=s + 4)
    ld8, _ = m8.decode_step(params, c8, new)
    assert c8["k"].dtype == jnp.int8
    rel = float(jnp.max(jnp.abs(ld8 - ld))) / float(jnp.max(jnp.abs(ld)))
    assert rel < 0.02, rel
