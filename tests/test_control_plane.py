"""Control-plane contracts (fl.control_plane + launch.allocd).

The load-bearing one is the differential replay: a live daemon that never
serves stale produces an allocation stream bitwise equal to
``simulator.run_scan`` fed the same admission trace -- the online path and
the offline reference share one ``_period_step``, and the healthy heartbeat
mask is a bitwise no-op.  The rest pin admission bookkeeping, heartbeat
liveness, COMMIT-protocol checkpoint/resume, and the deadline-miss
(stale-decision) degradation of the asyncio front end.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro import scenarios
from repro.checkpoint import CheckpointManager
from repro.distributed import fault
from repro.fl import control_plane
from repro.fl.control_plane import ControlPlane, ControlPlaneConfig
from repro.launch import allocd

_FAST = dict(capacity=6, k_max=6, rounds_required=60, seed=3)
# Services that never complete within a test's horizon (a lone service can
# clear 60 rounds in one 20 s period at full bandwidth).
_PERSIST = dict(capacity=6, k_max=6, rounds_required=100_000, seed=3)


def _drive(plane: ControlPlane, schedule: dict, n_periods: int,
           heartbeat_all: bool = False):
    """Scripted synchronous serving: admissions land before the period."""
    for p in range(n_periods):
        for sid, k in schedule.get(p, ()):
            plane.admit(sid, k)
        if heartbeat_all:
            for sid in list(plane.services):
                plane.heartbeat(sid)
        plane.tick()
    return plane


_SCHEDULE = {0: [("a", 4), ("b", 3)], 2: [("c", 5)], 5: [("d", 2)]}


@pytest.mark.parametrize("channel,churn", [
    ("iid", "none"),
    ("gauss_markov", "gilbert"),
])
def test_differential_replay_bitwise(channel, churn):
    """Live decisions == run_scan(collect_alloc) on the recorded trace,
    bit for bit, including under stochastic channel evolution and seeded
    churn, across admissions AND completion-based departures."""
    cfg = ControlPlaneConfig(channel_process=channel, churn_process=churn,
                             **_FAST)
    plane = _drive(ControlPlane(cfg), _SCHEDULE, 12)
    assert plane.metrics["admitted"] == 4
    assert plane.metrics["retired"] > 0, (
        "schedule must exercise completion-based departure")
    assert plane.replayable
    ref = plane.replay_reference()["history"]
    live_b = np.stack([d.b for d in plane.decisions])
    live_f = np.stack([d.f for d in plane.decisions])
    live_active = np.stack([d.active for d in plane.decisions])
    assert np.array_equal(np.asarray(ref["b"]), live_b)
    assert np.array_equal(np.asarray(ref["f"]), live_f)
    assert np.array_equal(np.asarray(ref["active"]), live_active)


def test_healthy_heartbeats_are_a_bitwise_noop():
    """Liveness tracking on + every client heartbeating == liveness off:
    the all-True availability mask must not perturb one bit."""
    base = _drive(ControlPlane(ControlPlaneConfig(**_FAST)), _SCHEDULE, 8)
    hb_cfg = ControlPlaneConfig(heartbeat_timeout_periods=2, **_FAST)
    hb = _drive(ControlPlane(hb_cfg), _SCHEDULE, 8, heartbeat_all=True)
    assert hb.metrics["heartbeat_drops"] == 0
    for d0, d1 in zip(base.decisions, hb.decisions):
        assert np.array_equal(d0.b, d1.b) and np.array_equal(d0.f, d1.f)


def test_heartbeat_timeout_drops_then_reclears():
    """A silent client is dropped from the clear after the timeout and
    re-enters the next period after heartbeating again -- never silently:
    the drops land in ``metrics['heartbeat_drops']``."""
    cfg = ControlPlaneConfig(heartbeat_timeout_periods=1, **_PERSIST)
    plane = ControlPlane(cfg)
    twin = ControlPlane(ControlPlaneConfig(**_PERSIST))  # liveness off
    for p in [plane, twin]:
        p.admit("a", 4)
        p.admit("b", 4)
    starved = []
    for period in range(6):
        plane.heartbeat("b")                 # "a" goes silent after admit
        d = plane.tick()
        t = twin.tick()
        if period >= 2:                      # past the 1-period timeout
            starved.append((d, t))
    assert plane.metrics["heartbeat_drops"] > 0
    # The drops are recorded per period, so the masked episode is STILL
    # replayable: run_scan fed the recorded ``avail`` planes reproduces the
    # served stream bitwise (PR 8 -- drops no longer falsify the trace).
    assert plane.replayable
    assert plane.recorded_avail() is not None
    ref = plane.replay_reference()
    b_ref = np.asarray(ref["history"]["b"])
    for d in plane.decisions:
        np.testing.assert_array_equal(np.asarray(d.b), b_ref[d.period])
    # Dropping every client of "a" must change the clear vs the healthy twin.
    assert any(not np.array_equal(d.b, t.b) for d, t in starved)
    # Re-clear: once "a" heartbeats again its cohort re-enters the solve.
    drops_before = plane.metrics["heartbeat_drops"]
    plane.heartbeat("a")
    plane.heartbeat("b")
    plane.tick()
    assert plane.metrics["heartbeat_drops"] == drops_before


def test_admission_validation_and_slot_accounting():
    plane = ControlPlane(ControlPlaneConfig(capacity=2, k_max=4,
                                            rounds_required=10_000))
    plane.admit("a", 3)
    with pytest.raises(ValueError, match="already admitted"):
        plane.admit("a", 2)
    with pytest.raises(ValueError, match="n_clients"):
        plane.admit("b", 5)
    plane.admit("b", 2)
    assert plane.free_slots == 0
    with pytest.raises(RuntimeError, match="slots occupied"):
        plane.admit("c", 2)
    assert plane.metrics["rejected"] == 1
    plane.retire("a")
    assert plane.free_slots == 1
    assert not plane.replayable          # forced retire breaks the trace
    with pytest.raises(RuntimeError, match="not replayable"):
        plane.replay_reference()


def test_allocation_of_reports_latest_decision():
    plane = ControlPlane(ControlPlaneConfig(**_PERSIST))
    plane.admit("a", 4)
    plane.tick()
    got = plane.allocation_of("a")
    assert got["b_mhz"] > 0 and got["stale"] is False
    with pytest.raises(KeyError):
        plane.allocation_of("ghost")


def test_checkpoint_resume_bitwise(tmp_path):
    """COMMIT-protocol snapshot at period 4; a fresh plane restored from it
    serves periods 4..7 bitwise-identically, registry included."""
    cfg = ControlPlaneConfig(**_FAST)
    a = ControlPlane(cfg)
    mgr = CheckpointManager(tmp_path / "cp")
    for p in range(4):
        for sid, k in _SCHEDULE.get(p, ()):
            a.admit(sid, k)
        a.tick()
    a.snapshot(mgr)
    tail_a = [a.tick() for _ in range(4)]

    b = ControlPlane(cfg)
    assert b.restore(mgr)
    assert b.period == 4
    assert set(b.services) == set(a.services) | set()
    tail_b = [b.tick() for _ in range(4)]
    for da, db in zip(tail_a, tail_b):
        assert da.period == db.period
        assert np.array_equal(da.b, db.b)
        assert np.array_equal(da.f, db.f)


def test_run_resumable_crash_resumes_bit_identically(tmp_path):
    """The scripted serving loop through fault.resumable_loop: a crash at
    period 5 with save_every=3 resumes to the same final state as an
    uninterrupted run (and the resumed trace still replays offline)."""
    cfg = ControlPlaneConfig(**_FAST)
    schedule = {0: (4, 3), 2: (5,)}
    clean_mgr = CheckpointManager(tmp_path / "clean")
    clean, _ = control_plane.run_resumable(cfg, schedule, 8, clean_mgr,
                                           fault.RestartPolicy(save_every=3))
    crash_mgr = CheckpointManager(tmp_path / "crash")
    policy = fault.RestartPolicy(save_every=3)
    with pytest.raises(RuntimeError, match="injected"):
        control_plane.run_resumable(cfg, schedule, 8, crash_mgr, policy,
                                    fail_at=5)
    resumed, plane = control_plane.run_resumable(cfg, schedule, 8, crash_mgr,
                                                 policy)
    for leaf_a, leaf_b in zip(jax.tree.leaves(clean),
                              jax.tree.leaves(resumed)):
        assert np.array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    assert plane.period == 8
    ref = plane.replay_reference()       # trace survives the crash/restore
    assert np.asarray(ref["history"]["b"]).shape[0] == 8


def test_daemon_stale_decision_on_deadline_miss():
    """Solver overrun -> the daemon serves the previous allocation rescaled
    to the live mask, flags and counts it, and the in-flight solve still
    commits; the fresh-solve stream stays stale-free."""
    daemon = allocd.AllocDaemon(ControlPlaneConfig(**_PERSIST))

    async def drive():
        daemon.submit(allocd.Admit("a", 4))
        daemon.submit(allocd.Admit("b", 3))
        await daemon.step_period()               # compile + fresh
        daemon.solver_timeout_s = 0.02
        daemon._solver_delay_s = 0.4
        stale = await daemon.step_period()       # deadline miss
        daemon.solver_timeout_s = None
        daemon._solver_delay_s = 0.0
        fresh = await daemon.step_period()       # pending solve commits
        await daemon.close()
        return stale, fresh

    stale, fresh = asyncio.run(drive())
    assert stale.stale and not fresh.stale
    assert daemon.plane.metrics["stale_decisions"] == 1
    assert [d.stale for d in daemon.served] == [False, True, False]
    assert not any(d.stale for d in daemon.plane.decisions)
    # budget-preserving rescale over the live slots
    B = daemon.plane.net.total_bandwidth_mhz
    np.testing.assert_allclose(stale.b.sum(), B, rtol=1e-5)


def test_daemon_records_rejections_instead_of_raising():
    # admit_max_retries=0 keeps capacity rejections immediate (the retry
    # path is covered by tests/test_chaos.py)
    daemon = allocd.AllocDaemon(ControlPlaneConfig(capacity=1, k_max=4,
                                                   rounds_required=10_000),
                                admit_max_retries=0)

    async def drive():
        daemon.submit(allocd.Admit("a", 3))
        daemon.submit(allocd.Admit("b", 3))      # no free slot
        daemon.submit(allocd.Heartbeat("ghost"))
        await daemon.step_period()
        await daemon.close()

    asyncio.run(drive())
    assert len(daemon.rejections) == 2
    assert daemon.plane.metrics["admitted"] == 1


def test_daemon_capacity_rejection_retries_before_giving_up():
    """With retries enabled, a full-capacity admit is queued with period
    backoff instead of rejected on the spot -- and only rejected once the
    bounded attempts are exhausted."""
    daemon = allocd.AllocDaemon(ControlPlaneConfig(capacity=1, k_max=4,
                                                   rounds_required=10_000),
                                admit_max_retries=2)

    async def drive():
        daemon.submit(allocd.Admit("a", 3))
        daemon.submit(allocd.Admit("b", 3))      # no free slot -> queued
        await daemon.step_period()
        first = len(daemon.rejections)
        # backoff is 1 then 2 periods; by period 4 both retries have fired
        for _ in range(4):
            await daemon.step_period()
        await daemon.close()
        return first

    rejected_at_first_period = asyncio.run(drive())
    assert rejected_at_first_period == 0
    assert daemon._retry_queue == []
    assert daemon.plane.metrics["admit_retries"] >= 2
    assert len(daemon.rejections) == 1
    sid, reason = daemon.rejections[0]
    assert sid == "b" and "gave up after 2 retries" in reason


def test_daemon_checkpoint_restart_resumes(tmp_path):
    mgr = CheckpointManager(tmp_path / "cp")
    cfg = ControlPlaneConfig(**_PERSIST)
    d1 = allocd.AllocDaemon(cfg, manager=mgr, save_every=2)
    assert not d1.resumed

    async def drive(daemon, n):
        daemon.submit(allocd.Admit("a", 4))
        for _ in range(n):
            await daemon.step_period()
        await daemon.close()

    asyncio.run(drive(d1, 5))
    d2 = allocd.AllocDaemon(cfg, manager=mgr, save_every=2)
    assert d2.resumed and d2.plane.period == 5
    assert "a" in d2.plane.services


def test_replay_requires_matched_override_pair():
    from repro.fl import simulator
    cfg = simulator.SimConfig(n_services_total=4, max_periods=2,
                              rounds_required=10, collect_history=True)
    with pytest.raises(ValueError, match="arrivals"):
        simulator.run_scan(cfg, arrivals=np.zeros(4, np.int32))


def test_collect_alloc_requires_history():
    from repro.fl import simulator
    cfg = simulator.SimConfig(n_services_total=4, max_periods=2,
                              rounds_required=10, collect_history=False,
                              collect_alloc=True)
    with pytest.raises(ValueError, match="collect_alloc"):
        simulator.run_scan(cfg)
