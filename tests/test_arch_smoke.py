"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned architecture's family runs one forward/train step on CPU, asserting
output shapes and the absence of NaNs; plus cached prefill+decode matching the
uncached oracle.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry

B, S = 2, 32


def _batch(cfg, key=jax.random.key(2)):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None, :], (3, B, S))
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = (
            jax.random.normal(jax.random.key(9), (B, 8, cfg.d_model)) * 0.1
        )
    if cfg.frontend == "audio":
        batch["frontend_embeds"] = (
            jax.random.normal(jax.random.key(9), (B, S, cfg.d_model)) * 0.1
        )
    return batch


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg = configs.get_smoke_config(name)
    model = registry.build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.all(jnp.isfinite(g))), f"{name}: non-finite grad at {path}"

    # one SGD step must change the loss (the graph is actually wired)
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = model.loss(params2, batch)
    assert bool(jnp.isfinite(loss2)) and float(loss2) != float(loss)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_prefill_decode_matches_oracle(name):
    cfg = configs.get_smoke_config(name)
    model = registry.build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    tok = batch["tokens"]
    new_tok = jax.random.randint(jax.random.key(3), (B, 1), 0, cfg.vocab_size)

    if cfg.family == "encdec":
        pre = {"frontend_embeds": batch["frontend_embeds"], "tokens": tok}
        _, cache = model.prefill(params, pre, max_len=S + 4)
        ld, cache = model.decode_step(params, cache, new_tok)
        pre2 = {"frontend_embeds": batch["frontend_embeds"],
                "tokens": jnp.concatenate([tok, new_tok], 1)}
        ref, _ = model.prefill(params, pre2, max_len=S + 4)
        err = float(jnp.max(jnp.abs(ld - ref)))
    else:
        pre = {k: v for k, v in batch.items() if k != "labels"}
        _, cache = model.prefill(params, pre, max_len=S + 4)
        kw = {}
        if cfg.mrope_sections:
            kw["positions"] = jnp.full((3, B, 1), S, jnp.int32)
        ld, cache = model.decode_step(params, cache, new_tok, **kw)
        full = jnp.concatenate([tok, new_tok], axis=1)
        fkw = {}
        if cfg.mrope_sections:
            fkw["positions"] = jnp.broadcast_to(
                jnp.arange(S + 1)[None, None, :], (3, B, S + 1)
            )
        if cfg.frontend == "vision":
            fkw["embeds_override"] = batch["frontend_embeds"]
        ref, _, _ = model.forward(params, full, **fkw)
        err = float(jnp.max(jnp.abs(ld[:, 0] - ref[:, -1])))
        assert int(cache["len"]) == S + 1
    assert err < 5e-5, f"{name}: cached decode diverges from oracle by {err}"


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_input_specs_cover_step_inputs(name):
    """Every declared (arch x shape) cell has well-formed specs."""
    cfg = configs.get_config(name)
    for shape_name in registry.SHAPES:
        if not registry.supports(cfg, shape_name):
            assert shape_name == "long_500k"
            continue
        spec = registry.input_specs(cfg, shape_name)
        leaves = jax.tree.leaves(spec)
        assert leaves, (name, shape_name)
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long_500k_eligibility_matches_design():
    eligible = {n for n in configs.ARCH_NAMES
                if registry.supports(configs.get_config(n), "long_500k")}
    assert eligible == {"gemma3-1b", "hymba-1.5b", "xlstm-1.3b"}
