"""Scenario engine tests: registry catalogue + typo rejection, the channel
innovations refactor (bitwise), correlation->0 degenerating to the i.i.d.
engine, AR(1) autocorrelation of the Gauss-Markov process, Rayleigh
stationarity, churn mask perturbations, arrival samplers, and -- the
acceptance bar -- single-trace compilation, scan/legacy/batch parity, and
checkpoint resume with every scenario process enabled."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.core import network
from repro.fl import simulator

BASE = dict(policy="es", n_services_total=3, rounds_required=80,
            p_arrive=2.0, seed=0, max_periods=100, k_max=32)

FULL_STACK = dict(
    channel_process=scenarios.spec("rayleigh_block", rho=0.9, shadowing_rho=0.8),
    arrival_process=scenarios.spec("mmpp", burst=6.0),
    churn_process=scenarios.spec("gilbert", p_drop=0.2, p_return=0.4,
                                 always_keep=1),
)


def _cfg(**kw) -> simulator.SimConfig:
    return simulator.SimConfig(**{**BASE, **kw})


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

def test_registries_cover_catalogue():
    assert {"iid", "gauss_markov", "rayleigh_block"} <= set(
        scenarios.available("channel"))
    assert {"poisson", "periodic", "batched", "mmpp"} <= set(
        scenarios.available("arrival"))
    assert {"none", "bernoulli", "gilbert"} <= set(scenarios.available("churn"))


def test_unknown_process_and_parameter_raise():
    net = network.NetworkConfig()
    with pytest.raises(ValueError, match="unknown channel process"):
        scenarios.get_channel("nope", net)
    with pytest.raises(ValueError, match="unknown parameter"):
        scenarios.get_channel(scenarios.spec("gauss_markov", rho_typo=0.5), net)
    with pytest.raises(ValueError, match="unknown parameter"):
        scenarios.get_arrival(scenarios.spec("mmpp", burstiness=2.0))
    with pytest.raises(ValueError, match="rho must be"):
        scenarios.get_channel(scenarios.spec("gauss_markov", rho=1.5), net)
    with pytest.raises(ValueError, match="p_drop must be"):
        scenarios.get_churn(scenarios.spec("gilbert", p_drop=2.0), net)


# ---------------------------------------------------------------------------
# Channel processes.
# ---------------------------------------------------------------------------

def test_channel_innovations_match_default_sampling_bitwise():
    """Feeding sample_services its own innovations must be a no-op: the hook
    correlated processes rely on cannot change the i.i.d. path."""
    net = network.NetworkConfig()
    key = jax.random.key(42)
    counts = np.array([5, 7, 9])
    svc_a, _ = network.sample_services(key, 3, net, k_max=12, client_counts=counts)
    eps = network.channel_innovations(key, 3, 12)
    svc_b, _ = network.sample_services(key, 3, net, k_max=12, client_counts=counts,
                                       channel_normals=eps)
    np.testing.assert_array_equal(np.asarray(svc_a.alpha), np.asarray(svc_b.alpha))
    np.testing.assert_array_equal(np.asarray(svc_a.t_comp), np.asarray(svc_b.t_comp))


def test_gauss_markov_zero_correlation_reproduces_iid():
    """Acceptance criterion: rho = 0 degenerates to today's i.i.d. redraw.
    Durations (the headline metric) are identical; per-period float stats
    agree to float32 fusion tolerance."""
    base = simulator.run_scan(_cfg())
    gm = simulator.run_scan(_cfg(
        channel_process=scenarios.spec("gauss_markov", rho=0.0)))
    assert gm["durations"] == base["durations"]
    assert gm["periods"] == base["periods"]
    np.testing.assert_allclose(gm["history"]["freq_sum"],
                               base["history"]["freq_sum"],
                               rtol=1e-4, atol=1e-5)


def test_gauss_markov_step_is_bitwise_iid_at_zero_rho():
    """Outside jit fusion, the rho = 0 process is *bitwise* the i.i.d. draw."""
    net = network.NetworkConfig()
    key = jax.random.key(7)
    svc, _ = network.sample_services(key, 4, net, k_max=16,
                                     client_counts=np.array([4, 8, 12, 16]))
    proc = scenarios.get_channel(scenarios.spec("gauss_markov", rho=0.0), net)
    _, svc2 = proc.step(key, proc.init(key, 4, 16), svc)
    np.testing.assert_array_equal(np.asarray(svc.alpha), np.asarray(svc2.alpha))


@pytest.mark.parametrize("rho,lo,hi", [(0.0, -0.3, 0.3), (0.95, 0.85, 1.0)])
def test_gauss_markov_lag1_autocorrelation(rho, lo, hi):
    net = network.NetworkConfig()
    proc = scenarios.get_channel(scenarios.spec("gauss_markov", rho=rho), net)
    key = jax.random.key(0)
    svc, _ = network.sample_services(key, 2, net, k_max=24,
                                     client_counts=np.array([24, 24]))
    state = proc.init(key, 2, 24)
    step = jax.jit(proc.step)
    zs = []
    for t in range(300):
        state, _ = step(jax.random.fold_in(key, t), state, svc)
        zs.append(np.asarray(state[1]).ravel())
    z = np.stack(zs)
    prev, nxt = z[:-1].ravel(), z[1:].ravel()
    corr = np.corrcoef(prev, nxt)[0, 1]
    assert lo < corr < hi, corr
    # stationary N(0, 1) marginals at any rho
    assert 0.85 < z.std() < 1.15


def test_rayleigh_block_stationary_unit_power():
    net = network.NetworkConfig()
    proc = scenarios.get_channel(scenarios.spec("rayleigh_block", rho=0.9), net)
    key = jax.random.key(3)
    svc, _ = network.sample_services(key, 2, net, k_max=24,
                                     client_counts=np.array([24, 24]))
    state = proc.init(key, 2, 24)
    step = jax.jit(proc.step)
    powers = []
    for t in range(300):
        state, svc_t = step(jax.random.fold_in(key, t), state, svc)
        powers.append(np.asarray(state[0]) ** 2 + np.asarray(state[1]) ** 2)
    p = np.stack(powers)
    assert 0.8 < p.mean() < 1.25          # E|h|^2 = 1
    # fading perturbs only the channel: vs the same-key i.i.d. draw, compute
    # times are bitwise untouched while transmission loads moved
    key_t = jax.random.fold_in(key, 299)
    iid_t, _ = network.sample_services(key_t, 2, net, k_max=24,
                                       client_counts=np.array([24, 24]))
    np.testing.assert_array_equal(np.asarray(svc_t.t_comp),
                                  np.asarray(iid_t.t_comp))
    assert not np.array_equal(np.asarray(svc_t.alpha), np.asarray(iid_t.alpha))


# ---------------------------------------------------------------------------
# Churn processes.
# ---------------------------------------------------------------------------

def test_fading_margin_clamps_deep_fades():
    """A tap below the gain floor applies exactly the -floor_db margin; a
    healthy tap applies its true -10 log10 |h|^2."""
    from repro.scenarios.channel import fading_margin_db
    floor = 10.0 ** (-40.0 / 10.0)
    deep = float(fading_margin_db(np.float32(1e-6), np.float32(0.0), floor))
    np.testing.assert_allclose(deep, 40.0, rtol=1e-6)
    healthy = float(fading_margin_db(np.float32(0.6), np.float32(0.8), floor))
    np.testing.assert_allclose(healthy, 0.0, atol=1e-5)   # |h|^2 = 1


def test_bernoulli_churn_masks_clients_and_respects_always_keep():
    net = network.NetworkConfig()
    key = jax.random.key(5)
    counts = np.array([6, 10, 14])
    svc, _ = network.sample_services(key, 3, net, k_max=16, client_counts=counts)
    proc = scenarios.get_churn(
        scenarios.spec("bernoulli", p_drop=1.0, always_keep=2), net)
    _, svc2 = proc.step(key, proc.init(key, 3, 16), svc)
    np.testing.assert_array_equal(np.asarray(svc2.client_counts()), [2, 2, 2])
    # dropped clients look exactly like padding
    assert float(np.asarray(svc2.alpha)[~np.asarray(svc2.mask)].max()) == 0.0


def test_total_churn_stalls_episode():
    """p_drop = 1 with no anchors: every service is an empty row forever --
    no FL progress, nothing finishes."""
    out = simulator.run_scan(_cfg(
        churn_process=scenarios.spec("bernoulli", p_drop=1.0), max_periods=30))
    assert not out["finished"]
    assert float(np.abs(out["history"]["freq_sum"]).max()) == 0.0


def test_gilbert_frozen_chain_drops_no_one():
    """Degenerate pair p_drop = p_return = 0: the chain never transitions,
    so a zero drop probability must mean full availability forever."""
    net = network.NetworkConfig()
    proc = scenarios.get_churn(
        scenarios.spec("gilbert", p_drop=0.0, p_return=0.0), net)
    key = jax.random.key(9)
    svc, _ = network.sample_services(key, 2, net, k_max=8,
                                     client_counts=np.array([8, 8]))
    state = proc.init(key, 2, 8)
    assert bool(np.all(np.asarray(state)))
    for t in range(3):
        state, svc2 = proc.step(jax.random.fold_in(key, t), state, svc)
        np.testing.assert_array_equal(np.asarray(svc2.client_counts()), [8, 8])


def test_gilbert_steady_state_availability():
    net = network.NetworkConfig()
    proc = scenarios.get_churn(
        scenarios.spec("gilbert", p_drop=0.2, p_return=0.2), net)
    key = jax.random.key(11)
    svc, _ = network.sample_services(key, 2, net, k_max=20,
                                     client_counts=np.array([20, 20]))
    state = proc.init(key, 2, 20)
    step = jax.jit(proc.step)
    avail = []
    for t in range(200):
        state, _ = step(jax.random.fold_in(key, t), state, svc)
        avail.append(np.asarray(state).mean())
    # steady state = p_return / (p_drop + p_return) = 0.5
    assert 0.4 < np.mean(avail) < 0.6


# ---------------------------------------------------------------------------
# Arrival processes.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["poisson", "periodic", "batched", "mmpp"])
def test_arrival_samplers_are_sane(name):
    draw = scenarios.get_arrival(name)
    arr = np.asarray(draw(jax.random.key(0), 50, 4.0))
    assert arr.shape == (50,) and np.issubdtype(arr.dtype, np.integer)
    assert np.all(arr >= 0) and np.all(np.diff(arr) >= 0)


def test_poisson_arrivals_match_engine_stream():
    """The default sampler is the exact device-side stream of the simulator's
    batched static draws: cumulative exponential gaps off the episode key,
    so every seed's episode is reproducible from the sampler alone."""
    key = jax.random.key(3)
    arr = np.asarray(scenarios.get_arrival("poisson")(key, 10, 5.0))
    gaps = jax.random.exponential(key, (10,), jnp.float32) * 5.0
    expected = np.floor(np.cumsum(np.asarray(gaps))).astype(arr.dtype)
    np.testing.assert_array_equal(arr, expected)


def test_arrival_samplers_vmap_bitwise_equals_per_key():
    """Batched (vmapped) draws are bitwise identical to per-key draws -- the
    invariant that lets run_fleet set up 10k episodes in one dispatch."""
    keys = jax.vmap(jax.random.key)(jnp.arange(5, dtype=jnp.uint32))
    for name in scenarios.available("arrival"):
        draw = scenarios.get_arrival(name)
        batched = jax.vmap(lambda k: draw(k, 12, 3.0))(keys)
        for i in range(5):
            np.testing.assert_array_equal(
                np.asarray(batched[i]), np.asarray(draw(keys[i], 12, 3.0)),
                err_msg=f"{name}: vmapped draw drifted from per-key draw")


def test_periodic_and_batched_arrivals_structure():
    assert list(np.asarray(scenarios.get_arrival("periodic")(
        jax.random.key(0), 4, 2.5))) == [0, 2, 5, 7]
    arr = np.asarray(scenarios.get_arrival(scenarios.spec("batched", group=3))(
        jax.random.key(0), 7, 2.0))
    assert arr[0] == arr[1] == arr[2] and arr[3] == arr[4] == arr[5]


def test_mmpp_is_burstier_than_poisson():
    """Squared coefficient of variation of inter-arrival gaps: ~1 for the
    Poisson process, clearly above 1 for the 2-state MMPP."""
    def cv2(name_or_spec, seed=0, n=4000):
        draw = scenarios.get_arrival(name_or_spec)
        gaps = np.diff(np.asarray(draw(jax.random.key(seed), n, 10.0),
                                  dtype=np.float64))
        return gaps.var() / gaps.mean() ** 2

    assert cv2("poisson") < 1.3
    assert cv2(scenarios.spec("mmpp", burst=8.0, stay=0.9)) > 1.6


# ---------------------------------------------------------------------------
# The engine with every scenario process enabled (acceptance criteria).
# ---------------------------------------------------------------------------

def test_full_stack_single_trace_and_one_compiled_batch():
    simulator.reset_trace_count()
    out = simulator.run_scan(_cfg(**FULL_STACK))
    assert out["finished"]
    assert simulator.trace_count() == 1
    # run_batch stays one compiled call: the period step is NOT retraced for
    # the batched entry of the same shape+scenario, and each lane is bitwise
    # its own single-seed episode.
    simulator.reset_trace_count()
    batch = simulator.run_batch(_cfg(**FULL_STACK), [0, 1])
    assert simulator.trace_count() == 1
    single = simulator.run_scan(_cfg(**FULL_STACK, seed=1))
    assert list(batch["durations"][1]) == single["durations"]


def test_full_stack_scan_matches_legacy_loop():
    scan = simulator.run_scan(_cfg(**FULL_STACK))
    legacy = simulator.run(_cfg(**FULL_STACK))
    assert scan["durations"] == legacy["durations"]
    assert scan["periods"] == legacy["periods"]
    assert scan["finished"] == legacy["finished"]


def test_full_stack_checkpoint_resume(tmp_path):
    """Scenario state (fading taps, shadowing, churn chains) survives the
    legacy engine's JSON snapshot: resuming mid-episode is exact."""
    cfg = _cfg(**FULL_STACK)
    partial = simulator.run(dataclasses.replace(cfg, max_periods=3),
                            checkpoint_path=str(tmp_path / "snap.json"))
    assert not partial["finished"]
    resumed = simulator.run(cfg, state=partial["state"])
    fresh = simulator.run(cfg)
    assert resumed["durations"] == fresh["durations"]
    assert resumed["periods"] == fresh["periods"]


def test_resume_without_scenario_state_is_rejected():
    """A mid-episode snapshot that predates the configured stateful scenario
    must not silently reinitialize its state at the resume period."""
    cfg = _cfg(**FULL_STACK)
    partial = simulator.run(dataclasses.replace(cfg, max_periods=3))
    legacy_snapshot = {k: v for k, v in partial["state"].items()
                       if k not in ("chan_state", "churn_state")}
    with pytest.raises(ValueError, match="stateful"):
        simulator.run(cfg, state=legacy_snapshot)
    # ...but a period-0 snapshot without the keys resumes fine (fresh init
    # IS the correct state before the first step)
    fresh0 = {"period": 0, "rounds_done": [0] * 3, "duration": [0] * 3,
              "history": []}
    out = simulator.run(cfg, state=fresh0)
    assert out["durations"] == simulator.run(cfg)["durations"]


def test_scenario_fields_participate_in_jit_statics():
    """Different scenario specs are different compilation keys, same spec is
    a cache hit -- the registry mirrors core.policy's string-keyed dispatch."""
    cfg = _cfg(churn_process=scenarios.spec("bernoulli", p_drop=0.1))
    simulator.reset_trace_count()
    simulator.run_scan(cfg)
    assert simulator.trace_count() == 1
    simulator.run_scan(cfg)                      # same spec: no retrace
    assert simulator.trace_count() == 1
    simulator.run_scan(_cfg(churn_process=scenarios.spec(
        "bernoulli", p_drop=0.3)))               # new params: one new trace
    assert simulator.trace_count() == 2
