"""The warm-started / kernel-fused allocation fast path:

* ``solve_lambda_newton`` and ``solve_lambda_newton_warm`` parity with the
  pinned solvers (``solve_lambda_bisect`` / ``disba``) on masked
  fixed-capacity sets, from good, stale, and sentinel seeds;
* the fused ``dual_demand`` Pallas kernel (interpret mode) against its
  pure-jnp oracle, including the closed-form slope vs finite differences;
* the joint (N, M) mBDF bisection bitwise against the vmapped per-column
  solve it replaced;
* auction leave-one-out charges: prefix-sum path vs the clearing-rerun
  reference;
* simulator state threading: warm-started durations match cold durations on
  the golden scenarios, ``trace_count() == 1`` for every
  (policy, warm_start) combination, ``collect_history=False`` aggregates,
  and the legacy engine's warm checkpoint round trip.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import auction, disba, fairness, intra, network, policy
from repro.core.types import ServiceSet, mask_inactive
from repro.fl import simulator
from repro.kernels import ops
from repro.kernels.dual_demand import dual_demand

B = network.B_TOTAL_MHZ

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "longterm_summary.json")


def _masked_fixed_capacity_set(seed, n=9, k=31):
    """Random padded ServiceSet with ragged counts and inactive slots."""
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.01, 0.3, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.01, 0.06, size=(n, k)).astype(np.float32)
    mask = np.zeros((n, k), dtype=bool)
    for i in range(n):
        mask[i, : rng.integers(2, k + 1)] = True
    mask[rng.integers(0, n)] = False
    alpha = np.where(mask, alpha, 0.0)
    t_comp = np.where(mask, t_comp, 0.0)
    return ServiceSet(alpha=jnp.asarray(alpha), t_comp=jnp.asarray(t_comp),
                      mask=jnp.asarray(mask))


# ---------------------------------------------------------------------------
# Warm-started market clearing.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_newton_solvers_match_bisect_on_masked_sets(seed):
    svc = _masked_fixed_capacity_set(seed)
    ref = disba.solve_lambda_bisect(svc, B)
    newt = disba.solve_lambda_newton(svc, B)
    warm_cold = disba.solve_lambda_newton_warm(svc, B)
    np.testing.assert_allclose(np.asarray(newt.b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(warm_cold.b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-4)
    # inactive slots stay at zero demand
    inactive = ~np.asarray(svc.service_active())
    assert np.all(np.asarray(warm_cold.b)[inactive] == 0.0)


@pytest.mark.parametrize("seed_scale", [1.0, 1.05, 0.7, 3.0])
def test_warm_clearer_converges_from_any_seed(seed_scale):
    """A good, slightly stale, badly stale, or out-of-bracket seed must all
    land on the bisect optimum -- the bracket safeguard never diverges."""
    svc = _masked_fixed_capacity_set(3)
    ref = disba.solve_lambda_bisect(svc, B)
    res = disba.solve_lambda_newton_warm(
        svc, B, lam_prev=ref.lam * jnp.float32(seed_scale))
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(jnp.sum(res.b)), B, rtol=1e-5)


def test_warm_clearer_sentinel_seed_matches_disba():
    svc = _masked_fixed_capacity_set(4)
    res = disba.solve_lambda_newton_warm(svc, B, lam_prev=disba.WARM_COLD)
    ref = disba.disba(svc, B, gamma=0.1, eps=1e-4)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b),
                               rtol=5e-3, atol=1e-3)


def test_demand_slope_matches_finite_difference():
    svc = _masked_fixed_capacity_set(5)
    lam = 0.4 * float(jnp.max(intra.p_max(svc)))
    eps = 1e-4 * lam
    d0, s0, _ = disba._demand_and_slope(svc, jnp.float32(lam), 48)
    d1, _, _ = disba._demand_and_slope(svc, jnp.float32(lam + eps), 48)
    fd = (float(d1) - float(d0)) / eps
    np.testing.assert_allclose(float(s0), fd, rtol=5e-3)


def test_warm_clearer_all_inactive_set():
    svc = _masked_fixed_capacity_set(6)
    none = mask_inactive(svc, jnp.zeros((svc.n_services,), bool))
    res = disba.solve_lambda_newton_warm(none, B, lam_prev=0.5)
    assert float(jnp.sum(jnp.abs(res.b))) == 0.0
    assert np.all(np.isfinite(np.asarray(res.f)))


# ---------------------------------------------------------------------------
# The fused dual_demand kernel (interpret mode on CPU).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dual_demand_kernel_matches_reference(seed):
    svc = _masked_fixed_capacity_set(seed)
    lam = (0.2 + 0.2 * seed) * float(jnp.max(intra.p_max(svc)))
    b_ref, s_ref = ops.dual_demand(svc.alpha, svc.t_comp, lam,
                                   use_pallas=False)
    b_k, s_k = dual_demand(svc.alpha, svc.t_comp, jnp.float32(lam),
                           interpret=True)
    np.testing.assert_allclose(np.asarray(b_k), np.asarray(b_ref),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-3, atol=1e-5)
    # inactive rows emit exactly zero demand and slope
    inactive = ~np.asarray(svc.service_active())
    assert np.all(np.asarray(b_k)[inactive] == 0.0)
    assert np.all(np.asarray(s_k)[inactive] == 0.0)


def test_warm_clearer_pallas_backend_matches_reference():
    svc = _masked_fixed_capacity_set(7)
    ref = disba.solve_lambda_newton_warm(svc, B)
    # off-TPU, use_pallas=True inside the backend runs the kernel in
    # interpret mode (the ops dispatch convention)
    res = disba.solve_lambda_newton_warm(svc, B, backend="pallas")
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b),
                               rtol=1e-3, atol=1e-4)


def test_unknown_demand_backend_raises():
    svc = _masked_fixed_capacity_set(0)
    with pytest.raises(ValueError, match="demand backend"):
        disba.solve_lambda_newton_warm(svc, B, backend="nope")


# ---------------------------------------------------------------------------
# Joint-grid mBDF and prefix-sum auction charges.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha_fair", [0.0, 0.5, 1.0])
def test_mbdf_grid_bitwise_matches_vmapped_columns(alpha_fair):
    svc = _masked_fixed_capacity_set(1)
    pmax = intra.p_max(svc)
    m = jnp.arange(1, 6, dtype=svc.alpha.dtype)
    prices = m[None, :] * pmax[:, None] / 6.0
    ref = jax.vmap(lambda p: fairness.mbdf(svc, p, alpha_fair),
                   in_axes=1, out_axes=1)(prices)
    grid = fairness.mbdf_grid(svc, prices, alpha_fair)
    np.testing.assert_array_equal(np.asarray(grid), np.asarray(ref))


@pytest.mark.parametrize("seed,b_total", [(0, 10.0), (1, 300.0), (2, 40.0)])
def test_leave_one_out_prices_match_clearing_reruns(seed, b_total):
    rng = np.random.default_rng(seed)
    n, k = 8, 7
    alpha = rng.uniform(0.01, 0.5, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.005, 0.08, size=(n, k)).astype(np.float32)
    if seed == 2:
        alpha[5] = alpha[1]
        t_comp[5] = t_comp[1]          # identical providers -> price ties
    from repro.core.types import make_service_set
    svc = make_service_set(alpha, t_comp)
    bid = auction.uniform_truthful_bids(svc, 5, 0.5)
    eye = jnp.eye(n, dtype=bid.prices.dtype)
    z_rerun = jax.vmap(
        lambda e: auction.clearing_price(bid, b_total, weights=1.0 - e))(eye)
    z_prefix = auction.leave_one_out_prices(bid, b_total)
    np.testing.assert_allclose(np.asarray(z_prefix), np.asarray(z_rerun),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("seed,b_total", [(0, 10.0), (1, 300.0), (2, 40.0)])
def test_prefix_charges_match_rerun_reference(seed, b_total):
    rng = np.random.default_rng(seed + 10)
    n, k = 9, 7
    alpha = rng.uniform(0.01, 0.5, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.005, 0.08, size=(n, k)).astype(np.float32)
    from repro.core.types import make_service_set
    svc = make_service_set(alpha, t_comp)
    bid = auction.uniform_truthful_bids(svc, 5, 0.5)
    b, _ = auction.allocate(bid, b_total)
    c_rerun = auction.charges(svc, bid, b, b_total, 0.5, method="rerun")
    c_prefix = auction.charges(svc, bid, b, b_total, 0.5, method="prefix")
    np.testing.assert_allclose(np.asarray(c_prefix), np.asarray(c_rerun),
                               rtol=1e-4, atol=1e-4)


def test_unknown_charges_method_raises():
    svc = _masked_fixed_capacity_set(0)
    bid = auction.uniform_truthful_bids(svc, 3, 0.5)
    b, _ = auction.allocate(bid, B)
    with pytest.raises(ValueError, match="charges method"):
        auction.charges(svc, bid, b, B, 0.5, method="nope")


# ---------------------------------------------------------------------------
# Stateful policy protocol.
# ---------------------------------------------------------------------------

def test_stateless_wrapper_matches_get_policy():
    svc = _masked_fixed_capacity_set(2)
    for name in policy.available():
        fn = policy.get_policy(name)
        pol = policy.get_stateful_policy(name, warm_start=False)
        state = pol.init_state(svc.n_services)
        assert state == ()
        b0, f0 = fn(svc, B)
        b1, f1, state = pol.step(svc, B, state)
        np.testing.assert_array_equal(np.asarray(b0), np.asarray(b1))
        np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))


def test_warm_coop_step_carries_dual_price():
    svc = _masked_fixed_capacity_set(2)
    pol = policy.get_stateful_policy("coop", warm_start=True)
    state = pol.init_state(svc.n_services)
    assert float(state.lam) == disba.WARM_COLD
    assert int(state.fallbacks) == 0
    b, f, state = pol.step(svc, B, state)
    ref = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(state.lam), float(ref.lam), rtol=1e-4)
    assert int(state.fallbacks) == 0    # healthy steps never count a rescue
    # an all-inactive period must NOT poison the carried price
    none = mask_inactive(svc, jnp.zeros((svc.n_services,), bool))
    _, _, state2 = pol.step(none, B, state)
    assert float(state2.lam) == float(state.lam)


def test_stateful_policy_unknown_option_raises():
    with pytest.raises(ValueError, match="unknown option"):
        policy.get_stateful_policy("coop", warm_start=True, iterz=3)
    with pytest.raises(ValueError, match="unknown policy"):
        policy.get_stateful_policy("nope")


# ---------------------------------------------------------------------------
# Simulator: warm start + collect_history through both engines.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden() -> dict:
    with open(GOLDEN_PATH) as fp:
        return json.load(fp)


@pytest.mark.parametrize("pol", simulator.POLICIES)
def test_warm_start_durations_match_cold_on_golden_scenarios(golden, pol):
    """The satellite property: warm-started batches reproduce the cold-start
    durations on the pinned golden scenarios, for every policy."""
    cfg = simulator.SimConfig(policy=pol, **golden["config"])
    cold = simulator.run_batch(cfg, golden["seeds"])
    warm = simulator.run_batch(dataclasses.replace(cfg, warm_start=True),
                               golden["seeds"])
    np.testing.assert_array_equal(np.asarray(warm["durations"]),
                                  np.asarray(cold["durations"]))
    assert [bool(x) for x in warm["finished"]] == \
        [bool(x) for x in cold["finished"]]


@pytest.mark.parametrize("warm_start", [False, True])
@pytest.mark.parametrize("pol", simulator.POLICIES)
def test_single_trace_for_every_policy_warm_combination(pol, warm_start):
    cfg = simulator.SimConfig(policy=pol, n_services_total=3,
                              rounds_required=60, p_arrive=2.0, seed=0,
                              max_periods=60, warm_start=warm_start)
    simulator.reset_trace_count()
    out = simulator.run_scan(cfg)
    assert out["finished"]
    assert simulator.trace_count() == 1


def test_single_trace_warm_with_stateful_scenarios():
    from repro import scenarios
    cfg = simulator.SimConfig(
        policy="coop", n_services_total=3, rounds_required=60, p_arrive=2.0,
        seed=0, max_periods=60, warm_start=True,
        channel_process=scenarios.spec("gauss_markov", rho=0.9),
        churn_process=scenarios.spec("bernoulli", p_drop=0.1),
    )
    simulator.reset_trace_count()
    simulator.run_scan(cfg)
    assert simulator.trace_count() == 1


def test_warm_batch_bitwise_identical_to_single_seed():
    cfg = simulator.SimConfig(policy="coop", n_services_total=3,
                              rounds_required=80, p_arrive=2.0,
                              max_periods=80, k_max=24, warm_start=True)
    batch = simulator.run_batch(cfg, [0, 1])
    for i, s in enumerate([0, 1]):
        single = simulator.run_scan(dataclasses.replace(cfg, seed=s))
        assert list(batch["durations"][i]) == single["durations"]
        for key in ("freq_sum", "objective"):
            p = single["periods"]
            np.testing.assert_array_equal(batch["history"][key][i][:p],
                                          single["history"][key])


def test_legacy_run_matches_scan_with_warm_start():
    cfg = simulator.SimConfig(policy="coop", n_services_total=3,
                              rounds_required=100, p_arrive=2.0, seed=1,
                              max_periods=100, warm_start=True)
    legacy = simulator.run(cfg)
    scan = simulator.run_scan(cfg)
    assert legacy["finished"] and scan["finished"]
    assert scan["durations"] == legacy["durations"]
    # the dual price (plus the fallback counter) rides in the snapshot
    assert len(legacy["state"]["pol_state"]) == 2


def test_legacy_warm_checkpoint_resume_is_exact(tmp_path):
    cfg = simulator.SimConfig(policy="coop", n_services_total=3,
                              rounds_required=100, p_arrive=2.0, seed=2,
                              max_periods=40, warm_start=True)
    full = simulator.run(cfg)
    # stop early, then resume from the snapshot
    part = simulator.run(dataclasses.replace(cfg, max_periods=12))
    resumed = simulator.run(cfg, state=part["state"])
    assert resumed["durations"] == full["durations"]
    assert resumed["periods"] == full["periods"]


def test_collect_history_false_matches_history_path():
    cfg = simulator.SimConfig(policy="es", n_services_total=3,
                              rounds_required=100, p_arrive=2.0, seed=1,
                              max_periods=100, k_max=24)
    with_hist = simulator.run_scan(cfg)
    no_hist = simulator.run_scan(
        dataclasses.replace(cfg, collect_history=False))
    assert no_hist["history"] is None
    assert no_hist["durations"] == with_hist["durations"]
    assert no_hist["periods"] == with_hist["periods"]
    for key in ("freq_sum", "objective", "n_active", "n_clients"):
        np.testing.assert_allclose(
            no_hist["totals"][key], float(np.sum(with_hist["history"][key])),
            rtol=1e-5)


def test_collect_history_false_legacy_run_matches_scan():
    """run() and run_scan() return the same summary shape and totals when
    history collection is off."""
    cfg = simulator.SimConfig(policy="es", n_services_total=3,
                              rounds_required=100, p_arrive=2.0, seed=1,
                              max_periods=100, k_max=24,
                              collect_history=False)
    scan = simulator.run_scan(cfg)
    legacy = simulator.run(cfg)
    assert legacy["history"] is None
    assert legacy["durations"] == scan["durations"]
    assert legacy["periods"] == scan["periods"]
    for key in ("freq_sum", "objective", "n_active", "n_clients"):
        np.testing.assert_allclose(legacy["totals"][key],
                                   scan["totals"][key], rtol=1e-5)


def test_collect_history_false_batch_aggregates():
    cfg = simulator.SimConfig(policy="coop", n_services_total=3,
                              rounds_required=80, p_arrive=2.0,
                              max_periods=80, k_max=24,
                              collect_history=False)
    seeds = [0, 1, 2]
    batch = simulator.run_batch(cfg, seeds)
    assert batch["history"] is None
    for i, s in enumerate(seeds):
        single = simulator.run_scan(dataclasses.replace(cfg, seed=s))
        assert list(batch["durations"][i]) == single["durations"]
        assert int(batch["periods"][i]) == single["periods"]
        np.testing.assert_allclose(float(batch["totals"]["objective"][i]),
                                   single["totals"]["objective"], rtol=1e-6)
