"""Tests for the fairness-adjusted multi-bid auction (paper §V)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import auction, disba, fairness, intra, network
from repro.core.types import make_service_set


@pytest.fixture(scope="module")
def scenario():
    svc, meta = network.table1_service_set(jax.random.key(0))
    return svc, network.B_TOTAL_MHZ


# ---------------------------------------------------------------------------
# Pseudo step functions.
# ---------------------------------------------------------------------------

def _hand_bid():
    # one provider: prices [1, 2, 3], demands [6, 4, 1]
    return auction.MultiBid(
        prices=jnp.array([[1.0, 2.0, 3.0]]), demands=jnp.array([[6.0, 4.0, 1.0]])
    )


def test_pseudo_mbdf_step_semantics():
    bid = _hand_bid()
    # left-continuous: value at exactly a bid price is that bid's demand
    assert float(auction.pseudo_mbdf(bid, jnp.float32(0.5), "left")[0]) == 6.0
    assert float(auction.pseudo_mbdf(bid, jnp.float32(1.0), "left")[0]) == 6.0
    assert float(auction.pseudo_mbdf(bid, jnp.float32(1.5), "left")[0]) == 4.0
    assert float(auction.pseudo_mbdf(bid, jnp.float32(3.0), "left")[0]) == 1.0
    assert float(auction.pseudo_mbdf(bid, jnp.float32(3.5), "left")[0]) == 0.0
    # right limits jump at the bid price
    assert float(auction.pseudo_mbdf(bid, jnp.float32(1.0), "right")[0]) == 4.0
    assert float(auction.pseudo_mbdf(bid, jnp.float32(3.0), "right")[0]) == 0.0


def test_pseudo_mmvf_integral_piecewise():
    bid = _hand_bid()
    # q(b) = 3 on (0,1], 2 on (1,4], 1 on (4,6], 0 above 6
    val = float(auction.pseudo_mmvf_integral(bid, jnp.array([0.0]), jnp.array([6.0]))[0])
    np.testing.assert_allclose(val, 3 * 1 + 2 * 3 + 1 * 2, rtol=1e-6)
    val2 = float(auction.pseudo_mmvf_integral(bid, jnp.array([0.5]), jnp.array([4.5]))[0])
    np.testing.assert_allclose(val2, 3 * 0.5 + 2 * 3 + 1 * 0.5, rtol=1e-6)


def test_clearing_price_hand_example():
    # two providers, supply 6
    bid = auction.MultiBid(
        prices=jnp.array([[1.0, 2.0], [1.5, 2.5]]),
        demands=jnp.array([[5.0, 2.0], [4.0, 1.0]]),
    )
    # d_bar(p): p<=1 -> 9; (1,1.5] -> 6(=2+4); (1.5,2] -> 3(=2+1); (2,2.5] -> 1; >2.5 -> 0
    # sup{p: d(p) > 6} = 1.0
    zeta = float(auction.clearing_price(bid, 6.0))
    assert zeta == 1.0
    b, _ = auction.allocate(bid, 6.0)
    # at zeta+: demands (2. ... wait (1,1.5] -> provider1: 2, provider2: 4 => 6
    np.testing.assert_allclose(float(jnp.sum(b)), 6.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# End-to-end auction properties.
# ---------------------------------------------------------------------------

def test_auction_allocates_full_supply(scenario):
    svc, B = scenario
    res = auction.run_auction(svc, B, n_bids=5, alpha_fair=0.5)
    np.testing.assert_allclose(float(jnp.sum(res.b)), B, rtol=1e-5)
    assert bool(jnp.all(res.b >= -1e-6))


def test_individual_rationality(scenario):
    """Prop. 4: truthful bidders never end with negative utility."""
    svc, B = scenario
    for a in (0.0, 0.3, 0.5, 0.8, 1.0):
        res = auction.run_auction(svc, B, n_bids=5, alpha_fair=a)
        assert bool(jnp.all(res.utilities >= -1e-4)), f"IR violated at alpha={a}"


def test_auction_approaches_exact_mmcp_with_more_bids(scenario):
    """Fig. 8: the M-bid approximation's welfare approaches the exact mMCP."""
    svc, B = scenario
    a = 0.5
    exact = fairness.exact_mmcp(svc, B, a)
    welfare_exact = float(jnp.sum(fairness.g_value(exact.f, a)))
    gaps = []
    for m in (2, 5, 20, 60):
        res = auction.run_auction(svc, B, n_bids=m, alpha_fair=a)
        gaps.append(welfare_exact - float(jnp.sum(fairness.g_value(res.f, a))))
    assert gaps[-1] <= gaps[0] + 1e-5
    assert gaps[-1] < 0.05 * abs(welfare_exact)


def test_alpha_zero_maximizes_total_frequency(scenario):
    """Prop. 2: at alpha=0 the clearing allocation maximizes sum_n f_n."""
    svc, B = scenario
    exact = fairness.exact_mmcp(svc, B, 0.0)
    total = float(jnp.sum(exact.f))
    rng = np.random.default_rng(1)
    for _ in range(30):
        w = rng.dirichlet(np.ones(svc.n_services)).astype(np.float32)
        f_rand = intra.freq(svc, jnp.asarray(w * B))
        assert total >= float(jnp.sum(f_rand)) - 1e-3


def test_alpha_one_recovers_proportional_fairness(scenario):
    """alpha=1: g = log(1+f), so the mMCP allocation equals cooperative DISBA."""
    svc, B = scenario
    exact = fairness.exact_mmcp(svc, B, 1.0)
    coop = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(exact.b), np.asarray(coop.b), rtol=2e-2, atol=1e-2)


def test_clearing_price_decreases_with_alpha(scenario):
    """Fig. 9: a fairness-leaning market clears at a lower price."""
    svc, B = scenario
    prices = [float(fairness.exact_mmcp(svc, B, a).price) for a in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(p1 >= p2 - 1e-6 for p1, p2 in zip(prices, prices[1:])), prices


def test_delta_bound_shrinks_with_bid_granularity(scenario):
    """Prop. 5 / §V.E: the truthfulness gap Delta_n decreases as the bid grid
    refines (M up), and does so substantially (the pseudo functions approach
    the true mBDF/mMVF)."""
    svc, B = scenario
    a = 0.5
    deltas = [
        auction.delta_bound(svc, auction.uniform_truthful_bids(svc, m, a), a)
        for m in (4, 8, 32)
    ]
    assert bool(jnp.all(deltas[1] <= deltas[0] + 1e-5))
    assert bool(jnp.all(deltas[2] <= deltas[1] + 1e-5))
    # M=32 should cut the M=4 gap by ~>2x for every provider.
    assert bool(jnp.all(deltas[2] <= 0.5 * deltas[0]))
    assert bool(jnp.all(deltas[2] >= 0))


def test_charges_nonnegative_and_cover_fairness_cost(scenario):
    svc, B = scenario
    a = 0.5
    res = auction.run_auction(svc, B, n_bids=5, alpha_fair=a)
    fair_c = fairness.fairness_cost(res.f, a)
    assert bool(jnp.all(res.charges >= fair_c - 1e-6))  # social cost term >= 0


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 12))
def test_property_supply_conservation(seed, m):
    rng = np.random.default_rng(seed)
    n, k = 6, 8
    alpha = rng.uniform(0.01, 0.5, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.005, 0.08, size=(n, k)).astype(np.float32)
    svc = make_service_set(alpha, t_comp)
    bid = auction.uniform_truthful_bids(svc, m, 0.5)
    b, zeta = auction.allocate(bid, 10.0)
    # prices ascend, demands descend
    assert bool(jnp.all(jnp.diff(bid.prices, axis=1) > 0))
    assert bool(jnp.all(jnp.diff(bid.demands, axis=1) <= 1e-5))
    assert bool(jnp.all(b >= -1e-6))
    total = float(jnp.sum(b))
    # full allocation whenever demand at the reserve exceeds supply
    demand_at_reserve = float(jnp.sum(bid.demands[:, 0]))
    if demand_at_reserve > 10.0:
        np.testing.assert_allclose(total, 10.0, rtol=1e-4)
    else:
        assert total <= 10.0 + 1e-4
