"""Checkpoint hardening contracts: idempotent re-save, per-shard checksum
verification, and ``restore_latest`` skipping past committed-but-corrupted
steps instead of crashing the restart loop (PR 8)."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(x: float) -> dict:
    return {"w": jnp.arange(12, dtype=jnp.float32) * jnp.float32(x),
            "step": jnp.int32(int(x))}


def _shard(mgr: CheckpointManager, step: int) -> str:
    return os.path.join(mgr._step_dir(step), "shard_0000.npz")


def _commit(mgr: CheckpointManager, step: int) -> str:
    return os.path.join(mgr._step_dir(step), "COMMIT")


def test_save_restore_roundtrip_with_checksums(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(3.0), extra={"tag": "x"})
    with open(os.path.join(mgr._step_dir(3), "meta.json")) as f:
        meta = json.load(f)
    assert "shard_0000.npz" in meta["shard_checksums"]
    ok, reason = mgr.verify_step(3)
    assert ok, reason
    tree, extra = mgr.restore(3, _tree(0.0))
    np.testing.assert_array_equal(tree["w"], np.asarray(_tree(3.0)["w"]))
    assert extra == {"tag": "x"}


def test_resave_same_step_is_idempotent(tmp_path):
    """Regression: re-saving an existing step (a restarted daemon replaying
    its last period) used to crash on the existing directory.  It must swap
    atomically and serve the NEW payload."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(1.0))
    mgr.save(5, _tree(2.0))        # same step, new content -- must not raise
    assert mgr.all_steps() == [5]
    ok, reason = mgr.verify_step(5)
    assert ok, reason
    tree, _ = mgr.restore(5, _tree(0.0))
    np.testing.assert_array_equal(tree["w"], np.asarray(_tree(2.0)["w"]))
    # no .old or temp residue left behind
    residue = [n for n in os.listdir(tmp_path)
               if n.endswith(".old") or n.startswith(".tmp_")]
    assert residue == []


def test_checksum_detects_flipped_byte(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0))
    shard = _shard(mgr, 1)
    size = os.path.getsize(shard)
    with open(shard, "r+b") as f:
        f.seek(size // 2)
        byte = f.read(1)
        f.seek(size // 2)
        f.write(bytes([byte[0] ^ 0xFF]))
    ok, reason = mgr.verify_step(1)
    assert not ok and "checksum" in reason
    with pytest.raises(IOError, match="corrupted"):
        mgr.restore(1, _tree(0.0))


def test_restore_latest_skips_corrupted_newest(tmp_path):
    """The headline degradation path: COMMIT present but the shard truncated
    underneath it -- restore_latest must fall back to the next-older step and
    record the skip, never crash and never serve garbage."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, _tree(10.0))
    mgr.save(20, _tree(20.0))
    shard = _shard(mgr, 20)
    with open(shard, "r+b") as f:
        f.truncate(os.path.getsize(shard) // 2)
    assert os.path.exists(_commit(mgr, 20))        # still "committed"
    step, tree, _ = mgr.restore_latest(_tree(0.0))
    assert step == 10
    np.testing.assert_array_equal(tree["w"], np.asarray(_tree(10.0)["w"]))
    assert [s for s, _ in mgr.last_skipped] == [20]
    assert "checksum" in mgr.last_skipped[0][1]


def test_restore_latest_ignores_torn_write(tmp_path):
    """A step without COMMIT (torn write) is invisible: not restored, not
    even counted as a skip -- it never claimed completeness."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    os.remove(_commit(mgr, 2))
    assert mgr.all_steps() == [1]
    step, tree, _ = mgr.restore_latest(_tree(0.0))
    assert step == 1 and mgr.last_skipped == []


def test_restore_latest_none_survives(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0))
    shard = _shard(mgr, 1)
    with open(shard, "r+b") as f:
        f.truncate(1)
    step, tree, extra = mgr.restore_latest(_tree(7.0))
    assert step is None and extra == {}
    np.testing.assert_array_equal(tree["w"], np.asarray(_tree(7.0)["w"]))
    assert len(mgr.last_skipped) == 1


def test_crash_mid_save_preserves_older_step(tmp_path, monkeypatch):
    """A crash during save (simulated: rename blows up) must leave the
    previous checkpoint intact and clean up its temp directory."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0))

    def boom(*args, **kwargs):
        raise OSError("disk gone")

    monkeypatch.setattr(os, "rename", boom)
    with pytest.raises(OSError, match="disk gone"):
        mgr.save(2, _tree(2.0))
    monkeypatch.undo()
    assert not [n for n in os.listdir(tmp_path) if n.startswith(".tmp_")]
    step, tree, _ = mgr.restore_latest(_tree(0.0))
    assert step == 1
    np.testing.assert_array_equal(tree["w"], np.asarray(_tree(1.0)["w"]))


def test_new_manager_sweeps_orphaned_tmp_dirs(tmp_path):
    os.makedirs(tmp_path / ".tmp_orphan")
    (tmp_path / ".tmp_orphan" / "shard_0000.npz").write_bytes(b"junk")
    CheckpointManager(str(tmp_path))
    assert not (tmp_path / ".tmp_orphan").exists()


def test_precheckchecksum_meta_falls_back_to_load_check(tmp_path):
    """Checkpoints written before shard checksums existed (no
    ``shard_checksums`` in meta) still verify via a decompress-and-index
    check, so old snapshots stay restorable."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0))
    meta_path = os.path.join(mgr._step_dir(1), "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["shard_checksums"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    ok, reason = mgr.verify_step(1)
    assert ok, reason
    shard = _shard(mgr, 1)
    with open(shard, "r+b") as f:
        f.truncate(os.path.getsize(shard) // 2)
    ok, reason = mgr.verify_step(1)
    assert not ok
