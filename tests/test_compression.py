"""Compression unit + regression tests: ratio pricing (clamp/warn above
dense, topk_int8 composition math, unknown-method rejection), the
``compress`` dispatch (none-flush semantics, exact-k on tied / all-zero
leaves), and the error-feedback round step built by
``server.make_fl_round_step(error_feedback=True)`` -- the telescoping
identity over a multi-round window on the *actual* params trajectory, the
EF-off bitwise pin, and the straggler residual freeze."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import compression, server
from repro.fl.service import arch_service_tuple
from repro import configs


# ---------------------------------------------------------------- ratios

def test_ratio_none_is_dense():
    assert compression.compression_ratio("none") == 1.0
    assert compression.compression_ratio("none", k_frac=0.9) == 1.0


def test_ratio_int8_is_bit_fraction():
    assert compression.compression_ratio("int8") == pytest.approx(0.25)
    assert compression.compression_ratio(
        "int8", weight_bits=16) == pytest.approx(0.5)


def test_ratio_topk_counts_values_and_indices():
    # k_frac * (weight_bits + index_bits) / weight_bits
    assert compression.compression_ratio(
        "topk", k_frac=0.05, index_bits=16) == pytest.approx(0.075)
    assert compression.compression_ratio(
        "topk", k_frac=0.01) == pytest.approx(0.02)


def test_ratio_topk_int8_composition_math():
    # quantized values (8 bits) + indices, over dense weight_bits
    assert compression.compression_ratio(
        "topk_int8", k_frac=0.05, index_bits=16) == pytest.approx(
            0.05 * (8 + 16) / 32)
    assert compression.compression_ratio(
        "topk_int8", k_frac=0.1, weight_bits=16,
        index_bits=32) == pytest.approx(0.1 * (8 + 32) / 16)


def test_ratio_clamps_and_warns_above_dense():
    """Large k_frac prices topk above a dense upload; the allocator must
    never see that, so the ratio clamps to 1.0 with a warning."""
    for method, kwargs in (("topk", dict(k_frac=0.9)),            # 1.8
                           ("topk_int8", dict(k_frac=0.9))):      # 1.125
        with pytest.warns(UserWarning, match="exceeds dense"):
            assert compression.compression_ratio(method, **kwargs) == 1.0
    # in-range ratios never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        compression.compression_ratio("topk", k_frac=0.01)
        compression.compression_ratio("int8")


def test_ratio_rejects_unknown_method_and_bad_k_frac():
    with pytest.raises(ValueError, match="unknown compression method"):
        compression.compression_ratio("gzip")
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="k_frac"):
            compression.compression_ratio("topk", k_frac=bad)
        with pytest.raises(ValueError, match="k_frac"):
            compression.compression_ratio("topk_int8", k_frac=bad)
    # k_frac is irrelevant to int8 -- out-of-range values must not trip it
    assert compression.compression_ratio("int8", k_frac=5.0) == 0.25


def test_service_tuple_rejects_inflated_multiplier():
    """arch_service_tuple refuses s^UT multipliers outside (0, 1]: a value
    above 1 means the caller bypassed compression_ratio's clamp."""
    cfg = configs.get_smoke_config("gemma-2b", n_layers=1, d_model=32,
                                   d_ff=64, vocab_size=32, n_heads=2,
                                   head_dim=16)
    kwargs = dict(r_dl=jnp.ones((2,)), r_ul=jnp.ones((2,)),
                  client_flops=jnp.full((2,), 1e12))
    for bad in (0.0, -0.5, 1.8):
        with pytest.raises(ValueError, match="uplink_compression"):
            arch_service_tuple(cfg, uplink_compression=bad, **kwargs)
    arch_service_tuple(cfg, uplink_compression=1.0, **kwargs)  # dense OK


# ------------------------------------------------------------- compress()

def test_compress_rejects_unknown_method():
    with pytest.raises(ValueError, match="unknown compression method"):
        compression.compress("gzip", {"w": jnp.ones((4,))})


def test_compress_none_identity_without_residual():
    delta = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    out, res = compression.compress("none", delta)
    assert res is None
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(delta["w"]))


def test_compress_none_flushes_residual():
    """Under error feedback the dense upload carries the backlog a lossy
    period withheld: ``none`` transmits delta + residual and zeroes the
    residual (what an adaptive controller switching back to dense needs)."""
    delta = {"w": jnp.asarray([1.0, 2.0])}
    res = {"w": jnp.asarray([0.5, -0.25])}
    out, new_res = compression.compress("none", delta, residual=res)
    np.testing.assert_array_equal(np.asarray(out["w"]), [1.5, 1.75])
    np.testing.assert_array_equal(np.asarray(new_res["w"]), [0.0, 0.0])


def test_compress_dispatch_matches_primitives():
    delta = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(32,)).astype(np.float32))}
    for method, direct in (
            ("topk", lambda d: compression.topk_sparsify(d, 0.25)),
            ("int8", lambda d: compression.int8_quantize(d))):
        got, got_res = compression.compress(method, delta, k_frac=0.25)
        want, want_res = direct(delta)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(want["w"]))
        np.testing.assert_array_equal(np.asarray(got_res["w"]),
                                      np.asarray(want_res["w"]))


def test_topk_int8_composes_under_one_residual():
    """topk_int8's residual absorbs the TOTAL round-trip error of the
    composition: transmitted + residual == delta (+ carried residual),
    exactly -- not just the sparsification stage's error."""
    rng = np.random.default_rng(1)
    delta = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    carried = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    out, res = compression.compress("topk_int8", delta, k_frac=0.25,
                                    residual=carried)
    # exactly k entries survive the sparsify stage (quantization keeps them)
    assert int(np.sum(np.asarray(out["w"]) != 0.0)) <= 16
    np.testing.assert_allclose(
        np.asarray(out["w"], np.float64) + np.asarray(res["w"], np.float64),
        np.asarray(delta["w"], np.float64) + np.asarray(carried["w"],
                                                        np.float64),
        rtol=1e-6, atol=1e-6)


def test_topk_exact_k_on_tied_leaf():
    """All-equal magnitudes: a threshold compare would keep every entry;
    top_k's index selection keeps exactly k (deterministic tie-break)."""
    delta = {"w": jnp.ones((16,))}
    sparse, res = compression.topk_sparsify(delta, 0.25)
    assert int(np.sum(np.asarray(sparse["w"]) != 0.0)) == 4
    np.testing.assert_allclose(
        np.asarray(sparse["w"]) + np.asarray(res["w"]),
        np.asarray(delta["w"]))


def test_topk_exact_k_on_all_zero_leaf():
    """Zero leaf (converged layer): threshold 0 would transmit the whole
    leaf as "kept zeros"; index selection transmits k entries and the
    residual stays exactly zero."""
    delta = {"w": jnp.zeros((16,)), "b": jnp.asarray([3.0, 0.0, -1.0, 0.5])}
    sparse, res = compression.topk_sparsify(delta, 0.25)
    np.testing.assert_array_equal(np.asarray(sparse["w"]), np.zeros((16,)))
    np.testing.assert_array_equal(np.asarray(res["w"]), np.zeros((16,)))
    # non-zero leaf is unaffected by its sibling: exactly 1 of 4 kept
    assert int(np.sum(np.asarray(sparse["b"]) != 0.0)) == 1
    assert float(sparse["b"][0]) == 3.0


def test_topk_k_floor_is_one():
    """k_frac below 1/n still transmits one entry per leaf, never zero."""
    sparse, _ = compression.topk_sparsify(
        {"w": jnp.asarray([0.1, -5.0, 0.2])}, 0.01)
    kept = np.asarray(sparse["w"])
    assert int(np.sum(kept != 0.0)) == 1 and float(kept[1]) == -5.0


# -------------------------------------------- error-feedback round step

def _ef_setup(n_clients=3, dim=8, seed=0):
    """Quadratic toy problem: loss = mean((w - x)^2), one leaf, so the raw
    per-round delta is analytically recoverable from a dense round step."""
    rng = np.random.default_rng(seed)

    def loss_fn(p, batch):
        return jnp.mean((p["w"] - batch["x"]) ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))}
    batches = {"x": jnp.asarray(rng.normal(
        size=(n_clients, 2, dim)).astype(np.float32))}
    kwargs = dict(local_steps=2, client_lr=0.3, server_lr=1.0)
    return loss_fn, params, batches, kwargs


def test_ef_round_step_telescopes_over_rounds():
    """Over any window of full-participation rounds with server_lr=1:
    (params_T - params_0) + mean_c(residual_T) == sum_t mean_c(raw delta_t)
    where the raw deltas are evaluated on the ACTUAL params trajectory --
    error feedback delays mass but never invents or drops it."""
    loss_fn, params0, batches, kwargs = _ef_setup()
    n_clients = batches["x"].shape[0]
    step_ef = server.make_fl_round_step(
        loss_fn, compression="topk", topk_frac=0.25,
        error_feedback=True, **kwargs)
    step_dense = server.make_fl_round_step(loss_fn, **kwargs)

    params = params0
    residuals = server.init_residuals(params0, n_clients)
    weights = jnp.ones((n_clients,))
    raw_sum = np.zeros_like(np.asarray(params0["w"], np.float64))
    for _ in range(6):
        # dense step at the EF trajectory's params recovers mean_c(raw delta)
        dense_next, _ = step_dense(params, batches, weights)
        raw_sum += (np.asarray(dense_next["w"], np.float64)
                    - np.asarray(params["w"], np.float64))
        params, _, residuals = step_ef(params, batches, weights, residuals)

    walked = (np.asarray(params["w"], np.float64)
              - np.asarray(params0["w"], np.float64))
    mean_resid = np.mean(np.asarray(residuals["w"], np.float64), axis=0)
    np.testing.assert_allclose(walked + mean_resid, raw_sum,
                               rtol=1e-4, atol=1e-5)
    # and the residual is genuinely nonzero (the compressor withheld mass)
    assert float(np.max(np.abs(np.asarray(residuals["w"])))) > 0.0


def test_ef_none_matches_plain_step_bitwise():
    """EF with the identity compressor and zero residuals is the plain
    FedAvg step bitwise; the residuals stay exactly zero."""
    loss_fn, params0, batches, kwargs = _ef_setup(seed=3)
    n_clients = batches["x"].shape[0]
    step_ef = server.make_fl_round_step(
        loss_fn, compression="none", error_feedback=True, **kwargs)
    step_plain = server.make_fl_round_step(loss_fn, **kwargs)
    weights = jnp.ones((n_clients,))
    residuals = server.init_residuals(params0, n_clients)
    p_ef, m_ef, res = step_ef(params0, batches, weights, residuals)
    p_plain, m_plain = step_plain(params0, batches, weights)
    np.testing.assert_array_equal(np.asarray(p_ef["w"]),
                                  np.asarray(p_plain["w"]))
    np.testing.assert_array_equal(np.asarray(m_ef["loss"]),
                                  np.asarray(m_plain["loss"]))
    np.testing.assert_array_equal(np.asarray(res["w"]),
                                  np.zeros_like(np.asarray(res["w"])))


def test_ef_straggler_residual_frozen():
    """A dropped client (weight 0) transmits nothing, so its residual must
    not advance -- neither flushed nor recompressed."""
    loss_fn, params0, batches, kwargs = _ef_setup(seed=5)
    n_clients = batches["x"].shape[0]
    step_ef = server.make_fl_round_step(
        loss_fn, compression="topk", topk_frac=0.25,
        error_feedback=True, **kwargs)
    weights = jnp.asarray([1.0, 0.0, 1.0])
    residuals = jax.tree.map(
        lambda p: jnp.arange(n_clients * p.size, dtype=p.dtype).reshape(
            (n_clients,) + p.shape) * 0.01,
        params0)
    _, _, res = step_ef(params0, batches, weights, residuals)
    # straggler's row untouched bitwise; participants' rows advanced
    np.testing.assert_array_equal(np.asarray(res["w"][1]),
                                  np.asarray(residuals["w"][1]))
    assert not np.array_equal(np.asarray(res["w"][0]),
                              np.asarray(residuals["w"][0]))


def test_init_residuals_shape_and_zero():
    params = {"a": jnp.ones((3, 2)), "b": jnp.ones((5,))}
    res = server.init_residuals(params, 4)
    assert res["a"].shape == (4, 3, 2) and res["b"].shape == (4, 5)
    assert all(float(jnp.max(jnp.abs(v))) == 0.0 for v in res.values())
