"""Distribution-layer tests: sharding rules, hierarchical/compressed
collectives on 8 host devices (subprocess), fault-tolerant resume, elastic
re-meshing."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.compat import AxisType, abstract_mesh
from repro.distributed import elastic, fault, sharding
from repro.models import registry


def _mesh_1d():
    """Production-shaped 16x16 mesh, abstract (no devices needed): sharding
    rules only read axis names/sizes."""
    return abstract_mesh((16, 16), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def test_param_sharding_rules_shapes():
    """Rules produce valid specs: every sharded dim divides the axis size."""
    mesh = _mesh_1d()
    for name in ("gemma-2b", "deepseek-v2-236b", "llama4-maverick-400b-a17b",
                 "xlstm-1.3b", "hymba-1.5b"):
        cfg = configs.get_config(name)
        params = registry.param_specs(cfg)
        sh = sharding.param_shardings(cfg, params, mesh)
        leaves = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
        assert len(leaves) == len(jax.tree.leaves(params))


def test_vocab_tables_never_fsdp_sharded():
    """The embed/unembed FSDP exemption (the 67 GB logits-gather fix)."""
    mesh = _mesh_1d()
    cfg = configs.get_config("gemma-2b")
    params = registry.param_specs(cfg)
    sh = sharding.param_shardings(cfg, params, mesh, fsdp=True)
    spec = sh["embed"].spec
    assert "data" not in jax.tree.leaves(tuple(spec)), spec


def test_expert_dim_sharded_on_model():
    mesh = _mesh_1d()
    cfg = configs.get_config("deepseek-v2-236b")
    params = registry.param_specs(cfg)
    sh = sharding.param_shardings(cfg, params, mesh, fsdp=False)
    spec = sh["blocks"]["ffn"]["routed"]["w_gate"].spec  # (L, E, d, f)
    assert spec[1] == "model", spec


def test_cache_sequence_parallel_fallback():
    """batch=1 long-context cells shard the cache on the sequence dim."""
    mesh = _mesh_1d()
    cfg = configs.get_config("gemma3-1b")
    model = registry.build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 4096))
    sh = sharding.cache_shardings(cfg, cache, mesh)
    assert sh["k"].spec[2] == "data", sh["k"].spec


MULTIDEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.distributed import collectives

    from repro.compat import make_mesh, AxisType
    mesh = make_mesh((2, 4), ("pod", "data"),
                     axis_types=(AxisType.Auto,) * 2)
    # local shard (4, 16): dim0 must divide the intra-pod (data=4) axis for
    # the reduce-scatter leg
    x = jnp.arange(32 * 16, dtype=jnp.float32).reshape(32, 16)

    def body(x):
        return collectives.hierarchical_psum(x, "data", "pod")

    out = jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod", "data")),
                            out_specs=P(("pod", "data"))))(x)
    # hierarchical psum of the 8 local (4,16) blocks == their plain sum,
    # replicated (tiled back through the out_specs concat)
    block_sum = x.reshape(8, 4, 16).sum(0)
    expect = jnp.tile(block_sum, (8, 1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)

    def body_c(x):
        full, resid = collectives.compressed_psum_int8(x, "data", "pod")
        return full, resid

    full, resid = jax.jit(shard_map(body_c, mesh=mesh,
                                    in_specs=P(("pod", "data")),
                                    out_specs=(P(("pod", "data")), P(("pod", "data")))))(x)
    err = np.abs(np.asarray(full) - np.asarray(expect))
    scale = np.abs(np.asarray(expect)).max()
    assert err.max() < 0.02 * scale + 1e-3, err.max()
    assert np.abs(np.asarray(resid)).max() < scale  # residual bounded
    print("COLLECTIVES-OK")
    """
)


def test_hierarchical_and_compressed_collectives_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", MULTIDEV], capture_output=True,
                         text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COLLECTIVES-OK" in out.stdout, out.stderr[-3000:]


def test_resumable_loop_survives_injected_failure(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    init = {"x": jnp.zeros(())}

    def step(state, t):
        return {"x": state["x"] + t}

    with pytest.raises(RuntimeError, match="injected"):
        fault.resumable_loop(step, init, 20, mgr,
                             fault.RestartPolicy(save_every=5), fail_at=13)
    # restart: resumes from step 10, replays 10..19
    final = fault.resumable_loop(step, init, 20, mgr,
                                 fault.RestartPolicy(save_every=5))
    assert float(final["x"]) == sum(range(20))


def test_resume_trajectory_identical_to_uninterrupted(tmp_path):
    """Deterministic data + checkpointing => failure-free and failed+resumed
    runs produce identical states."""
    mgr1 = CheckpointManager(str(tmp_path / "a"), keep=3)
    mgr2 = CheckpointManager(str(tmp_path / "b"), keep=3)

    def step(state, t):
        key = jax.random.fold_in(jax.random.key(7), t)
        return {"x": state["x"] * 0.9 + jax.random.normal(key, ())}

    init = {"x": jnp.ones(())}
    clean = fault.resumable_loop(step, init, 12, mgr1,
                                 fault.RestartPolicy(save_every=4))
    with pytest.raises(RuntimeError):
        fault.resumable_loop(step, init, 12, mgr2,
                             fault.RestartPolicy(save_every=4), fail_at=9)
    resumed = fault.resumable_loop(step, init, 12, mgr2,
                                   fault.RestartPolicy(save_every=4))
    np.testing.assert_allclose(float(clean["x"]), float(resumed["x"]), rtol=1e-6)


def test_elastic_remesh_factorizations():
    plan = elastic.plan_service_remesh(256, 240, model_parallel=16)
    assert plan["before"] == {"data": 16, "model": 16}
    # 240 % 16 == 0 -> model parallel preserved
    assert plan["after"] == {"data": 15, "model": 16}
    assert not plan["model_parallel_changed"]
    plan2 = elastic.plan_service_remesh(256, 252, model_parallel=16)
    # 252 = 4*63 -> model shrinks to 4
    assert plan2["after"]["model"] == 4
    assert plan2["model_parallel_changed"]


def test_allocator_invariant_under_remesh():
    """The paper-layer elasticity: the bandwidth allocation is a pure function
    of the service set, so device-layer re-meshing never changes it."""
    from repro.core import disba, network
    svc, _ = network.sample_services(jax.random.key(0), 8, k_max=30)
    res = disba.solve_lambda_bisect(svc, 10.0)
    # (solve twice to emulate re-run after remesh)
    res2 = disba.solve_lambda_bisect(svc, 10.0)
    np.testing.assert_array_equal(np.asarray(res.b), np.asarray(res2.b))


EP_MOE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import moe
    from repro.models.config import ModelConfig
    from repro.distributed import api as dist_api

    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, head_dim=16, d_ff=0, vocab_size=64,
                      n_experts=8, n_experts_per_token=2, d_ff_expert=48,
                      capacity_factor=8.0, dtype="float32")
    p = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model))
    ref = moe.apply_moe_dense_ref(p, x, cfg)
    from repro.compat import make_mesh, AxisType
    mesh = make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    dist_api.set_mesh(mesh)
    out, aux = jax.jit(lambda p_, x_: moe.apply_moe(p_, x_, cfg))(p, x)
    g = jax.jit(jax.grad(
        lambda p_, x_: jnp.sum(moe.apply_moe(p_, x_, cfg)[0] ** 2)))(p, x)
    dist_api.set_mesh(None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
    print("EP-MOE-OK")
    """
)


def test_expert_parallel_moe_8dev():
    """The shard_map expert-parallel dispatch equals the dense oracle on a
    (data=2, model=4) mesh and differentiates cleanly (§Perf cell 2)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", EP_MOE_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "EP-MOE-OK" in out.stdout, out.stderr[-3000:]


def test_serve_2d_param_shardings():
    """Serving layout: weights stationary on both axes, no FSDP gathers."""
    mesh = _mesh_1d()
    cfg = configs.get_config("deepseek-v2-236b")
    params = registry.param_specs(cfg)
    sh = sharding.param_shardings(cfg, params, mesh, serve_2d=True)
    spec = sh["blocks"]["attn"]["wq_b"].spec     # (L, q_lora, H*(dn+dr))
    assert spec[-1] == "model" and spec[-2] == "data", spec
    espec = sh["blocks"]["ffn"]["routed"]["w_gate"].spec  # (L, E, d, f)
    assert espec[1] == "model" and espec[3] == "data", espec
