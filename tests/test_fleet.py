"""Fleet engine acceptance: device-sharded, memory-bounded episode sweeps.

* batched device-side static draws: one dispatch for a whole fleet, bitwise
  identical to the looped per-seed reference for every arrival process;
* ``run_fleet`` per-seed bitwise equality vs ``run_batch`` / ``run_scan``
  under chunking, padding (uneven fleet sizes), ``collect_history`` on/off,
  and warm-start carry across chunk boundaries -- on 1 device in-process and
  on 8 forced-host devices in a subprocess;
* single-trace compilation for every (policy, scenario, warm) combination;
* a 4096-episode aggregate-only sweep whose outputs contain no (S, T) array.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import scenarios
from repro.compat import flat_mesh
from repro.fl import simulator

BASE = dict(policy="es", n_services_total=3, rounds_required=100,
            p_arrive=2.0, max_periods=100, k_max=32)

FULL_STACK = dict(
    channel_process=scenarios.spec("gauss_markov", rho=0.9),
    arrival_process=scenarios.spec("mmpp", burst=6.0),
    churn_process=scenarios.spec("bernoulli", p_drop=0.1),
)


def _cfg(**kw) -> simulator.SimConfig:
    return simulator.SimConfig(**{**BASE, **kw})


def _mesh1():
    return flat_mesh(1, axis_name="seeds")


# ---------------------------------------------------------------------------
# Vectorized static draws.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arrival", ["poisson", "periodic", "batched", "mmpp"])
def test_static_draws_batch_bitwise_equals_looped_reference(arrival):
    """One batched draw == looping the per-seed path, for every arrival
    process: fleet setup can be O(1) dispatches without changing a single
    episode."""
    cfg = _cfg(arrival_process=arrival)
    net = simulator._default_net(cfg)
    seeds = [0, 3, 11, 42]
    arrivals, counts = simulator._static_draws_batch(cfg, net, seeds)
    assert arrivals.shape == counts.shape == (4, cfg.n_services_total)
    for i, s in enumerate(seeds):
        a_ref, c_ref = simulator._static_draws(
            dataclasses.replace(cfg, seed=s), net)
        np.testing.assert_array_equal(arrivals[i], a_ref)
        np.testing.assert_array_equal(counts[i], c_ref)


def test_static_draws_respect_client_bounds():
    cfg = _cfg(mean_clients=6.0, var_clients=100.0, k_max=9)
    net = simulator._default_net(cfg)
    _, counts = simulator._static_draws_batch(cfg, net, list(range(32)))
    assert counts.min() >= net.k_min
    assert counts.max() <= 9


# ---------------------------------------------------------------------------
# run_fleet parity vs run_batch / run_scan (single device, in-process).
# ---------------------------------------------------------------------------

def test_fleet_bitwise_equals_batch_and_scan_uneven_chunked():
    """Fleet of 5 on chunk 2: remainder chunk + padding.  Every per-seed
    output must be bitwise identical to run_batch AND to the seed's own
    run_scan."""
    cfg = _cfg()
    seeds = [0, 1, 2, 3, 4]
    fleet = simulator.run_fleet(cfg, seeds, mesh=_mesh1(), chunk_size=2)
    assert fleet["fleet"] == {"n_devices": 1, "mesh_axis": "seeds",
                              "chunk": 2, "n_chunks": 3, "padded_to": 6}
    batch = simulator.run_batch(cfg, seeds)
    np.testing.assert_array_equal(fleet["durations"], batch["durations"])
    np.testing.assert_array_equal(fleet["finished"], batch["finished"])
    for key in ("freq_sum", "objective", "n_active", "n_clients"):
        np.testing.assert_array_equal(fleet["history"][key],
                                      batch["history"][key])
    single = simulator.run_scan(dataclasses.replace(cfg, seed=3))
    assert list(fleet["durations"][3]) == single["durations"]
    p = single["periods"]
    np.testing.assert_array_equal(fleet["history"]["freq_sum"][3][:p],
                                  single["history"]["freq_sum"])


@pytest.mark.parametrize("chunk_size", [1, 3, None])
def test_fleet_invariant_to_chunk_size(chunk_size):
    cfg = _cfg(collect_history=False)
    seeds = [0, 1, 2, 3]
    fleet = simulator.run_fleet(cfg, seeds, mesh=_mesh1(),
                                chunk_size=chunk_size)
    batch = simulator.run_batch(cfg, seeds)
    np.testing.assert_array_equal(fleet["durations"], batch["durations"])
    np.testing.assert_array_equal(fleet["periods"], batch["periods"])
    for key in simulator._AGG_KEYS:
        np.testing.assert_array_equal(fleet["totals"][key],
                                      batch["totals"][key])


def test_fleet_warm_start_carry_across_chunks():
    """Warm-started policy state rides inside each episode's scan carry;
    chunking the fleet must not perturb it -- durations and float history
    stay bitwise equal to the flat warm batch."""
    cfg = _cfg(policy="coop", rounds_required=80, max_periods=80, k_max=24,
               warm_start=True)
    seeds = [0, 1, 2]
    fleet = simulator.run_fleet(cfg, seeds, mesh=_mesh1(), chunk_size=1)
    batch = simulator.run_batch(cfg, seeds)
    np.testing.assert_array_equal(fleet["durations"], batch["durations"])
    for key in ("freq_sum", "objective"):
        np.testing.assert_array_equal(fleet["history"][key],
                                      batch["history"][key])


def test_fleet_rejects_empty_and_multiaxis():
    with pytest.raises(ValueError, match="at least one seed"):
        simulator.run_fleet(_cfg(), [])
    mesh2d = jax.make_mesh((1, 1), ("a", "b"))
    with pytest.raises(ValueError, match="one-axis mesh"):
        simulator.run_fleet(_cfg(), [0], mesh=mesh2d)


def test_legacy_resume_rejects_foreign_draw_stream():
    """A legacy-engine checkpoint written under a different episode-static
    draw stream (e.g. the pre-fleet host-NumPy draws) must be refused on
    resume: arrivals are re-derived from cfg.seed, so continuing would
    silently diverge from the snapshot's recorded progress."""
    cfg = _cfg(max_periods=12)
    part = simulator.run(dataclasses.replace(cfg, max_periods=4))
    state = dict(part["state"])
    assert state["draw_stream"] == simulator.DRAW_STREAM
    # same-stream resume still works ...
    resumed = simulator.run(cfg, state=dict(state))
    full = simulator.run(cfg)
    assert resumed["durations"] == full["durations"]
    # ... a foreign or missing stream tag does not
    state["draw_stream"] = "numpy/v0"
    with pytest.raises(ValueError, match="draw stream"):
        simulator.run(cfg, state=state)
    state.pop("draw_stream")
    with pytest.raises(ValueError, match="draw stream"):
        simulator.run(cfg, state=state)


# ---------------------------------------------------------------------------
# Single-trace compilation across policy x scenario x warm combos.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("warm_start", [False, True])
@pytest.mark.parametrize("pol", simulator.POLICIES)
def test_fleet_single_trace_every_policy_warm_combo(pol, warm_start):
    cfg = simulator.SimConfig(policy=pol, n_services_total=3,
                              rounds_required=60, p_arrive=2.0,
                              max_periods=60, warm_start=warm_start)
    simulator.reset_trace_count()
    out = simulator.run_fleet(cfg, [0, 1, 2], mesh=_mesh1(), chunk_size=2)
    assert out["finished"].all()
    assert simulator.trace_count() == 1
    # same combo again: fully cached, no retrace
    simulator.run_fleet(cfg, [3, 4, 5], mesh=_mesh1(), chunk_size=2)
    assert simulator.trace_count() == 1


@pytest.mark.parametrize("warm_start", [False, True])
@pytest.mark.parametrize("pol", ["coop", "es"])
def test_fleet_single_trace_with_stateful_scenarios(pol, warm_start):
    cfg = simulator.SimConfig(policy=pol, n_services_total=3,
                              rounds_required=60, p_arrive=2.0,
                              max_periods=60, warm_start=warm_start,
                              **FULL_STACK)
    simulator.reset_trace_count()
    simulator.run_fleet(cfg, [0, 1, 2], mesh=_mesh1(), chunk_size=2)
    assert simulator.trace_count() == 1


# ---------------------------------------------------------------------------
# Memory-bounded sweeps: no (S, T) history in aggregate-only mode.
# ---------------------------------------------------------------------------

def test_fleet_4096_aggregate_only_materializes_no_history():
    """A 4096-episode chunked sweep in aggregate-only mode completes and
    returns per-seed scalars only -- no output array carries a period axis,
    so peak memory stays O(chunk) + O(S) summaries."""
    cfg = simulator.SimConfig(policy="ec", n_services_total=2,
                              rounds_required=2000, p_arrive=2.0,
                              mean_clients=6.0, var_clients=2.0,
                              max_periods=6, collect_history=False)
    n_seeds = 4096
    out = simulator.run_fleet(cfg, range(n_seeds), mesh=_mesh1())
    assert out["history"] is None
    assert out["fleet"]["chunk"] == simulator.FLEET_CHUNK
    assert out["fleet"]["n_chunks"] == n_seeds // simulator.FLEET_CHUNK
    allowed = {(n_seeds,), (n_seeds, cfg.n_services_total)}
    for name in ("avg_duration", "std_duration", "durations", "finished",
                 "periods"):
        assert np.asarray(out[name]).shape in allowed, name
    for key, val in out["totals"].items():
        assert val.shape == (n_seeds,), key
    # the pad-free seed axis survives intact
    assert list(out["seeds"]) == list(range(n_seeds))


# ---------------------------------------------------------------------------
# 8 forced-host devices (subprocess so the XLA flag doesn't leak).
# ---------------------------------------------------------------------------

MULTIDEV_FLEET_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro import scenarios
    from repro.fl import simulator

    assert jax.device_count() == 8
    cfg = simulator.SimConfig(
        policy="coop", n_services_total=3, rounds_required=80, p_arrive=2.0,
        max_periods=80, k_max=24, warm_start=True,
        channel_process=scenarios.spec("gauss_markov", rho=0.9),
        churn_process=scenarios.spec("bernoulli", p_drop=0.1))
    seeds = list(range(11))   # uneven over 8 devices -> pad + remainder
    simulator.reset_trace_count()
    fleet = simulator.run_fleet(cfg, seeds, chunk_size=2)
    assert simulator.trace_count() == 1, simulator.trace_count()
    assert fleet["fleet"]["n_devices"] == 8, fleet["fleet"]
    batch = simulator.run_batch(cfg, seeds)
    np.testing.assert_array_equal(fleet["durations"], batch["durations"])
    for key in ("freq_sum", "objective", "n_active", "n_clients"):
        np.testing.assert_array_equal(fleet["history"][key],
                                      batch["history"][key])
    print("FLEET-8DEV-OK")
    """
)


def test_fleet_eight_devices_bitwise_parity():
    """run_fleet sharded over 8 forced-host devices (default mesh from
    launch.mesh.make_fleet_mesh): bitwise per-seed parity with the flat
    single-device run_batch, warm start + stateful scenarios enabled."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_FLEET_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "FLEET-8DEV-OK" in out.stdout, out.stderr[-2000:]
