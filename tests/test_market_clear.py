"""The whole-market megakernel: one fused Pallas launch of the complete
safeguarded-Newton dual solve (kernels/market_clear.py).

* interpret-mode parity of the ``market_clear`` launch against the reference
  ``solve_lambda_newton_warm`` finals (warm / stale / cold seeds) on masked
  padded fixed-capacity sets -- exact-to-dtype, the PR-3 kernel convention;
* the ``ops.market_clear(use_pallas=False)`` fallback bitwise against the
  reference solver (it *is* the reference solver);
* budget conservation and zero-demand inactive slots, including the
  all-inactive degenerate market;
* ``disba.solve_lambda_newton_warm(backend="megakernel")`` wiring;
* ``disba_sharded(method="newton")``: warm-startable scalar-psum-only dual
  trips match the dense solver, reference and pallas per-shard demand;
* the warm-carry protocol: ``intra_backend="megakernel"`` threads through
  ``StatefulPolicy`` and ``fl.simulator`` unchanged (``trace_count() == 1``);
* the (N, M) mbdf grid kernel vs ``fairness.mbdf_grid`` and its auction
  entry point.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import auction, disba, fairness, network, policy
from repro.core.types import ServiceSet, mask_inactive
from repro.fl import simulator
from repro.kernels import ops, ref
from repro.kernels.market_clear import market_clear, mbdf_demand

B = network.B_TOTAL_MHZ


def _masked_fixed_capacity_set(seed, n=9, k=31):
    """Random padded ServiceSet with ragged counts and inactive slots."""
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.01, 0.3, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.01, 0.06, size=(n, k)).astype(np.float32)
    mask = np.zeros((n, k), dtype=bool)
    for i in range(n):
        mask[i, : rng.integers(2, k + 1)] = True
    mask[rng.integers(0, n)] = False
    alpha = np.where(mask, alpha, 0.0)
    t_comp = np.where(mask, t_comp, 0.0)
    return ServiceSet(alpha=jnp.asarray(alpha), t_comp=jnp.asarray(t_comp),
                      mask=jnp.asarray(mask))


# ---------------------------------------------------------------------------
# Kernel parity vs the reference solver finals.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,seed_scale", [
    (0, 1.03),   # warm: the temporal-coherence case the megakernel targets
    (1, 0.7),    # stale seed -> safeguarded recovery
    (2, None),   # cold sentinel
])
def test_market_clear_kernel_matches_reference_finals(seed, seed_scale):
    svc = _masked_fixed_capacity_set(seed)
    lam_prev = (jnp.float32(disba.WARM_COLD) if seed_scale is None
                else disba.solve_lambda_bisect(svc, B).lam
                * jnp.float32(seed_scale))
    expect = disba.solve_lambda_newton_warm(svc, B, lam_prev)
    b, f, lam = market_clear(svc.alpha, svc.t_comp, jnp.float32(B), lam_prev,
                             tile_n=8, interpret=True)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(expect.lam),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(expect.b),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f), np.asarray(expect.f),
                               rtol=1e-3, atol=1e-5)


def test_market_clear_budget_and_inactive_rows():
    svc = _masked_fixed_capacity_set(4)
    b, f, _ = market_clear(svc.alpha, svc.t_comp, jnp.float32(B),
                           jnp.float32(disba.WARM_COLD), tile_n=8,
                           interpret=True)
    np.testing.assert_allclose(float(jnp.sum(b)), B, rtol=1e-5)
    inactive = ~np.asarray(svc.service_active())
    assert inactive.any()
    assert np.all(np.asarray(b)[inactive] == 0.0)
    assert np.all(np.asarray(f)[inactive] == 0.0)


def test_market_clear_all_inactive_market():
    svc = _masked_fixed_capacity_set(5)
    svc = mask_inactive(svc, jnp.zeros((svc.n_services,), bool))
    b, f, lam = market_clear(svc.alpha, svc.t_comp, jnp.float32(B),
                             jnp.float32(0.2), tile_n=8, interpret=True)
    assert np.all(np.asarray(b) == 0.0)
    assert np.all(np.asarray(f) == 0.0)
    assert np.isfinite(float(lam))


def test_ops_fallback_is_bitwise_reference():
    """use_pallas=False must delegate to the reference solver itself."""
    svc = _masked_fixed_capacity_set(6)
    lam_prev = jnp.float32(0.15)
    b, f, lam = ops.market_clear(svc.alpha, svc.t_comp, jnp.float32(B),
                                 lam_prev, use_pallas=False)
    expect = disba.solve_lambda_newton_warm(svc, B, lam_prev)
    assert np.array_equal(np.asarray(b), np.asarray(expect.b))
    assert np.array_equal(np.asarray(f), np.asarray(expect.f))
    assert float(lam) == float(expect.lam)


def test_disba_megakernel_backend():
    svc = _masked_fixed_capacity_set(7)
    lam_prev = disba.solve_lambda_bisect(svc, B).lam * jnp.float32(1.02)
    res = disba.solve_lambda_newton_warm(svc, B, lam_prev,
                                         backend="megakernel")
    expect = disba.solve_lambda_newton_warm(svc, B, lam_prev)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(expect.b),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.f), np.asarray(expect.f),
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(float(res.lam), float(expect.lam), rtol=1e-4)


# ---------------------------------------------------------------------------
# Sharded Newton: scalar-only cross-device dual trips.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("demand_backend", ["reference", "pallas"])
def test_disba_sharded_newton_matches_dense(demand_backend):
    svc = _masked_fixed_capacity_set(8, n=12)
    lam_prev = disba.solve_lambda_bisect(svc, B).lam * jnp.float32(1.05)
    expect = disba.solve_lambda_newton_warm(svc, B, lam_prev)
    res = disba.disba_sharded(None, svc, B, method="newton",
                              lam_prev=lam_prev, iters=disba.WARM_ITERS,
                              demand_backend=demand_backend)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(expect.b),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(res.lam), float(expect.lam), rtol=1e-3)


def test_disba_sharded_newton_cold_seed_matches_newton():
    svc = _masked_fixed_capacity_set(9, n=8)
    expect = disba.solve_lambda_newton(svc, B)
    res = disba.disba_sharded(None, svc, B, method="newton", iters=12,
                              newton_inner_iters=disba.BISECT_ITERS)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(expect.b),
                               rtol=1e-4, atol=1e-5)


def test_disba_sharded_unknown_method_raises():
    svc = _masked_fixed_capacity_set(10, n=4)
    with pytest.raises(ValueError, match="method"):
        disba.disba_sharded(None, svc, B, method="simplex")


def test_disba_sharded_bisect_path_unchanged():
    """The default method stays the cold bisection -- existing callers see
    identical results."""
    svc = _masked_fixed_capacity_set(11, n=8)
    res = disba.disba_sharded(None, svc, B)
    expect = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(expect.b),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Warm-carry protocol: StatefulPolicy / simulator threading.
# ---------------------------------------------------------------------------

def test_stateful_policy_megakernel_step_matches_reference():
    svc = _masked_fixed_capacity_set(12)
    pol = policy.get_stateful_policy("coop", warm_start=True,
                                     intra_backend="megakernel")
    pol_ref = policy.get_stateful_policy("coop", warm_start=True)
    b, f, state = pol.step(svc, B, pol.init_state(svc.n_services))
    b_r, f_r, state_r = pol_ref.step(svc, B,
                                     pol_ref.init_state(svc.n_services))
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_r),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_r),
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(float(state.lam), float(state_r.lam),
                               rtol=1e-4)


def test_simulator_scan_megakernel_traces_once():
    cfg = simulator.SimConfig(policy="coop", intra_backend="megakernel",
                              warm_start=True, n_services_total=6,
                              max_periods=60, seed=0)
    simulator.reset_trace_count()
    out = simulator.run_scan(cfg)
    assert simulator.trace_count() == 1
    ref_out = simulator.run_scan(
        simulator.SimConfig(policy="coop", warm_start=True,
                            n_services_total=6, max_periods=60, seed=0))
    np.testing.assert_allclose(
        np.asarray(out["avg_duration"]), np.asarray(ref_out["avg_duration"]),
        rtol=1e-3)


# ---------------------------------------------------------------------------
# The (N, M) mbdf grid kernel on the market tiling.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,alpha_fair", [(0, 0.5), (1, 0.0), (2, 1.0)])
def test_mbdf_kernel_matches_grid_reference(seed, alpha_fair):
    svc = _masked_fixed_capacity_set(seed)
    bid = auction.uniform_truthful_bids(svc, 5, alpha_fair)
    expect = fairness.mbdf_grid(svc, bid.prices, alpha_fair)
    got = mbdf_demand(svc.alpha, svc.t_comp, bid.prices, alpha_fair,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_mbdf_grid_pallas_backend_and_auction_entry():
    svc = _masked_fixed_capacity_set(3)
    bid_ref = auction.uniform_truthful_bids(svc, 5, 0.5)
    bid_k = auction.uniform_truthful_bids(svc, 5, 0.5, backend="pallas")
    assert np.array_equal(np.asarray(bid_ref.prices),
                          np.asarray(bid_k.prices))
    np.testing.assert_allclose(np.asarray(bid_k.demands),
                               np.asarray(bid_ref.demands),
                               rtol=1e-4, atol=1e-5)
    with pytest.raises(ValueError, match="mbdf backend"):
        fairness.mbdf_grid(svc, bid_ref.prices, 0.5, backend="nope")


def test_mbdf_demand_ref_oracle_delegates():
    svc = _masked_fixed_capacity_set(4)
    bid = auction.uniform_truthful_bids(svc, 4, 0.5)
    got = ref.mbdf_demand_ref(svc.alpha, svc.t_comp, bid.prices, 0.5)
    expect = fairness.mbdf_grid(svc, bid.prices, 0.5)
    assert np.array_equal(np.asarray(got), np.asarray(expect))
