"""Serving-driver regressions (launch.serve).

Pins the two bugs the driver shipped with:
  * ``--reduced`` was declared ``action="store_true", default=True`` -- a
    flag that could never be turned off, leaving the full-config branch
    dead code;
  * the first generated token was always ``argmax`` even with
    ``--temperature > 0`` (and ``t_prefill`` was read before blocking on
    the async-dispatched logits), so sampled generation silently started
    greedy and emitted ``gen`` tokens only by accident of the loop bounds.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import serve

_V = 11


class _StubModel:
    """Deterministic toy model: prefill logits ramp up to token _V-1 (the
    argmax), decode logits ramp down to token 0.  Cache carries a length
    counter so decode launches are countable through jit."""

    def prefill(self, params, batch, max_len):
        b, length = batch["tokens"].shape
        logits = jnp.broadcast_to(
            jnp.arange(_V, dtype=jnp.float32) * 0.1, (b, length, _V))
        return logits, {"len": jnp.int32(length)}

    def decode_step(self, params, cache, tok):
        b = tok.shape[0]
        logits = jnp.broadcast_to(
            -jnp.arange(_V, dtype=jnp.float32) * 0.1, (b, 1, _V))
        return logits, {"len": cache["len"] + 1}


# -- the --reduced flag ------------------------------------------------------

def test_reduced_flag_defaults_on():
    assert serve.build_parser().parse_args([]).reduced is True


def test_reduced_flag_can_be_disabled():
    """The pre-fix parser accepted only ``--reduced`` (a no-op given the
    True default); ``--no-reduced`` must parse and flip the branch."""
    assert serve.build_parser().parse_args(["--no-reduced"]).reduced is False
    assert serve.build_parser().parse_args(["--reduced"]).reduced is True


def test_resolve_config_reaches_both_branches(monkeypatch):
    from repro import configs
    monkeypatch.setattr(configs, "get_smoke_config", lambda arch: "smoke")
    monkeypatch.setattr(configs, "get_config", lambda arch: "full")
    assert serve.resolve_config("any", reduced=True) == "smoke"
    assert serve.resolve_config("any", reduced=False) == "full"


# -- sampling + token count --------------------------------------------------

def _generate(gen, temperature, seed=0, batch_size=2, prompt_len=3):
    model = _StubModel()
    batch = {"tokens": jnp.zeros((batch_size, prompt_len), jnp.int32)}
    return serve.generate(
        model, {}, batch, max_len=prompt_len + gen, gen=gen,
        temperature=temperature, key=jax.random.key(seed), jit_prefill=False)


def test_first_token_uses_temperature_path():
    """Regression: the first token must come from the same categorical
    sampler as the rest, not argmax.  With seed 0 / temperature 3 on the
    stub's ramp logits the sampled token (8) differs from argmax (10)."""
    out, _ = _generate(gen=3, temperature=3.0, seed=0)
    key = jax.random.key(0)
    expected = serve.sample_token(
        jax.random.split(key)[1],
        _StubModel().prefill({}, {"tokens": jnp.zeros((2, 3), jnp.int32)},
                             max_len=6)[0],
        3.0)
    assert jnp.array_equal(out[:, :1], expected)
    assert int(expected[0, 0]) != _V - 1, (
        "chosen seed must distinguish sampling from argmax")


def test_first_token_greedy_at_temperature_zero():
    out, _ = _generate(gen=2, temperature=0.0)
    assert int(out[0, 0]) == _V - 1          # prefill argmax
    assert int(out[0, 1]) == 0               # decode argmax


def test_emits_exactly_gen_tokens():
    for gen in (1, 4):
        out, info = _generate(gen=gen, temperature=1.0)
        assert out.shape == (2, gen)
        assert info["decode_steps"] == gen - 1
        # cache counter: prompt_len + one bump per decode launch
        assert int(info["cache"]["len"]) == 3 + (gen - 1)


def test_gen_must_be_positive():
    with pytest.raises(ValueError, match="gen"):
        _generate(gen=0, temperature=1.0)


def test_prefill_timing_measured_on_ready_logits():
    _, info = _generate(gen=1, temperature=1.0)
    assert info["t_prefill"] > 0.0
    assert info["decode_steps"] == 0
