"""Unit + property tests for the intra-service allocator (paper Eqns. 1-10, 14)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intra
from repro.core.types import ServiceSet, make_service_set, round_time_given_alloc


def _random_service(seed, n=4, k=9):
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.01, 0.2, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.01, 0.06, size=(n, k)).astype(np.float32)
    mask = np.ones((n, k), dtype=bool)
    # ragged client counts
    for i in range(n):
        kk = rng.integers(2, k + 1)
        mask[i, kk:] = False
    return make_service_set(alpha, t_comp, mask)


def test_round_time_above_compute_floor():
    svc = _random_service(0)
    b = jnp.array([1.0, 2.0, 0.5, 3.0])
    t = intra.solve_round_time(svc, b)
    assert bool(jnp.all(t > svc.t_comp_max()))


def test_allocation_sums_to_budget_and_equalizes():
    svc = _random_service(1)
    b = jnp.array([1.0, 2.0, 0.5, 3.0])
    alloc = intra.client_allocation(svc, b)
    np.testing.assert_allclose(np.asarray(alloc.sum(-1)), np.asarray(b), rtol=1e-5)
    # At the optimum every *valid* client finishes at t* (Eq. 6).
    t = intra.solve_round_time(svc, b)
    finish = svc.t_comp + svc.alpha / jnp.maximum(alloc, 1e-30)
    finish = jnp.where(svc.mask, finish, t[:, None])
    np.testing.assert_allclose(np.asarray(finish), np.asarray(t)[:, None] * np.ones_like(finish), rtol=1e-3)


def test_optimality_vs_random_splits():
    """No random feasible split beats the equal-finish-time solution."""
    svc = _random_service(2)
    b = jnp.array([1.0, 1.5, 2.0, 0.8])
    t_opt = intra.solve_round_time(svc, b)
    rng = np.random.default_rng(0)
    for _ in range(25):
        w = rng.uniform(0.05, 1.0, size=svc.alpha.shape).astype(np.float32)
        w = np.where(np.asarray(svc.mask), w, 0.0)
        w = w / w.sum(-1, keepdims=True) * np.asarray(b)[:, None]
        t_rand = round_time_given_alloc(svc, jnp.where(svc.mask, jnp.asarray(w), 1e30))
        assert bool(jnp.all(t_rand >= t_opt - 1e-4))


def test_freq_monotone_increasing_and_concave():
    svc = _random_service(3)
    bs = jnp.linspace(0.05, 8.0, 60)
    f = jax.vmap(lambda b: intra.freq(svc, jnp.full((4,), b)))(bs)  # (60, 4)
    df = jnp.diff(f, axis=0)
    assert bool(jnp.all(df > 0)), "f*(b) must be increasing"
    d2f = jnp.diff(df, axis=0)
    assert bool(jnp.all(d2f <= 1e-5)), "f*(b) must be concave"


def test_freq_prime_matches_numerical_derivative():
    svc = _random_service(4)
    b0 = jnp.full((4,), 1.7)
    h = 1e-2
    f_hi = intra.freq(svc, b0 + h)
    f_lo = intra.freq(svc, b0 - h)
    numeric = (f_hi - f_lo) / (2 * h)
    analytic = intra.freq_prime_at_f(svc, intra.freq(svc, b0))
    np.testing.assert_allclose(np.asarray(analytic), np.asarray(numeric), rtol=2e-2)


def test_bandwidth_freq_roundtrip():
    svc = _random_service(5)
    b = jnp.array([0.3, 1.0, 2.5, 4.0])
    f = intra.freq(svc, b)
    b_back = intra.bandwidth_from_freq(svc, f)
    np.testing.assert_allclose(np.asarray(b_back), np.asarray(b), rtol=1e-3)


def test_padding_invariance():
    """Adding padded client slots must not change any result."""
    svc = _random_service(6, n=3, k=6)
    pad = 5
    alpha = jnp.pad(svc.alpha, ((0, 0), (0, pad)))
    t_comp = jnp.pad(svc.t_comp, ((0, 0), (0, pad)), constant_values=99.0)
    mask = jnp.pad(svc.mask, ((0, 0), (0, pad)), constant_values=False)
    svc_pad = ServiceSet(alpha=alpha, t_comp=t_comp, mask=mask)
    b = jnp.array([1.0, 2.0, 3.0])
    np.testing.assert_allclose(
        np.asarray(intra.freq(svc, b)), np.asarray(intra.freq(svc_pad, b)), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(intra.demand(svc, 0.5)), np.asarray(intra.demand(svc_pad, 0.5)), rtol=1e-6
    )


def test_demand_decreasing_in_price_and_zero_above_pmax():
    svc = _random_service(7)
    pmax = intra.p_max(svc)
    lams = jnp.linspace(1e-3, float(pmax.max()) * 1.2, 50)
    d = jax.vmap(lambda l: intra.demand(svc, l))(lams)
    assert bool(jnp.all(jnp.diff(d, axis=0) <= 1e-5))
    above = lams[:, None] >= pmax[None, :]
    assert bool(jnp.all(jnp.where(above, d, 0.0) == 0.0))


def test_price_freq_inverse_consistency():
    svc = _random_service(8)
    lam = 0.4 * intra.p_max(svc)
    f = intra.freq_from_price(svc, lam)
    lam_back = intra.price_at_freq(svc, f)
    np.testing.assert_allclose(np.asarray(lam_back), np.asarray(lam), rtol=1e-3)


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    b_scale=st.floats(0.05, 50.0),
    n=st.integers(1, 6),
    k=st.integers(2, 12),
)
def test_property_invariants(seed, b_scale, n, k):
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(1e-3, 1.0, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(1e-4, 0.2, size=(n, k)).astype(np.float32)
    svc = make_service_set(alpha, t_comp)
    b = jnp.full((n,), float(b_scale))
    t = intra.solve_round_time(svc, b)
    alloc = intra.client_allocation(svc, b)
    assert bool(jnp.all(t > svc.t_comp_max()))
    assert bool(jnp.all(alloc >= 0))
    np.testing.assert_allclose(np.asarray(alloc.sum(-1)), np.asarray(b), rtol=1e-4)
    f = intra.freq(svc, b)
    assert bool(jnp.all((f > 0) & (f < intra.f_max(svc))))
