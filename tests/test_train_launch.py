"""Training-driver regressions (launch.train).

Pins the two bugs the driver shipped with:
  * ``--reduced`` was declared ``action="store_true", default=True`` -- a
    flag that could never be turned off, leaving the full-config branch
    dead code (the same bug PR 7 pinned in launch.serve);
  * the allocator's s^UT pricing and the round step's sparsifier each fell
    back to their own hard-coded ``k_frac`` default, so the bandwidth model
    could price a different sparsity than the clients actually transmitted.
    ``compression_setup`` now feeds ONE ``--topk-frac`` to both sides.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import compression as fl_comp
from repro.launch import train


# -- the --reduced flag ------------------------------------------------------

def test_reduced_flag_defaults_on():
    assert train.build_parser().parse_args([]).reduced is True


def test_reduced_flag_can_be_disabled():
    """The pre-fix parser accepted only ``--reduced`` (a no-op given the
    True default); ``--no-reduced`` must parse and flip the branch."""
    assert train.build_parser().parse_args(["--no-reduced"]).reduced is False
    assert train.build_parser().parse_args(["--reduced"]).reduced is True


def test_resolve_config_reaches_both_branches(monkeypatch):
    from repro import configs
    monkeypatch.setattr(configs, "get_smoke_config", lambda arch: "smoke")
    monkeypatch.setattr(configs, "get_config", lambda arch: "full")
    assert train.resolve_config("any", reduced=True) == "smoke"
    assert train.resolve_config("any", reduced=False) == "full"


# -- k_frac agreement between pricing and round step -------------------------

def test_topk_frac_flag_parses():
    args = train.build_parser().parse_args(
        ["--compression", "topk", "--topk-frac", "0.25"])
    assert args.compression == "topk" and args.topk_frac == 0.25
    assert train.build_parser().parse_args([]).topk_frac == 0.01


def test_compression_setup_prices_and_transmits_same_k_frac():
    """One ``--topk-frac`` value must reach BOTH the s^UT multiplier and the
    round step's sparsifier -- desync here means the allocator budgets
    bandwidth for an upload the clients never send."""
    args = train.build_parser().parse_args(
        ["--compression", "topk", "--topk-frac", "0.25", "--error-feedback"])
    comp = train.compression_setup(args)
    assert comp["ratio"] == pytest.approx(
        fl_comp.compression_ratio("topk", k_frac=0.25))
    rs = comp["round_step_kwargs"]
    assert rs["compression"] == "topk"
    assert rs["topk_frac"] == 0.25
    assert rs["error_feedback"] is True
    # dense config prices dense and transmits dense
    dense = train.compression_setup(train.build_parser().parse_args([]))
    assert dense["ratio"] == 1.0
    assert dense["round_step_kwargs"]["compression"] == "none"
    assert dense["round_step_kwargs"]["error_feedback"] is False


def test_round_step_kwargs_reach_the_sparsifier():
    """Behavioral end of the agreement test: a round step built from
    ``compression_setup``'s kwargs keeps exactly k_frac of the delta."""
    from repro.fl import server

    args = train.build_parser().parse_args(
        ["--compression", "topk", "--topk-frac", "0.5"])
    kwargs = train.compression_setup(args)["round_step_kwargs"]

    def loss_fn(p, batch):
        return jnp.sum(p["w"] * batch["g"])   # grad == batch["g"]

    step = server.make_fl_round_step(loss_fn, local_steps=1, client_lr=1.0,
                                     server_lr=1.0, **kwargs)
    params = {"w": jnp.zeros((4,))}
    # one client, distinct gradient magnitudes: top half is entries 3, 2
    batches = {"g": jnp.asarray([[[0.1, 0.2, 0.3, 0.4]]])}
    new_params, _ = step(params, batches, jnp.ones((1,)))
    got = np.asarray(new_params["w"])
    np.testing.assert_allclose(got, [0.0, 0.0, -0.3, -0.4], rtol=1e-6)
    assert int(np.sum(got != 0.0)) == 2    # exactly k = 0.5 * 4
