"""Benchmark-harness tests: roofline composition math, collective-byte HLO
parsing, the per-period policy ordering that Figs. 11-12 rely on,
MODEL_FLOPS sanity for dense vs MoE archs, and schema validation of the
committed repo-root BENCH_*.json trajectory artifacts."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import roofline
from repro.launch.dryrun import collective_bytes, _shape_bytes

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("artifact,validator_module", [
    ("BENCH_allocation.json", "bench_allocation"),
    ("BENCH_fleet.json", "bench_fleet"),
    ("BENCH_cotrain.json", "paper_figs_cotrain"),
    ("BENCH_serve.json", "bench_serve"),
    ("BENCH_fault.json", "bench_fault"),
    ("BENCH_robust.json", "bench_robust"),
])
def test_committed_bench_artifacts_validate(artifact, validator_module):
    """The repo-root bench trajectory must stay machine-reconstructable:
    every committed artifact parses, passes its schema checker, and carries
    the commit/date/backend provenance stamp."""
    import importlib
    import warnings

    mod = importlib.import_module(f"benchmarks.{validator_module}")
    with open(os.path.join(_REPO_ROOT, artifact)) as fp:
        data = json.load(fp)
    mod.validate(data)
    assert data["tiny"] is False, f"{artifact} must be a full-size run"
    if data.get("dirty"):
        warnings.warn(
            f"\n{'!' * 70}\n"
            f"{artifact} carries a DIRTY provenance stamp: the numbers were\n"
            f"measured with uncommitted changes on top of commit\n"
            f"{data.get('commit', '?')[:12]}, so that commit alone does NOT\n"
            f"reproduce them.  Regenerate from a clean tree (commit the code\n"
            f"first, run the bench, then commit the artifact).\n"
            f"{'!' * 70}",
            UserWarning, stacklevel=2)


def test_shape_bytes_parser():
    assert _shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert _shape_bytes("bf16[2,4,8]") == 2 * 4 * 8 * 2
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("f32[8] u8[4]") == 36


def test_collective_bytes_parser():
    hlo = """
  %all-reduce.1 = f32[256,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[64,64]{1,0} all-gather-start(%y), dimensions={0}
  %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
  %other = f32[2,2]{1,0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 256 * 1024 * 4
    assert out["all-gather"] == 64 * 64 * 2
    assert out["all-to-all"] == 2 * 8 * 8 * 4
    assert "add" not in out


def test_corrected_terms_composition():
    cell = {"arch": "gemma-2b", "shape": "train_4k", "n_chips": 256,
            "flops": 1e12, "bytes_accessed": 1e11,
            "collective_bytes": {"all-reduce": 1e9}}
    block = {"flops": 5e11, "bytes_accessed": 5e10,
             "collective_bytes": {"all-gather": 2e8}}
    out = roofline.corrected_terms(cell, block, trips=2)
    np.testing.assert_allclose(out["flops_corrected"], 1e12 + 5e11)
    np.testing.assert_allclose(out["collective_bytes_corrected"], 1e9 + 2e8)
    assert out["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < out["roofline_fraction"] <= 1.0


def test_model_flops_moe_counts_active_only():
    dense = roofline.model_flops("gemma-2b", "train_4k")
    moe_total = roofline.model_flops("deepseek-v2-236b", "train_4k")
    # deepseek-v2 has ~21B active of 236B total; active FLOPs must be far
    # below 6*236e9*tokens
    from repro import configs
    total_params = configs.get_config("deepseek-v2-236b").param_count()
    tokens = 4096 * 256
    assert moe_total < 0.25 * 6 * total_params * tokens
    assert dense > 0


def test_per_period_policy_ordering():
    """The structural claim behind Fig. 11: on the proportional-fairness
    objective, Coop >= ES/PP/EC for any drawn period."""
    from repro.core import baselines, disba, network
    for seed in range(3):
        svc, _ = network.sample_services(jax.random.key(seed), 5)
        B = network.B_TOTAL_MHZ
        coop = float(jnp.sum(jnp.log1p(disba.solve_lambda_bisect(svc, B).f)))
        for fn in (baselines.equal_client, baselines.equal_service,
                   baselines.proportional):
            _, f = fn(svc, B)
            assert coop >= float(jnp.sum(jnp.log1p(f))) - 1e-5
