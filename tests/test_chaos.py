"""Chaos-engine contracts (PR 8): seeded storms replay exactly, every
injector family preserves the safety invariants, and each hardened
degradation path (solver fallback, carry repair, stale-streak degrade,
admission backoff) is counted -- never silent -- while the compiled step
still traces exactly once."""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro import chaos
from repro.chaos import invariants as chaos_invariants
from repro.chaos.engine import run_storm
from repro.chaos.injectors import (AdmissionChaos, CheckpointChaos,
                                   HeartbeatChaos, SolverChaos,
                                   poison_channel_state, poison_warm_seed)
from repro.chaos.schedule import ChaosSchedule
from repro.core import disba, policy
from repro.core.types import ServiceSet
from repro.fl import simulator
from repro.fl.control_plane import ControlPlane, ControlPlaneConfig
from repro.launch import allocd

B = 100.0


# ---------------------------------------------------------------------------
# Schedule determinism.
# ---------------------------------------------------------------------------

def test_schedule_same_seed_same_draws():
    a, b = ChaosSchedule(7), ChaosSchedule(7)
    for period in (0, 3, 11):
        for channel in ("solver", "hb/svc-1-0", "admission"):
            assert (a.rng(period, channel).random(4).tolist()
                    == b.rng(period, channel).random(4).tolist())


def test_schedule_channels_independent():
    """Draws on one channel never move another channel's stream -- the
    property that lets injectors fire in any combination without perturbing
    each other's schedules."""
    s = ChaosSchedule(7)
    before = s.rng(5, "solver").random(3).tolist()
    s.rng(5, "checkpoint").random(1000)       # burn a different channel
    assert s.rng(5, "solver").random(3).tolist() == before
    assert s.rng(5, "solver").random(1) != s.rng(6, "solver").random(1)


# ---------------------------------------------------------------------------
# Solver hardening units: sanitize + counted cold-bisection rescue.
# ---------------------------------------------------------------------------

def _svc(n=9, k=31, poison_row=None, seed=0):
    """Same construction as tests/test_fast_alloc.py's masked sets (the
    regime the warm clearer's tolerance contracts are pinned on), plus an
    optional NaN planted in a masked-in client of an active row."""
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.01, 0.3, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.01, 0.06, size=(n, k)).astype(np.float32)
    mask = np.zeros((n, k), dtype=bool)
    for i in range(n):
        mask[i, : rng.integers(2, k + 1)] = True
    alpha = np.where(mask, alpha, 0.0)
    t_comp = np.where(mask, t_comp, 0.0)
    if poison_row is not None:
        assert mask[poison_row, 0]
        alpha[poison_row, 0] = np.nan
    return ServiceSet(alpha=jnp.asarray(alpha), t_comp=jnp.asarray(t_comp),
                      mask=jnp.asarray(mask))


def test_sanitize_service_set_flags_and_cleans():
    clean, poisoned = disba.sanitize_service_set(_svc())
    assert not bool(poisoned)
    np.testing.assert_array_equal(np.asarray(clean.alpha),
                                  np.asarray(_svc().alpha))
    clean, poisoned = disba.sanitize_service_set(_svc(poison_row=1))
    assert bool(poisoned)
    assert np.all(np.isfinite(np.asarray(clean.alpha)))
    assert not bool(np.asarray(clean.mask)[1, 0])   # poisoned client masked
    assert bool(np.asarray(clean.mask)[1, 1])       # siblings stay in


def test_warm_solve_clean_is_bitwise_unchanged_and_unflagged():
    svc = _svc()
    res = disba.solve_lambda_newton_warm(svc, B, lam_prev=disba.WARM_COLD)
    assert not bool(res.fallback)
    ref = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bad_seed", [np.nan, np.inf, -np.inf])
def test_warm_solve_nonfinite_seed_triggers_counted_fallback(bad_seed):
    svc = _svc()
    res = disba.solve_lambda_newton_warm(svc, B, lam_prev=float(bad_seed))
    assert bool(res.fallback)
    assert np.all(np.isfinite(np.asarray(res.b)))
    assert np.isfinite(float(res.lam))
    ref = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-4)


def test_warm_solve_poisoned_inputs_trigger_fallback():
    res = disba.solve_lambda_newton_warm(_svc(poison_row=2), B,
                                         lam_prev=jnp.float32(0.5))
    assert bool(res.fallback)
    assert np.all(np.isfinite(np.asarray(res.b)))
    assert np.all(np.isfinite(np.asarray(res.f)))


def test_warm_solve_badly_stale_finite_seed_recovers_unflagged():
    """A finite but absurd warm price is the safeguarded bracket's job, not
    the rescue's: no fallback counted, result still correct."""
    svc = _svc()
    res = disba.solve_lambda_newton_warm(svc, B, lam_prev=jnp.float32(1e7))
    assert not bool(res.fallback)
    ref = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-4)


def test_warm_dual_state_accumulates_fallbacks():
    pol = policy.get_stateful_policy("coop", warm_start=True)
    state = pol.init_state(4)
    assert policy.fallback_count(state) == 0
    _, _, state = pol.step(_svc(), B, state)
    assert policy.fallback_count(state) == 0
    _, _, state = pol.step(_svc(poison_row=0), B, state)
    assert policy.fallback_count(state) == 1
    _, _, state = pol.step(_svc(), B, state)
    assert policy.fallback_count(state) == 1      # healthy step: no growth
    assert policy.fallback_count(()) == 0         # stateless policies


# ---------------------------------------------------------------------------
# NaN-poisoned channel state: every policy x warm combo degrades counted,
# serves finite, and still traces once.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("warm", [False, True])
@pytest.mark.parametrize("pol", simulator.POLICIES)
def test_poisoned_channel_counted_finite_single_trace(pol, warm):
    # Unique statics per combo so the lru-cached serve step cannot mask the
    # trace count with a prior compilation.
    rounds = 4321 + 2 * simulator.POLICIES.index(pol) + int(warm)
    cfg = ControlPlaneConfig(capacity=4, k_max=4, policy=pol,
                             warm_start=warm, rounds_required=rounds,
                             channel_process="gauss_markov", seed=0)
    simulator.reset_trace_count()
    plane = ControlPlane(cfg)
    # Fill every slot at full cohort so the poisoned leaf entry is
    # guaranteed to hit an enrolled client of an active row -- a NaN landing
    # in padding is legitimately absorbed by the masks (and would only be
    # counted via the carry repair).
    for i in range(cfg.capacity):
        plane.admit(f"s{i}", cfg.k_max)
    plane.tick()
    ev = poison_channel_state(plane, np.random.default_rng(0))
    assert ev is not None       # gauss_markov carries float state
    d = plane.tick()
    assert np.all(np.isfinite(d.b)) and np.all(np.isfinite(d.f))
    m = plane.metrics
    counted = (m["solver_fallbacks"] + m["nonfinite_decisions"]
               + m["carry_repairs"])
    assert counted > 0, "injected poison was absorbed silently"
    if pol == "coop" and warm:
        assert m["solver_fallbacks"] >= 1
    assert not plane.replayable and plane.unreplayable_reasons
    # Recovery: the repaired carry clears the next period finitely.
    d2 = plane.tick()
    assert np.all(np.isfinite(d2.b)) and np.all(np.isfinite(d2.f))
    assert simulator.trace_count() == 1


def test_poison_warm_seed_counted_on_next_tick():
    cfg = ControlPlaneConfig(capacity=4, k_max=4, policy="coop",
                             warm_start=True, rounds_required=5000, seed=0)
    plane = ControlPlane(cfg)
    plane.admit("a", 3)
    plane.tick()
    ev = poison_warm_seed(plane, np.random.default_rng(0), value=np.nan)
    assert ev is not None
    d = plane.tick()
    assert np.all(np.isfinite(d.b))
    assert plane.metrics["solver_fallbacks"] >= 1


def test_poison_helpers_return_none_when_inapplicable():
    cfg = ControlPlaneConfig(capacity=2, k_max=4, policy="coop",
                             warm_start=False, rounds_required=5000,
                             channel_process="iid", seed=0)
    plane = ControlPlane(cfg)
    assert poison_channel_state(plane, np.random.default_rng(0)) is None
    assert poison_warm_seed(plane, np.random.default_rng(0)) is None


# ---------------------------------------------------------------------------
# Daemon degradation paths: stale streak bound, admission backoff.
# ---------------------------------------------------------------------------

def _run(coro):
    return asyncio.run(coro)


def test_stale_streak_degrades_to_equal_share():
    cfg = ControlPlaneConfig(capacity=4, k_max=4, policy="coop",
                             warm_start=True, rounds_required=5000, seed=0)

    async def drive():
        daemon = allocd.AllocDaemon(cfg, max_stale_streak=2)
        daemon.submit(allocd.Admit("a", 3))
        daemon.submit(allocd.Admit("b", 2))
        flags = []
        await daemon.step_period()                 # healthy clear
        for _ in range(4):
            daemon._force_stale_next = True
            d = await daemon.step_period()
            flags.append((d.stale, d.degraded))
        await daemon.close()
        return flags, daemon

    flags, daemon = _run(drive())
    # streak 1 -> plain stale; streak >= max_stale_streak -> degraded.
    assert flags == [(True, False), (True, True), (True, True), (True, True)]
    m = daemon.plane.metrics
    assert m["stale_decisions"] >= 1 and m["degraded_decisions"] == 3
    # The degraded serve is budget-conserving equal share with f = 0.
    d = daemon.served[-1]
    np.testing.assert_allclose(float(np.sum(d.b)),
                               daemon.plane.net.total_bandwidth_mhz,
                               rtol=1e-5)
    assert np.all(np.asarray(d.f) == 0.0)


def test_admission_backoff_retries_then_lands():
    cfg = ControlPlaneConfig(capacity=1, k_max=4, policy="coop",
                             warm_start=True, rounds_required=5000, seed=0)

    async def drive():
        daemon = allocd.AllocDaemon(cfg, admit_max_retries=3)
        daemon.submit(allocd.Admit("a", 2))
        await daemon.step_period()
        daemon.submit(allocd.Admit("b", 2))        # capacity full -> retry
        await daemon.step_period()
        daemon.plane.retire("a")                   # slot frees up
        for _ in range(3):
            await daemon.step_period()
        await daemon.close()
        return daemon

    daemon = _run(drive())
    assert "b" in daemon.plane.services
    assert daemon.plane.metrics["admit_retries"] >= 1
    assert daemon.rejections == []


def test_admission_gives_up_after_bounded_retries():
    cfg = ControlPlaneConfig(capacity=1, k_max=4, policy="coop",
                             warm_start=True, rounds_required=5000, seed=0)

    async def drive():
        daemon = allocd.AllocDaemon(cfg, admit_max_retries=2)
        daemon.submit(allocd.Admit("a", 2))
        await daemon.step_period()
        daemon.submit(allocd.Admit("b", 2))        # never frees: must give up
        for _ in range(8):
            await daemon.step_period()
        await daemon.close()
        return daemon

    daemon = _run(drive())
    assert daemon._retry_queue == []
    assert len(daemon.rejections) == 1
    assert "gave up after 2 retries" in daemon.rejections[0][1]


# ---------------------------------------------------------------------------
# Storms: every injector family preserves the invariants; same seed ->
# identical digest.
# ---------------------------------------------------------------------------

_STORM_CFG = ControlPlaneConfig(
    capacity=6, k_max=6, policy="coop", warm_start=True, rounds_required=250,
    channel_process="gauss_markov", heartbeat_timeout_periods=2, seed=0)


def _family(name, k_max, tmp_path):
    base = [AdmissionChaos(k_max, p_admit=0.5)]     # the workload
    if name == "heartbeat":
        return base + [HeartbeatChaos(p_drop=0.2, p_flap=0.1)], None
    if name == "solver":
        return base + [SolverChaos(p_deadline=0.2, p_poison_chan=0.15,
                                   p_poison_seed=0.1)], None
    if name == "checkpoint":
        return base + [CheckpointChaos(p_torn=0.1, p_truncate=0.1,
                                       p_corrupt=0.1, p_restart=0.15)], \
            str(tmp_path / "ckpt")
    return base, None                                # admission alone


@pytest.mark.parametrize("family",
                         ["admission", "heartbeat", "solver", "checkpoint"])
def test_storm_invariants_per_injector_family(tmp_path, family):
    injectors, ckpt = _family(family, _STORM_CFG.k_max, tmp_path)
    report = run_storm(_STORM_CFG, seed=11, n_periods=18,
                       injectors=injectors, checkpoint_dir=ckpt)
    bad = {k: v for k, v in report["invariants"].items() if not v["ok"]}
    assert not bad, f"{family} storm violated invariants: {bad}"
    assert report["served"]["fresh"] + report["served"]["stale"] + \
        report["served"]["degraded"] == 18


def test_storm_same_seed_identical_digest(tmp_path):
    r1 = run_storm(_STORM_CFG, seed=42, n_periods=20,
                   checkpoint_dir=str(tmp_path / "a"))
    r2 = run_storm(_STORM_CFG, seed=42, n_periods=20,
                   checkpoint_dir=str(tmp_path / "b"))
    assert r1["digest"] == r2["digest"]
    assert r1["events"] == r2["events"]
    assert r1["metrics"] == r2["metrics"]
    r3 = run_storm(_STORM_CFG, seed=43, n_periods=20,
                   checkpoint_dir=str(tmp_path / "c"))
    assert r3["digest"] != r1["digest"]
    for r in (r1, r3):
        bad = {k: v for k, v in r["invariants"].items() if not v["ok"]}
        assert not bad, bad


def test_healthy_storm_replay_invariant_is_bitwise():
    """With no injectors at all (scripted admissions only), the plane stays
    replayable and the invariant harness's differential replay actually
    runs -- guarding against the replay check silently skipping forever."""
    cfg = ControlPlaneConfig(capacity=4, k_max=4, policy="coop",
                             warm_start=True, rounds_required=300, seed=0)

    class Workload(chaos.Injector):
        name = "workload"

        def pre(self, engine, period):
            if period in (0, 2) and engine.daemon.plane.free_slots:
                engine.daemon.submit(
                    allocd.Admit(f"w{period}", 3))
                return [{"action": "admit", "service": f"w{period}"}]
            return []

    report = run_storm(cfg, seed=1, n_periods=10, injectors=[Workload()])
    replay = report["invariants"]["replay"]
    assert replay["ok"] and not replay["skipped"] and replay["checked"] > 0
    assert report["served"]["fresh"] == 10
    assert all(v == 0 for k, v in report["metrics"].items()
               if k in ("solver_fallbacks", "nonfinite_decisions",
                        "carry_repairs", "degraded_decisions"))


def test_assert_invariants_raises_on_violation():
    cfg = ControlPlaneConfig(capacity=2, k_max=4, policy="coop",
                             warm_start=True, rounds_required=5000, seed=0)
    plane = ControlPlane(cfg)
    plane.admit("a", 2)
    d = plane.tick()
    forged = d._replace(b=np.full_like(d.b, 1e9))   # budget violation
    with pytest.raises(AssertionError, match="budget"):
        chaos_invariants.assert_invariants([forged], plane)
