"""Tile-edge padding parity: every allocation kernel must be exact on shapes
where N is NOT a multiple of its row tile and K is NOT a multiple of the
128-lane pad, with ragged masks and fully-inactive service slots riding in
the padded region.  Also the unified ``ops._resolve_backend`` dispatch rule,
including the ``REPRO_FORCE_PALLAS`` CI override.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import disba, network
from repro.core.types import ServiceSet
from repro.kernels import ops, ref
from repro.kernels.bisect_alloc import bisect_alloc
from repro.kernels.dual_demand import dual_demand
from repro.kernels.market_clear import market_clear, mbdf_demand

B = network.B_TOTAL_MHZ

# None of these N are tile multiples (tiles are 8 / 128); K values straddle
# the 128-lane pad boundary: 13 < 128, 130 and 257 just past a multiple.
EDGE_SHAPES = [(5, 13), (9, 130), (13, 100), (21, 257)]


def _edge_set(seed, n, k):
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.01, 0.3, size=(n, k)).astype(np.float32)
    t_comp = rng.uniform(0.01, 0.06, size=(n, k)).astype(np.float32)
    mask = np.zeros((n, k), dtype=bool)
    for i in range(n):
        mask[i, : rng.integers(1, k + 1)] = True
    mask[rng.integers(0, n)] = False          # a fully-inactive slot
    alpha = np.where(mask, alpha, 0.0)
    t_comp = np.where(mask, t_comp, 0.0)
    return ServiceSet(alpha=jnp.asarray(alpha), t_comp=jnp.asarray(t_comp),
                      mask=jnp.asarray(mask))


@pytest.mark.parametrize("n,k", EDGE_SHAPES)
def test_dual_demand_tile_edges(n, k):
    svc = _edge_set(0, n, k)
    lam = jnp.float32(0.2)
    b, slope = dual_demand(svc.alpha, svc.t_comp, lam, interpret=True)
    b_r, s_r = ref.dual_demand_ref(svc.alpha, svc.t_comp, lam)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_r),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(slope), np.asarray(s_r),
                               rtol=1e-3, atol=1e-4)
    inactive = ~np.asarray(svc.service_active())
    assert np.all(np.asarray(b)[inactive] == 0.0)


@pytest.mark.parametrize("n,k", EDGE_SHAPES)
def test_bisect_alloc_tile_edges(n, k):
    svc = _edge_set(1, n, k)
    b = jax.random.uniform(jax.random.key(2), (n,), minval=0.2, maxval=4.0)
    b = jnp.where(svc.service_active(), b, 0.0)
    t_star, b_alloc = bisect_alloc(svc.alpha, svc.t_comp, b, interpret=True)
    t_r, b_r = ref.bisect_alloc_ref(svc.alpha, svc.t_comp, b)
    active = np.asarray(svc.service_active())
    np.testing.assert_allclose(np.asarray(t_star)[active],
                               np.asarray(t_r)[active], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(b_alloc), np.asarray(b_r),
                               rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("n,k", EDGE_SHAPES)
def test_market_clear_tile_edges(n, k):
    svc = _edge_set(2, n, k)
    lam_prev = disba.solve_lambda_bisect(svc, B).lam * jnp.float32(1.03)
    expect = disba.solve_lambda_newton_warm(svc, B, lam_prev)
    b, f, lam = market_clear(svc.alpha, svc.t_comp, jnp.float32(B), lam_prev,
                             tile_n=8, interpret=True)
    np.testing.assert_allclose(float(lam), float(expect.lam), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(expect.b),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f), np.asarray(expect.f),
                               rtol=1e-3, atol=1e-5)
    inactive = ~np.asarray(svc.service_active())
    assert np.all(np.asarray(b)[inactive] == 0.0)
    assert np.all(np.asarray(f)[inactive] == 0.0)


@pytest.mark.parametrize("n,k", EDGE_SHAPES)
def test_mbdf_tile_edges(n, k):
    svc = _edge_set(3, n, k)
    from repro.core import auction, fairness

    bid = auction.uniform_truthful_bids(svc, 3, 0.5)
    expect = fairness.mbdf_grid(svc, bid.prices, 0.5)
    got = mbdf_demand(svc.alpha, svc.t_comp, bid.prices, 0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# The unified dispatch rule.
# ---------------------------------------------------------------------------

def test_resolve_backend_defaults(monkeypatch):
    monkeypatch.delenv(ops.FORCE_PALLAS_ENV, raising=False)
    on_tpu = ops._on_tpu()
    use, interp = ops._resolve_backend(None, False)
    assert use is on_tpu
    assert interp is (not on_tpu)
    # explicit overrides always win
    assert ops._resolve_backend(True, False)[0] is True
    assert ops._resolve_backend(False, False)[0] is False
    # explicit interpret stays on
    assert ops._resolve_backend(True, True)[1] is True


def test_resolve_backend_force_pallas_env(monkeypatch):
    monkeypatch.setenv(ops.FORCE_PALLAS_ENV, "1")
    use, interp = ops._resolve_backend(None, False)
    assert use is True
    assert interp is (not ops._on_tpu())
    # the env var forces only the *auto* path; explicit False still wins
    assert ops._resolve_backend(False, False)[0] is False
    monkeypatch.setenv(ops.FORCE_PALLAS_ENV, "0")
    assert ops._resolve_backend(None, False)[0] is ops._on_tpu()


def test_force_pallas_env_runs_interpret_kernel(monkeypatch):
    """With the override set, the auto path of an op really is the kernel:
    dual_demand's auto result matches the explicit interpret launch."""
    monkeypatch.setenv(ops.FORCE_PALLAS_ENV, "1")
    svc = _edge_set(4, 7, 19)
    lam = jnp.float32(0.25)
    b_auto, s_auto = ops.dual_demand(svc.alpha, svc.t_comp, lam)
    b_kern, s_kern = dual_demand(svc.alpha, svc.t_comp, lam, interpret=True)
    assert np.array_equal(np.asarray(b_auto), np.asarray(b_kern))
    assert np.array_equal(np.asarray(s_auto), np.asarray(s_kern))
