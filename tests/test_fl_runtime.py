"""FL runtime tests: real federated training improves the loss, FedAvg
equals the centralized gradient step in the 1-local-step IID case, straggler
drop works, compression feeds the allocator, the simulator runs/restarts,
checkpoint manager survives crashes, optimizers descend."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.fl import compression, server, simulator
from repro.fl.service import arch_service_tuple
from repro.core.types import stack_services
from repro.core import intra
from repro.models import registry
from repro.optim import adamw, sgd


def _tiny_model():
    cfg = configs.get_smoke_config("gemma-2b", n_layers=2, d_model=64, d_ff=128,
                                   vocab_size=64, n_heads=2, head_dim=32)
    return cfg, registry.build_model(cfg)


def _client_batches(data, step, n_clients, local_steps, batch):
    per = [
        jax.tree.map(lambda *xs: jnp.stack(xs),
                     *[data.batch(step * 100 + e, batch, client_id=c)
                       for e in range(local_steps)])
        for c in range(n_clients)
    ]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def test_federated_training_reduces_loss():
    cfg, model = _tiny_model()
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, seed=0, temperature=0.3)
    params = model.init(jax.random.key(0))
    round_step = jax.jit(server.make_fl_round_step(
        model.loss, local_steps=2, client_lr=2.0))
    n_clients = 4
    weights = jnp.ones((n_clients,))
    losses = []
    for step in range(8):
        batches = _client_batches(data, step, n_clients, 2, batch=8)
        params, metrics = round_step(params, batches, weights)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_fedavg_single_step_equals_central_sgd():
    """With 1 local step and identical client batches, FedAvg == plain SGD."""
    cfg, model = _tiny_model()
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, seed=0)
    params = model.init(jax.random.key(0))
    batch = data.batch(0, 4)
    n_clients = 3
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None, None], (n_clients, 1, *x.shape)), batch
    )
    round_step = server.make_fl_round_step(model.loss, local_steps=1, client_lr=0.1)
    p_fed, _ = round_step(params, batches, jnp.ones((n_clients,)))
    g = jax.grad(model.loss)(params, batch)
    p_sgd = jax.tree.map(lambda p, gr: p - 0.1 * gr, params, g)
    for a, b in zip(jax.tree.leaves(p_fed), jax.tree.leaves(p_sgd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_straggler_drop_excludes_late_clients():
    lat = jnp.array([0.1, 0.5, 3.0, 0.2])
    w = server.straggler_weights(lat, deadline=1.0)
    np.testing.assert_array_equal(np.asarray(w), [1, 1, 0, 1])
    deltas = {"w": jnp.arange(4, dtype=jnp.float32)[:, None] * jnp.ones((4, 2))}
    agg = server.fedavg_round(deltas, w)
    np.testing.assert_allclose(np.asarray(agg["w"]), (0 + 1 + 3) / 3.0)


def test_compression_error_feedback_converges():
    """Error feedback telescopes exactly: sum of transmissions equals
    n*delta - final residual, and the residual stays bounded (never grows
    past the scale set by the largest untransmitted mass)."""
    delta = {"w": jax.random.normal(jax.random.key(0), (64,))}
    residual = jax.tree.map(jnp.zeros_like, delta)
    sent_total = jnp.zeros((64,))
    n = 30
    max_res = 0.0
    for _ in range(n):
        sparse, residual = compression.topk_sparsify(delta, 0.1, residual)
        sent_total = sent_total + sparse["w"]
        max_res = max(max_res, float(jnp.max(jnp.abs(residual["w"]))))
    np.testing.assert_allclose(
        np.asarray(sent_total), np.asarray(n * delta["w"] - residual["w"]),
        rtol=1e-4, atol=1e-4,
    )
    # bounded residual: top-k with EF cannot accumulate more than ~1/k_frac
    # rounds' worth of the largest entry
    assert max_res < 12 * float(jnp.max(jnp.abs(delta["w"])))


def test_topk_keeps_exactly_k_on_ties():
    """Regression for the |x| >= thresh selection: a leaf of tied
    magnitudes must transmit exactly k entries, not every tied one (the
    threshold form kept all of them and made compression_ratio a lie)."""
    delta = {"w": jnp.ones((8,))}
    sparse, residual = compression.topk_sparsify(delta, 0.25)
    assert int(jnp.count_nonzero(sparse["w"])) == 2
    # what wasn't sent is carried by the residual, exactly
    np.testing.assert_array_equal(np.asarray(sparse["w"] + residual["w"]),
                                  np.asarray(delta["w"]))
    # mixed leaf: ties below the cut resolve to exactly k winners too
    delta = {"w": jnp.asarray([3.0, -1.0, 1.0, 1.0])}
    sparse, _ = compression.topk_sparsify(delta, 0.5)
    kept = np.flatnonzero(np.asarray(sparse["w"]))
    assert len(kept) == 2 and 0 in kept


def test_topk_all_zero_leaf_stays_sparse():
    """Regression for thresh == 0 on an all-zero leaf: |x| >= 0 selected the
    ENTIRE leaf (n transmitted entries billed as k).  The index+scatter form
    keeps the k-entry budget and a zero residual."""
    delta = {"w": jnp.zeros((16,)), "b": jnp.asarray([0.0, 2.0, 0.0, 0.0])}
    sparse, residual = compression.topk_sparsify(delta, 0.25)
    np.testing.assert_array_equal(np.asarray(sparse["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(residual["w"]), 0.0)
    # the non-zero leaf still transmits its top entry
    np.testing.assert_array_equal(np.asarray(sparse["b"]), [0.0, 2.0, 0.0, 0.0])


def test_compression_ratio_feeds_allocator():
    """Compressed uplink shrinks alpha and strictly increases f* at fixed b."""
    cfg = configs.get_smoke_config("gemma-2b")
    r = jnp.full((4,), 8.0)
    phi = jnp.full((4,), 1e12)
    dense = arch_service_tuple(cfg, r_dl=r, r_ul=r, client_flops=phi)
    comp = arch_service_tuple(
        cfg, r_dl=r, r_ul=r, client_flops=phi,
        uplink_compression=compression.compression_ratio("topk", 0.01),
    )
    svc = stack_services([dense, comp])
    b = jnp.array([1.0, 1.0])
    f = intra.freq(svc, b)
    assert float(f[1]) > float(f[0])


def test_int8_quantization_bounded_error():
    delta = {"w": jax.random.normal(jax.random.key(1), (256,))}
    deq, res = compression.int8_quantize(delta)
    scale = float(jnp.max(jnp.abs(delta["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(res["w"]))) <= scale * 0.5 + 1e-6


@pytest.mark.parametrize("policy", ["coop", "selfish", "ec", "es", "pp"])
def test_simulator_runs_all_policies(policy):
    cfg = simulator.SimConfig(policy=policy, n_services_total=3,
                              rounds_required=150, p_arrive=2.0, seed=1)
    out = simulator.run(cfg)
    assert out["finished"]
    assert out["avg_duration"] >= 1.0


def test_simulator_coop_not_worse_than_equal_service():
    base = dict(n_services_total=4, rounds_required=300, p_arrive=1.0, seed=3)
    coop = simulator.run(simulator.SimConfig(policy="coop", **base))
    es = simulator.run(simulator.SimConfig(policy="es", **base))
    assert coop["avg_duration"] <= es["avg_duration"] + 1e-9


def test_simulator_resumes_from_state():
    cfg = simulator.SimConfig(policy="coop", n_services_total=3,
                              rounds_required=200, p_arrive=1.0, seed=5,
                              max_periods=3)
    partial = simulator.run(cfg)
    assert not partial["finished"]
    cfg_full = simulator.SimConfig(policy="coop", n_services_total=3,
                                   rounds_required=200, p_arrive=1.0, seed=5)
    resumed = simulator.run(cfg_full, state=partial["state"])
    fresh = simulator.run(cfg_full)
    assert resumed["finished"] and fresh["finished"]
    assert resumed["durations"] == fresh["durations"]


def test_checkpoint_roundtrip_and_crash_recovery(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5, dtype=jnp.float32), "b": {"c": jnp.ones((2, 3))}}
    mgr.save(1, tree, extra={"loss": 1.0})
    tree2 = jax.tree.map(lambda x: x * 2, tree)
    mgr.save(2, tree2, extra={"loss": 0.5})
    # simulate a crash: an incomplete step dir without COMMIT
    bad = os.path.join(str(tmp_path), "step_0000000003")
    os.makedirs(bad)
    with open(os.path.join(bad, "meta.json"), "w") as f:
        f.write("{}")
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 2 and extra == {"loss": 0.5}
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree2["a"]))


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_adamw_descends_quadratic():
    init, update = adamw(lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state = update(g, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_sgd_momentum_descends():
    init, update = sgd(lr=0.05, momentum=0.9)
    params = {"w": jnp.array([3.0])}
    state = init(params)
    for _ in range(200):
        params, state = update({"w": 2 * params["w"]}, state, params)
    assert abs(float(params["w"][0])) < 1e-2


def test_synthetic_data_deterministic_and_learnable():
    data = SyntheticLM(vocab_size=64, seq_len=8, seed=0)
    b1 = data.batch(3, 4)
    b2 = data.batch(3, 4)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = data.batch(4, 4)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
