"""Tests for DISBA (Algorithm 1) and its fast variants — paper §IV."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, disba, intra, network


@pytest.fixture(scope="module")
def scenario():
    svc, meta = network.table1_service_set(jax.random.key(0))
    return svc, network.B_TOTAL_MHZ


def test_disba_converges_to_market_clearing(scenario):
    svc, B = scenario
    res = disba.disba(svc, B, gamma=0.1, eps=1e-4)
    ref = disba.solve_lambda_bisect(svc, B)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b), rtol=5e-3, atol=1e-3)
    np.testing.assert_allclose(float(res.lam), float(ref.lam), rtol=5e-3)


def test_newton_matches_bisect(scenario):
    svc, B = scenario
    ref = disba.solve_lambda_bisect(svc, B)
    newt = disba.solve_lambda_newton(svc, B)
    np.testing.assert_allclose(np.asarray(newt.b), np.asarray(ref.b), rtol=1e-4, atol=1e-5)


def test_budget_feasibility(scenario):
    svc, B = scenario
    for res in (disba.disba(svc, B), disba.solve_lambda_bisect(svc, B)):
        np.testing.assert_allclose(float(jnp.sum(res.b)), B, rtol=1e-5)
        assert bool(jnp.all(res.b >= 0))


def test_kkt_stationarity(scenario):
    """At the optimum, f'/(1+f) equals the shared dual price for every active
    service (Eq. 13)."""
    svc, B = scenario
    res = disba.solve_lambda_bisect(svc, B)
    price = intra.price_at_freq(svc, res.f)
    active = res.b > 1e-4
    np.testing.assert_allclose(
        np.asarray(price)[np.asarray(active)], float(res.lam), rtol=5e-3
    )


def test_disba_beats_benchmarks(scenario):
    """Proportional-fairness optimality: DISBA's objective dominates EC/ES/PP."""
    svc, B = scenario
    res = disba.solve_lambda_bisect(svc, B)
    obj_coop = float(jnp.sum(jnp.log1p(res.f)))
    for fn in (baselines.equal_client, baselines.equal_service, baselines.proportional):
        _, f = fn(svc, B)
        assert obj_coop >= float(jnp.sum(jnp.log1p(f))) - 1e-5


def test_disba_beats_random_feasible_points(scenario):
    svc, B = scenario
    res = disba.solve_lambda_bisect(svc, B)
    obj = float(disba.objective(svc, res.b))
    rng = np.random.default_rng(0)
    for _ in range(30):
        w = rng.dirichlet(np.ones(svc.n_services)).astype(np.float32)
        assert obj >= float(disba.objective(svc, jnp.asarray(w * B))) - 1e-5


def test_diminishing_step_converges_from_aggressive_gamma(scenario):
    svc, B = scenario
    res = disba.disba(svc, B, gamma=0.5, eps=1e-3, diminishing=True)
    ref = disba.solve_lambda_bisect(svc, B)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b), rtol=5e-2, atol=5e-3)


def test_trace_matches_jitted(scenario):
    svc, B = scenario
    hist = disba.disba_trace(svc, B, gamma=0.1, eps=1e-4)
    res = disba.disba(svc, B, gamma=0.1, eps=1e-4)
    assert hist["iterations"] == int(res.iterations)
    np.testing.assert_allclose(
        np.asarray(hist["b_final"]), np.asarray(res.b), rtol=1e-4
    )


def test_disba_sharded_single_device(scenario):
    """shard_map variant on the trivial 1-device mesh must equal the reference."""
    svc, B = scenario
    # pad services to the device count multiple (1 here, no-op)
    mesh = jax.make_mesh((1,), ("data",))
    res = disba.disba_sharded(mesh, svc, B, axis_names=("data",))
    ref = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b), rtol=1e-4, atol=1e-5)


def _pad_to_multiple(svc, multiple: int):
    """Append all-masked rows until n_services divides ``multiple`` (the
    fixed-capacity pad convention: empty rows demand zero bandwidth)."""
    from repro.core.types import ServiceSet

    extra = -svc.n_services % multiple
    if extra == 0:
        return svc
    z = jnp.zeros((extra, svc.alpha.shape[1]), svc.alpha.dtype)
    return ServiceSet(
        alpha=jnp.concatenate([svc.alpha, z]),
        t_comp=jnp.concatenate([svc.t_comp, z]),
        mask=jnp.concatenate([svc.mask, jnp.zeros(z.shape, bool)]),
    )


def test_disba_sharded_default_mesh_via_compat(scenario):
    """mesh=None builds the mesh through compat.flat_mesh -- the same
    construction path run_fleet uses -- and must match the explicit mesh.
    Padded to the visible device count so the test holds on any host."""
    from repro.compat import flat_mesh

    svc, B = scenario
    svc = _pad_to_multiple(svc, jax.device_count())
    res = disba.disba_sharded(None, svc, B)
    ref = disba.disba_sharded(flat_mesh(axis_name="data"), svc, B)
    np.testing.assert_array_equal(np.asarray(res.b), np.asarray(ref.b))
    with pytest.raises(ValueError, match="one-axis"):
        disba.disba_sharded(None, svc, B, axis_names=("a", "b"))


def test_disba_sharded_masked_padded_matches_dense(scenario):
    """All-masked pad rows (the fixed-capacity convention) demand zero
    bandwidth, so a padded sharded solve equals the dense reference on the
    real rows and allocates exactly nothing to the pads."""
    from repro.core.types import ServiceSet, mask_inactive

    svc, B = scenario
    n = svc.n_services
    padded = _pad_to_multiple(
        ServiceSet(
            alpha=jnp.concatenate([svc.alpha, jnp.zeros_like(svc.alpha)]),
            t_comp=jnp.concatenate([svc.t_comp, jnp.zeros_like(svc.t_comp)]),
            mask=jnp.concatenate([svc.mask, jnp.zeros_like(svc.mask)]),
        ),
        jax.device_count(),
    )
    pad = padded.n_services
    assert pad >= 2 * n
    res = disba.disba_sharded(None, padded, B)
    ref = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(res.b)[:n], np.asarray(ref.b),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res.b)[n:], 0.0)
    np.testing.assert_array_equal(np.asarray(res.f)[n:], 0.0)
    # masking out live rows mid-set behaves the same way
    keep = jnp.arange(pad) != 1
    masked = mask_inactive(padded, keep)
    sub = ServiceSet(
        alpha=jnp.concatenate([svc.alpha[:1], svc.alpha[2:]]),
        t_comp=jnp.concatenate([svc.t_comp[:1], svc.t_comp[2:]]),
        mask=jnp.concatenate([svc.mask[:1], svc.mask[2:]]),
    )
    res_m = disba.disba_sharded(None, masked, B)
    ref_m = disba.solve_lambda_bisect(sub, B)
    np.testing.assert_allclose(
        np.asarray(res_m.b)[np.asarray(keep)][: n - 1],
        np.asarray(ref_m.b), rtol=1e-4, atol=1e-5)
    assert float(np.asarray(res_m.b)[1]) == 0.0


MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import disba, network
    from repro.core.types import ServiceSet

    svc, _ = network.sample_services(jax.random.key(1), 16, k_max=30)
    B = network.B_TOTAL_MHZ
    mesh = jax.make_mesh((8,), ("data",))
    res = disba.disba_sharded(mesh, svc, B, axis_names=("data",))
    ref = disba.solve_lambda_bisect(svc, B)
    np.testing.assert_allclose(np.asarray(res.b), np.asarray(ref.b), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(jnp.sum(res.b)), B, rtol=1e-5)
    # mesh=None routes through compat.flat_mesh over all 8 devices -- the
    # same mesh-construction path as fl.simulator.run_fleet
    res_auto = disba.disba_sharded(None, svc, B)
    np.testing.assert_array_equal(np.asarray(res_auto.b), np.asarray(res.b))
    print("SHARDED-OK")
    """
)


def test_disba_sharded_eight_devices():
    """The paper's operator<->provider message pattern across 8 devices: only a
    scalar psum crosses shards; the allocation must match the centralized
    solution.  Runs in a subprocess so the 8-device XLA flag doesn't leak."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert "SHARDED-OK" in out.stdout, out.stderr[-2000:]
