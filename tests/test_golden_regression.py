"""Golden regression: run_batch summary statistics for every policy, pinned
against checked-in JSON so allocator refactors cannot silently drift the
Fig. 11-15 trajectory.  Durations are integers and compared exactly;
per-period float statistics to tight tolerance (cross-platform FP).
Regenerate deliberately with: PYTHONPATH=src python tests/golden/regen_golden.py
"""
import json
import os

import numpy as np
import pytest

from repro.fl import simulator

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "longterm_summary.json")


@pytest.fixture(scope="module")
def golden() -> dict:
    with open(GOLDEN_PATH) as fp:
        return json.load(fp)


def test_golden_covers_every_registered_paper_policy(golden):
    assert set(golden["policies"]) == set(simulator.POLICIES)


@pytest.mark.parametrize("pol", simulator.POLICIES)
def test_run_batch_matches_golden(golden, pol):
    cfg = simulator.SimConfig(policy=pol, **golden["config"])
    out = simulator.run_batch(cfg, golden["seeds"])
    exp = golden["policies"][pol]
    np.testing.assert_array_equal(
        np.asarray(out["durations"]), np.asarray(exp["durations"]),
        err_msg=f"{pol}: per-service durations drifted from golden")
    np.testing.assert_allclose(
        out["avg_duration"], exp["avg_duration"], rtol=1e-9,
        err_msg=f"{pol}: avg_duration drifted from golden")
    assert [bool(x) for x in out["finished"]] == exp["finished"]
    np.testing.assert_allclose(
        out["history"]["freq_sum"].mean(axis=1), exp["mean_freq_sum"],
        rtol=1e-4, err_msg=f"{pol}: mean frequency trajectory drifted")
