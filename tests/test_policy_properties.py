"""Property-based tests (hypothesis) parametrized over every registered
AllocationPolicy: the allocation is never oversubscribed and the full budget
lands on the active rows (exactly, except the demand-limited auction, which
clears min(B, aggregate demand)), inactive slots get exactly zero, and
allocations are equivariant to permutations of the service rows.  Runs in CI
(hypothesis is installed there, with a workflow step that fails the build if
these would silently skip); deterministic spot-checks of the same invariants
live in tests/test_policy_simulator.py so the properties are exercised even
where hypothesis is absent."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
import hypothesis.strategies as st  # noqa: E402

from repro.core import network, policy  # noqa: E402
from repro.core.types import ServiceSet  # noqa: E402

B = network.B_TOTAL_MHZ
K = 16  # fixed client pad so every example reuses one trace cache entry


def build_service_set(seed: int, n: int, n_inactive: int) -> ServiceSet:
    """Random padded ServiceSet with ragged counts and n_inactive empty rows."""
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.01, 0.4, size=(n, K)).astype(np.float32)
    t_comp = rng.uniform(0.01, 0.08, size=(n, K)).astype(np.float32)
    mask = np.zeros((n, K), dtype=bool)
    for i in range(n):
        mask[i, : rng.integers(1, K + 1)] = True
    for i in rng.permutation(n)[:n_inactive]:
        mask[i] = False
    alpha = np.where(mask, alpha, 0.0)
    t_comp = np.where(mask, t_comp, 0.0)
    return ServiceSet(alpha=jnp.asarray(alpha), t_comp=jnp.asarray(t_comp),
                      mask=jnp.asarray(mask))


def check_budget_and_inactive(name: str, svc: ServiceSet) -> None:
    b, f = policy.allocate(name, svc, B)
    b, f = np.asarray(b), np.asarray(f)
    active = np.asarray(svc.service_active())
    # inactive slots: exactly zero, not merely small
    assert np.all(b[~active] == 0.0)
    assert np.all(f[~active] == 0.0)
    assert np.all(b >= 0.0) and np.all(f >= 0.0)
    if not active.any():
        assert b.sum() == 0.0
        return
    # never oversubscribed
    assert b[active].sum() <= B * (1.0 + 1e-4)
    if name == "selfish":
        # the auction is demand-limited: providers take min(B, what they bid
        # for) -- the budget clears exactly iff aggregate demand reaches B
        from repro.core import auction
        bid = auction.uniform_truthful_bids(svc, n_bids=5, alpha_fair=0.5)
        max_demand = float(np.asarray(bid.demands)[active, 0].sum())
        np.testing.assert_allclose(b[active].sum(), min(B, max_demand),
                                   rtol=1e-3)
    else:
        # every other policy hands the whole budget to the active rows
        np.testing.assert_allclose(b[active].sum(), B, rtol=1e-4)


def check_permutation_equivariance(name: str, svc: ServiceSet,
                                   perm: np.ndarray) -> None:
    b, f = policy.allocate(name, svc, B)
    svc_p = ServiceSet(alpha=svc.alpha[perm], t_comp=svc.t_comp[perm],
                       mask=svc.mask[perm])
    b_p, f_p = policy.allocate(name, svc_p, B)
    np.testing.assert_allclose(np.asarray(b_p), np.asarray(b)[perm],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f)[perm],
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", policy.available())
@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8),
                  n_inactive=st.integers(0, 2))
def test_budget_on_active_rows_and_zero_on_inactive(name, seed, n, n_inactive):
    check_budget_and_inactive(name, build_service_set(seed, n, min(n_inactive, n)))


@pytest.mark.parametrize("name", policy.available())
@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 8))
def test_permutation_equivariance(name, seed, n):
    svc = build_service_set(seed, n, n_inactive=1)
    perm = np.random.default_rng(seed + 1).permutation(n)
    check_permutation_equivariance(name, svc, perm)


@pytest.mark.parametrize("name", policy.available())
@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_all_inactive_set_allocates_nothing(name, seed):
    svc = build_service_set(seed, n=3, n_inactive=3)
    check_budget_and_inactive(name, svc)
